"""Paper Fig. 7 — performance after a fixed sample budget under different
communication periods tau, for EASGD / WASGD / WASGD+. The paper's claim:
WASGD+ at tau=1000 matches EASGD at tau=50 (i.e. it tolerates 20x less
communication)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, train_run

SAMPLES_PER_WORKER = 1280      # fixed two-epoch-style budget


def run(fast: bool = False):
    taus = [8, 16, 64] if fast else [8, 16, 64, 160]
    b_local = 8
    results = {}
    for p in ([4] if fast else [4, 8]):
        for tau in taus:
            rounds = max(2, SAMPLES_PER_WORKER // (tau * b_local))
            for method, kw in [
                ("easgd", dict(rule="easgd", easgd_alpha=0.9 / 16)),
                ("wasgd", dict(rule="wasgd", strategy="inverse", beta=1.0,
                               order_search=False)),
                ("wasgd+", dict(rule="wasgd", strategy="boltzmann",
                                beta=0.9, a_tilde=1.0, order_search=True)),
            ]:
                t0 = time.time()
                res = train_run(p=p, tau=tau, b_local=b_local, rounds=rounds,
                                **kw)
                results[(method, tau, p)] = res["final_loss"]
                emit(f"fig7_{method}_tau{tau}_p{p}",
                     (time.time() - t0) / rounds * 1e6,
                     f"final_loss={res['final_loss']:.4f};acc={res['acc']:.3f}")

    for p in ([4] if fast else [4, 8]):
        for tau in taus:
            better = results[("wasgd+", tau, p)] <= \
                results[("easgd", tau, p)] + 1e-9
            emit(f"fig7_claim_wasgdplus_beats_easgd_tau{tau}_p{p}", 0.0,
                 f"holds={better}")
    return results
