# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark entry point:

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig8,...]

Modules (one per paper figure + the roofline deliverable):
  fig3  order_effect     — sample-order delta sweep
  fig4  temperature      — T = 1/a_tilde weighting-strategy sweep
  fig5  beta_sweep       — acceptance beta sweep
  fig6  estimation_m     — weight-estimation error vs m (Eq. 27)
  fig7  tau_sweep        — communication-period sweep, EASGD vs WASGD(+)
  fig8  convergence      — WASGD+ vs all six baselines (Figs. 8-11)
  kern  kernel_bench     — Pallas kernel microbenchmarks
  roof  roofline_table   — dry-run roofline table (§Roofline)
  bynd  beyond_paper     — beyond-paper extensions (anneal, order ablation,
                           bf16 comm payload)
  alg4  async_straggler  — Alg. 4 async-vs-sync straggler simulation
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig3,fig4,fig5,fig6,fig7,"
                         "fig8,kern,roof")
    args = ap.parse_args()

    from benchmarks import (async_straggler, beta_sweep, beyond_paper,
                            convergence, estimation_m, kernel_bench,
                            order_effect, roofline_table, tau_sweep,
                            temperature)
    modules = {
        "fig3": order_effect, "fig4": temperature, "fig5": beta_sweep,
        "fig6": estimation_m, "fig7": tau_sweep, "fig8": convergence,
        "kern": kernel_bench, "roof": roofline_table, "bynd": beyond_paper,
        "alg4": async_straggler,
    }
    selected = (args.only.split(",") if args.only else list(modules))

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for key in selected:
        try:
            modules[key].run(fast=args.fast)
        except Exception as e:                     # noqa: BLE001
            failures.append((key, e))
            print(f"{key}_FAILED,0.0,{type(e).__name__}:{e}", flush=True)
    print(f"total_wall,{(time.time() - t0) * 1e6:.0f},"
          f"failures={len(failures)}")
    if failures:
        for key, e in failures:
            print(f"  {key}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
