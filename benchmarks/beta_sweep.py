"""Paper Fig. 5 — acceptance parameter beta sweep vs the beta=1 baseline
(Eq. 47 difference metric). The paper finds the optimum strictly inside
(0, 1): full acceptance is not always optimal, beta=0 is worst."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, train_run
from benchmarks.temperature import eq47_metric


def run(fast: bool = False):
    rounds = 10 if fast else 20
    reps = 2 if fast else 3
    betas = [0.0, 0.3, 0.5, 0.7, 0.8, 0.9, 1.0]

    base_curves = [train_run("wasgd", beta=1.0, rounds=rounds,
                             order_seed=300 + r)["losses"]
                   for r in range(reps)]

    results = {}
    for beta in betas:
        t0 = time.time()
        diffs = []
        for r in range(reps):
            res = train_run("wasgd", beta=beta, rounds=rounds,
                            order_seed=400 + r)
            diffs.append(eq47_metric(base_curves, res["losses"]))
        results[beta] = float(np.mean(diffs))
        emit(f"fig5_beta{beta}", (time.time() - t0) / reps / rounds * 1e6,
             f"eq47_vs_beta1={results[beta]:+.4f};err={np.std(diffs):.4f}")

    worst = min(results, key=results.get)
    emit("fig5_claim_beta0_is_worst", 0.0, f"holds={worst == 0.0}")
    best = max(results, key=results.get)
    emit("fig5_best_beta", 0.0, f"beta={best}")
    return results
