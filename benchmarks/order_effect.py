"""Paper Fig. 3 — order effect: runs of delta same-label samples.

Workers traverse the delta-grouped order SEQUENTIALLY (no reshuffling — that
is the experiment), and quality is measured by the loss over the FULL
dataset, not the recent (label-biased) batches. delta=1 (interleaved) should
beat delta=1000 (one label per communication period).
"""
from __future__ import annotations

import time

from benchmarks.common import dataset, emit, sequential_batches, train_custom
from repro.core.order import grouped_order


def run(fast: bool = False):
    X, y = dataset(0)
    deltas = [1, 10, 100, 1000]
    rounds = 10 if fast else 20
    results = {}
    for delta in deltas:
        order = grouped_order(y, delta, seed=0)
        Xo, yo = X[order], y[order]
        t0 = time.time()
        res = train_custom(
            "wasgd", sequential_batches(Xo, yo, 4, 8, 8), rounds,
            p=4, tau=8, eval_data=(X, y))
        results[delta] = res
        emit(f"fig3_order_delta{delta}",
             (time.time() - t0) / rounds * 1e6,
             f"full_loss={res['train_loss_full']:.4f};acc={res['acc']:.3f}")
    ok = results[1]["train_loss_full"] < results[1000]["train_loss_full"]
    emit("fig3_claim_delta1_beats_delta1000", 0.0, f"holds={ok}")
    return results
