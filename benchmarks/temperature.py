"""Paper Fig. 4 — weighting-strategy temperature sweep T = 1/a_tilde,
scored against the equally weighted baseline with the paper's Eq. 47
difference metric (positive = better than baseline).

Driven through the worker-assessment POLICY axis (core/weights.py): the
baseline is the ``"equal"`` policy and every temperature point is the
``"boltzmann(a=1/T)"`` policy spec — no raw ``a_tilde`` plumbing, so the
sweep exercises exactly the path ``WASGDConfig.policy`` users take.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, train_run


def eq47_metric(base_curves, cur_curve):
    """mean over records of (mean baseline value - current value)."""
    base = np.mean([c for c in base_curves], axis=0)
    n = min(len(base), len(cur_curve))
    return float(np.mean(base[:n] - cur_curve[:n]))


def run(fast: bool = False):
    rounds = 10 if fast else 20
    reps = 2 if fast else 3
    Ts = [0.01, 0.1, 1.0, 10.0, 100.0]

    base_curves = [train_run("wasgd", policy="equal", rounds=rounds,
                             seed=0, order_seed=100 + r)["losses"]
                   for r in range(reps)]

    results = {}
    for T in Ts:
        diffs = []
        t0 = time.time()
        for r in range(reps):
            res = train_run("wasgd", policy=f"boltzmann(a={1.0 / T})",
                            rounds=rounds, seed=0, order_seed=200 + r)
            diffs.append(eq47_metric(base_curves, res["losses"]))
        m, s = float(np.mean(diffs)), float(np.std(diffs))
        results[T] = m
        emit(f"fig4_T{T}", (time.time() - t0) / reps / rounds * 1e6,
             f"eq47_vs_equal={m:+.4f};err={s:.4f}")

    # Property 2: T->0 (a->inf) must underperform the equal baseline
    emit("fig4_claim_T0_worse_than_equal", 0.0,
         f"holds={results[0.01] <= max(results.values()) + 1e-9 and results[0.01] < 0.005}")
    return results
