"""Async WASGD+ (Alg. 4) vs synchronous (Alg. 1) under stragglers — the
paper's Sec. 3.5 decision rule, quantified: with high step-time variance the
async variant reaches the same loss in less simulated wall-clock; with
uniform step times the synchronous variant wins (no dropped work)."""
from __future__ import annotations

import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import dataset, emit, model
from repro.core.async_sim import StepTimeModel, run_parallel_sgd


def _setup(seed=0):
    X, y = dataset(seed)
    params, axes, loss_fn, apply_fn = model(seed)

    def grad_fn(params_stacked, batch):
        def one(p, b):
            return loss_fn(p, b)[0]
        losses = jax.vmap(one)(params_stacked, batch)
        grads = jax.grad(lambda ps: jax.vmap(one)(ps, batch).sum())(
            params_stacked)
        return losses, grads

    def batches(w, per_round):
        rng = np.random.default_rng(seed + 1)
        while True:
            idx = rng.integers(0, len(X), size=(w, per_round))
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, axes, loss_fn, jax.jit(grad_fn), batches


def run(fast: bool = False):
    rounds = 10 if fast else 20
    p, b, tau = 4, 2, 8
    params, axes, loss_fn, grad_fn, batches = _setup()

    for regime, tm_kw in [
        ("uniform", dict(sigma=0.05, straggle_p=0.0)),
        ("stragglers", dict(sigma=0.2, straggle_p=0.05, straggle_mult=20.0)),
    ]:
        res = {}
        for mode, sync in [("sync", True), ("async", False)]:
            t0 = time.time()
            tm = StepTimeModel(p + b, seed=3, **tm_kw)
            out = run_parallel_sgd(
                loss_fn, grad_fn, params, axes,
                batches(p + b, tau * 8), n_workers=p, backups=b, tau=tau,
                rounds=rounds, lr=0.05, time_model=tm, synchronous=sync)
            res[mode] = out
            emit(f"alg4_{regime}_{mode}",
                 (time.time() - t0) / rounds * 1e6,
                 f"sim_wall={out.wall:.1f};final_loss={out.losses[-1]:.4f};"
                 f"dropped={out.dropped_rounds}")
        speedup = res["sync"].wall / res["async"].wall
        emit(f"alg4_{regime}_async_speedup", 0.0, f"x{speedup:.2f}")
    emit("alg4_claim_async_wins_under_stragglers", 0.0,
         "holds=see speedup rows (sync~1x uniform, async>1x stragglers)")
