"""Kernel microbenchmarks: wagg / decode_attn / rmsnorm vs their pure-jnp
references (interpret mode on CPU — relative numbers are indicative only;
the BlockSpec tiling is the TPU deployment artifact)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.decode_attn import decode_attn, decode_attn_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm
from repro.kernels.wagg import wagg, wagg_ref

# Output artifacts anchored to the repo's results/ dir, not the process cwd
# — the auto-selector (core/backends.py:AUTO_BENCH_PATH) resolves the same
# absolute location, so a table recorded here is found from any cwd.
RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results")


def _time(fn, *args, n=20):
    # warmup: one call, fenced over the WHOLE output pytree (the old
    # tuple-special-case evaluated fn twice and fenced only element 0)
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run(fast: bool = False):
    key = jax.random.key(0)

    # wagg: a 16-worker 4M-element parameter block
    p, n = 16, 1 << 20 if fast else 1 << 22
    x = jax.random.normal(key, (p, n), jnp.float32)
    theta = jax.nn.softmax(jnp.arange(p, dtype=jnp.float32))
    f_kernel = jax.jit(lambda x, t: wagg(x, t, 0.9))
    f_ref = jax.jit(lambda x, t: wagg_ref(x, t, 0.9))
    emit("kernel_wagg_interp", _time(f_kernel, x, theta, n=5),
         f"shape={p}x{n}")
    emit("kernel_wagg_ref_xla", _time(f_ref, x, theta, n=5),
         f"shape={p}x{n}")

    # decode_attn: gemma-style kv=1 over a 8k cache
    b, kv, g, hd, S = 2, 1, 4, 128, 4096 if fast else 8192
    q = jax.random.normal(jax.random.fold_in(key, 10), (b, kv, g, hd),
                          jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kv, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, S, kv, hd))
    cl = jnp.int32(S)
    f_kernel = jax.jit(lambda q, k, v: decode_attn(q, k, v, cl))
    f_ref = jax.jit(lambda q, k, v: decode_attn_ref(q, k, v, cl))
    emit("kernel_decode_attn_interp", _time(f_kernel, q, kc, vc, n=5),
         f"cache={S}")
    emit("kernel_decode_attn_ref_xla", _time(f_ref, q, kc, vc, n=5),
         f"cache={S}")

    # rmsnorm over a (4096, 2048) activation
    rows = 1024 if fast else 4096
    x = jax.random.normal(jax.random.fold_in(key, 20), (rows, 2048),
                          jnp.bfloat16)
    s = jnp.ones((2048,), jnp.float32)
    f_kernel = jax.jit(lambda x, s: rmsnorm(x, s))
    f_ref = jax.jit(lambda x, s: rmsnorm_ref(x, s))
    emit("kernel_rmsnorm_interp", _time(f_kernel, x, s, n=5), f"rows={rows}")
    emit("kernel_rmsnorm_ref_xla", _time(f_ref, x, s, n=5), f"rows={rows}")

    run_extra(fast=fast)
    run_backends(fast=fast)
    run_backend_matrix(fast=fast)
    run_async(fast=fast)
    run_pipeline(fast=fast)
    run_policies(fast=fast)
    run_elastic(fast=fast)
    run_serve(fast=fast)


def run_backends(fast: bool = False):
    """Sweep every registered aggregation backend (core/backends.py) over a
    shared worker-stacked leaf — the apples-to-apples comparison the registry
    exists for. Interpret-mode/1-device numbers are indicative only."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.core.backends import (AggregationContext, available_backends,
                                     get_backend)

    p, n = 8, (1 << 18 if fast else 1 << 20)
    x = jax.random.normal(jax.random.key(2), (p, n), jnp.float32)
    theta = jax.nn.softmax(jnp.arange(p, dtype=jnp.float32))
    axes = {"w": ("worker", None)}
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    ctx = AggregationContext(mesh=mesh, comm_dtype=jnp.float32, n_pods=2)

    for name in available_backends():
        backend = get_backend(name)
        fn = jax.jit(lambda x, t, b=backend: b.aggregate(
            {"w": x}, axes, t, 0.9, ctx=ctx)["w"])
        # pallas interpret mode is orders slower: fewer reps, same protocol
        reps = 2 if name == "pallas_wagg" else 5
        emit(f"agg_backend_{name}", _time(fn, x, theta, n=reps),
             f"shape={p}x{n}")


def run_backend_matrix(fast: bool = False, out_path: str = None):
    """The two-axis sweep: every ``schedule x codec`` spec (plus the
    ``overlap=`` variant of multi-phase schedules) over a shared
    worker-stacked leaf, emitted as ``BENCH_backend_matrix.json`` — the
    table ``backend="auto"`` (core/backends.py:select_auto_spec) reads its
    measurements from. Interpret-mode / host-device numbers are indicative
    only; the record shape (spec, bytes, mesh size, us) is the artifact."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import backends as B

    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_backend_matrix.json")
    p, n = 8, (1 << 18 if fast else 1 << 20)
    x = jax.random.normal(jax.random.key(3), (p, n), jnp.float32)
    theta = jax.nn.softmax(jnp.arange(p, dtype=jnp.float32))
    axes = {"w": ("worker", None)}
    devs = jax.devices()
    mesh_devs = devs if p % len(devs) == 0 else devs[:1]
    mesh = Mesh(np.array(mesh_devs), ("data",))
    ctx = B.AggregationContext(mesh=mesh, n_pods=2)
    total_bytes = int(x.size * x.dtype.itemsize)

    records = []
    for spec in B.available_specs():
        sched, codec = spec.split(":")
        n_phases = getattr(B.get_backend(spec).schedule, "n_phases", 1)
        for overlap in ((False, True) if n_phases > 1 else (False,)):
            if overlap:
                # a small independent reduction riding between the phases;
                # the thunk's result must be RETURNED (and so blocked on) —
                # dropping it would let XLA dead-code-eliminate the thunk
                # and the row would time the non-overlap program.
                def fn(xx, t, s=spec):
                    out, extra = B.aggregate_with(
                        s, {"w": xx}, axes, t, 0.9, ctx=ctx,
                        overlap=lambda: (t * t).sum())
                    return out["w"], extra
                fn = jax.jit(fn)
            else:
                fn = jax.jit(lambda xx, t, s=spec: B.aggregate_with(
                    s, {"w": xx}, axes, t, 0.9, ctx=ctx)["w"])
            # pallas interpret mode is orders slower: fewer reps
            reps = 2 if sched == "pallas_wagg" else 5
            us = _time(fn, x, theta, n=reps)
            records.append({
                "spec": spec, "schedule": sched, "codec": codec,
                "overlap": overlap, "us_per_call": round(us, 1),
                "total_bytes": total_bytes, "workers": p,
                "mesh_devices": len(mesh_devs),
                "host_devices": len(devs)})
            emit(f"agg_matrix_{spec}{'+ov' if overlap else ''}", us,
                 f"shape={p}x{n}")

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": "backend_matrix", "records": records}, f,
                  indent=2)
    emit("backend_matrix_json", 0.0, out_path)
    return records


def run_async(fast: bool = False, out_path: str = None):
    """Alg. 4 round sweep: host-side event simulation vs the on-device
    ``async_*`` backends, same injected straggler schedule. Emits CSV rows
    AND writes ``BENCH_async.json`` so the async perf trajectory is recorded
    per-commit alongside the CSV artifact. Single-host numbers are
    indicative only (the collectives are trivial); the shape of the record —
    per-round wall time, final loss, dropped rounds — is the artifact.
    The on_device rows include one trace+compile (each driver call builds a
    fresh jitted round; ``includes_compile`` marks them in the JSON), so
    compare them against each other, not against the warmed host_sim row."""
    import functools
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import backends as B
    from repro.core.async_device import run_parallel_sgd_on_device

    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_async.json")
    from repro.core.async_sim import (StepTimeModel, make_schedule,
                                      run_parallel_sgd)
    from repro.data import make_classification
    from repro.models import cnn
    from repro.models.param import build

    p, b, tau = (2, 1, 2) if fast else (6, 2, 4)
    rounds = 4 if fast else 10
    w = p + b
    X, y = make_classification(0, 1024, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4), jax.random.key(0))

    def loss_fn(pp, bb):
        return cnn.classification_loss(cnn.mlp_apply(pp, bb["x"]),
                                       bb["y"]), {}

    def grad_fn(ps, batch):
        # NB: not named "one" — the record-keeping closures below reuse that
        # name, and shadowing a vmapped function confuses readers and tools.
        per_worker = lambda pp, bb: loss_fn(pp, bb)[0]
        losses = jax.vmap(per_worker)(ps, batch)
        grads = jax.grad(lambda q: jax.vmap(per_worker)(q, batch).sum())(ps)
        return losses, grads
    grad_fn = jax.jit(grad_fn)

    def batches():
        rng = np.random.default_rng(1)
        while True:
            idx = rng.integers(0, len(X), size=(w, tau * 8))
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    sched = make_schedule(
        StepTimeModel(w, sigma=0.3, straggle_p=0.1, straggle_mult=20,
                      seed=3),
        rounds=rounds, tau=tau, n_workers=p, backups=b)
    # worker dim must divide the mesh; fall back to 1 device otherwise
    devs = jax.devices()
    mesh_devs = devs if w % len(devs) == 0 else devs[:1]
    mesh = Mesh(np.array(mesh_devs), ("data",))

    records = []

    def one(mode, fn, warmup, includes_compile):
        if warmup:
            fn()
        t0 = time.time()
        out = fn()
        # fence the final worker-stacked params before stopping the clock —
        # the drivers return with device work still in flight
        jax.block_until_ready(out.params)
        us = (time.time() - t0) / rounds * 1e6
        records.append({"mode": mode, "us_per_round": round(us, 1),
                        "includes_compile": includes_compile,
                        "final_loss": float(out.losses[-1]),
                        "sim_wall": out.wall,
                        "dropped_rounds": out.dropped_rounds,
                        "workers": w, "backups": b, "tau": tau,
                        "rounds": rounds, "mesh_devices": len(mesh_devs),
                        "host_devices": len(jax.devices())})
        emit(f"async_round_{mode}", us,
             f"p{p}+b{b};final_loss={out.losses[-1]:.4f};"
             f"dropped={out.dropped_rounds}")

    # host_sim: warm grad_fn once so the timed pass is steady-state.
    one("host_sim", lambda: run_parallel_sgd(
        loss_fn, grad_fn, params, axes, batches(), n_workers=p, backups=b,
        tau=tau, rounds=rounds, lr=0.05, schedule=sched),
        warmup=True, includes_compile=False)
    for backend in ("async_einsum", "async_shard_map", "async_rs_ag"):
        # each driver call builds a fresh jitted round, so a warm-up pass
        # can't pre-compile it — skip the dead work and flag the record.
        one(f"on_device_{backend}", lambda be=backend: run_parallel_sgd_on_device(
            grad_fn, params, axes, batches(), n_workers=p, backups=b,
            tau=tau, rounds=rounds, lr=0.05, schedule=sched, backend=be,
            ctx=B.AggregationContext(mesh=mesh)),
            warmup=False, includes_compile=True)

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": "async_round", "records": records}, f, indent=2)
    emit("async_bench_json", 0.0, out_path)


def run_policies(fast: bool = False, out_path: str = None):
    """Worker-assessment policy x async-strategy sweep.

    Every representative policy spec of the third axis (core/weights.py)
    runs the same small Alg. 4 workload under each async execution
    strategy: ``host_sim`` (numpy event simulation), ``on_device``
    (schedule-driven jitted rounds) and ``on_device_measured`` (the mask
    derived from MEASURED per-device round times — no StepTimeModel).
    Emits CSV rows and ``BENCH_policy.json``: per-round walltime, final
    loss, dropped rounds per (policy, strategy). Single-host numbers are
    indicative only (the on_device rows include one trace+compile each,
    ``includes_compile`` marks them); the record shape is the artifact, and
    on a real mesh the policy column shows what an assessment choice costs
    per round.
    """
    import functools
    import numpy as np
    from repro.core.async_device import run_parallel_sgd_on_device
    from repro.core.async_sim import (StepTimeModel, make_schedule,
                                      run_parallel_sgd)
    from repro.data import make_classification
    from repro.models import cnn
    from repro.models.param import build

    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_policy.json")
    p, b, tau = (2, 1, 2) if fast else (4, 2, 4)
    rounds = 3 if fast else 8
    w = p + b
    X, y = make_classification(0, 1024, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4), jax.random.key(0))

    def loss_fn(pp, bb):
        return cnn.classification_loss(cnn.mlp_apply(pp, bb["x"]),
                                       bb["y"]), {}

    def grad_fn(ps, batch):
        # NB: not named "one" — the record-keeping closures below reuse that
        # name, and shadowing a vmapped function confuses readers and tools.
        per_worker = lambda pp, bb: loss_fn(pp, bb)[0]
        losses = jax.vmap(per_worker)(ps, batch)
        grads = jax.grad(lambda q: jax.vmap(per_worker)(q, batch).sum())(ps)
        return losses, grads
    grad_fn = jax.jit(grad_fn)

    def batches():
        rng = np.random.default_rng(1)
        while True:
            idx = rng.integers(0, len(X), size=(w, tau * 8))
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    sched = make_schedule(
        StepTimeModel(w, sigma=0.3, straggle_p=0.1, straggle_mult=20,
                      seed=3),
        rounds=rounds, tau=tau, n_workers=p, backups=b)

    policies = (["boltzmann", "ema(0.9)"] if fast else
                ["boltzmann", "inverse", "ema(0.9)", "trimmed(1)", "topk(2)",
                 "boltzmann(a=2)|anneal(cosine, period=8, peak=8)",
                 "ema(0.9)|time_aware"])

    records = []

    def one(policy, mode, fn, includes_compile):
        t0 = time.time()
        out = fn()
        # fence the final worker-stacked params before stopping the clock —
        # the drivers return with device work still in flight
        jax.block_until_ready(out.params)
        us = (time.time() - t0) / rounds * 1e6
        records.append({"policy": policy, "async_strategy": mode,
                        "us_per_round": round(us, 1),
                        "includes_compile": includes_compile,
                        "final_loss": float(out.losses[-1]),
                        "dropped_rounds": out.dropped_rounds,
                        "measured_times": out.round_times is not None,
                        "workers": w, "backups": b, "tau": tau,
                        "rounds": rounds,
                        "host_devices": len(jax.devices())})
        # spec strings may contain commas (anneal args); keep the CSV
        # name,us,derived contract intact — the JSON keeps the exact spec.
        label = policy.replace(" ", "").replace(",", ";")
        emit(f"policy_{label}_{mode}", us,
             f"p{p}+b{b};final_loss={out.losses[-1]:.4f}")

    for policy in policies:
        one(policy, "host_sim", lambda pol=policy: run_parallel_sgd(
            loss_fn, grad_fn, params, axes, batches(), n_workers=p,
            backups=b, tau=tau, rounds=rounds, lr=0.05, schedule=sched,
            policy=pol), includes_compile=False)
        one(policy, "on_device", lambda pol=policy: run_parallel_sgd_on_device(
            grad_fn, params, axes, batches(), n_workers=p, backups=b,
            tau=tau, rounds=rounds, lr=0.05, schedule=sched, policy=pol,
            backend="async_einsum"), includes_compile=True)
        one(policy, "on_device_measured",
            lambda pol=policy: run_parallel_sgd_on_device(
                grad_fn, params, axes, batches(), n_workers=p, backups=b,
                tau=tau, rounds=rounds, lr=0.05, measure_times=True,
                policy=pol, backend="async_einsum"), includes_compile=True)

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": "policy", "records": records}, f, indent=2)
    emit("policy_bench_json", 0.0, out_path)
    return records


def run_pipeline(fast: bool = False, out_path: str = None):
    """Pipelined vs unpipelined WASGD round walltime per aggregation spec.

    Builds the same smoke MLP round three ways per spec — unpipelined
    (``pipeline=None``), ``"parity"`` and ``"speculative"`` — drives each
    jitted step over steady-state rounds, and records the per-round
    walltime delta in ``BENCH_pipeline.json``. Host-device collectives are
    trivial, so single-host numbers are indicative only; the record shape
    (spec x pipeline mode x us_per_round) is the artifact, and on a real
    mesh the pipelined rows are where the seam hides the all-gather.
    """
    import functools
    import numpy as np
    from jax.sharding import Mesh
    from repro.configs.base import WASGDConfig
    from repro.core import replicate_workers
    from repro.data import make_classification
    from repro.data.pipeline import first_microbatch
    from repro.models import cnn
    from repro.models.param import build
    from repro.optim import make_optimizer
    from repro.train.state import init_state
    from repro.train.step import build_train_step, init_comm_state

    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_pipeline.json")
    p, tau, bl = (2, 2, 4) if fast else (4, 4, 8)
    rounds = 3 if fast else 10
    d_hidden = 32 if fast else 128
    X, y = make_classification(0, 2048, d=16, n_classes=4)
    params0, axes0 = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=d_hidden, n_classes=4),
        jax.random.key(0))
    params0, axes = replicate_workers(params0, axes0, p)

    def loss_fn(pp, bb):
        return cnn.classification_loss(cnn.mlp_apply(pp, bb["x"]),
                                       bb["y"]), {}

    devs = jax.devices()
    # shard the p worker copies over p real devices when the host has them
    # (the CI multidevice smoke forces 8) — collapsing to 1 device would
    # bench trivial collectives and record a meaningless pipelined delta.
    if len(devs) >= p:
        mesh_devs = devs[:p]
    elif p % len(devs) == 0:
        mesh_devs = devs
    else:
        mesh_devs = devs[:1]
    mesh = Mesh(np.array(mesh_devs), ("data",))
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(X), size=tau * p * bl)
    batch = {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}
    next_first = jax.device_put(first_microbatch(
        {"x": X[idx], "y": y[idx]}, p, tau))
    total_bytes = sum(int(np.asarray(v).nbytes) for v in batch.values())

    records = []
    for spec in ("einsum:f32", "rs_ag:f32", "rs_ag:bf16"):
        for mode in (None, "parity", "speculative"):
            wcfg = WASGDConfig(tau=tau, backend=spec)
            opt = make_optimizer("sgd", 0.05, 0.0, 0.0)
            step = build_train_step(loss_fn, opt, axes, wcfg, p,
                                    mesh=mesh, pipeline=mode)
            state = init_state(params0, opt.init(params0), p,
                               init_comm_state("wasgd", params0, axes, p,
                                               wcfg=wcfg))
            if mode is None:
                jstep = jax.jit(step)

                def drive(state):
                    for _ in range(rounds):
                        state, metrics = jstep(state, batch)
                    return state, metrics
            else:
                primer = jax.jit(step.primer)
                jstep = jax.jit(step)
                carry0 = primer(state.params, batch)

                def drive(state, carry0=carry0, jstep=jstep):
                    carry = carry0
                    for _ in range(rounds):
                        state, metrics, carry = jstep(state, batch,
                                                      next_first, carry)
                    return state, metrics

            # fence the WHOLE step output (state incl. opt/comm leaves and
            # metrics), not just params — the per-round metrics of the last
            # round are still in flight when params resolve
            jax.block_until_ready(drive(state))        # warmup + compile
            t0 = time.time()
            out = jax.block_until_ready(drive(state))
            us = (time.time() - t0) / rounds * 1e6
            out_state, metrics = out
            label = mode or "off"
            records.append({
                "spec": spec, "pipeline": label,
                "us_per_round": round(us, 1), "rounds": rounds,
                "workers": p, "tau": tau, "b_local": bl,
                "batch_bytes": total_bytes,
                "mesh_devices": len(mesh_devs),
                "host_devices": len(devs)})
            emit(f"pipeline_{spec}_{label}", us, f"p{p} tau{tau}")

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": "pipeline", "records": records}, f, indent=2)
    emit("pipeline_bench_json", 0.0, out_path)
    return records


def run_elastic(fast: bool = False, out_path: str = None):
    """Elastic membership + sharded checkpoint costs vs state bytes x p.

    For each worker count: time a grow (``p -> p+2``, newcomers adopt the
    aggregate) and a shrink (``p -> max(1, p-2)``) of a full worker-stacked
    ``TrainState`` through ``core/membership.resize_train_state``; then time
    a synchronous sharded save, its restore, and the CALLER-VISIBLE cost of
    the async save (the on-device snapshot + enqueue — the part that sits on
    the training critical path; the wait column is the hidden write riding
    the next rounds). Records land in ``BENCH_elastic.json``.
    """
    import functools
    import shutil
    import tempfile
    import numpy as np
    from repro.configs.base import WASGDConfig
    from repro.checkpoint.io import (AsyncCheckpointer, restore_sharded,
                                     save_sharded)
    from repro.core import replicate_workers
    from repro.core.membership import resize_train_state
    from repro.models import cnn
    from repro.models.param import build
    from repro.optim import make_optimizer
    from repro.train.state import init_state
    from repro.train.step import init_comm_state

    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_elastic.json")
    d_hidden = 64 if fast else 256
    ps = (2, 4) if fast else (2, 4, 8, 16)
    wcfg = WASGDConfig(tau=2, async_mode="on_device")
    params0, axes0 = build(functools.partial(
        cnn.mlp_init, d_in=32, d_hidden=d_hidden, n_classes=8),
        jax.random.key(0))
    opt = make_optimizer("adamw", 1e-3, 0.0, 0.01)

    records = []
    tmp = tempfile.mkdtemp(prefix="bench_elastic_")
    try:
        for p in ps:
            params, axes = replicate_workers(params0, axes0, p)
            state = init_state(params, opt.init(params), p,
                               init_comm_state("wasgd", params, axes, p,
                                               wcfg=wcfg))
            state_bytes = sum(int(np.asarray(x).nbytes)
                              for x in jax.tree.leaves(state))

            def grow(s=state, a=axes, p=p):
                return resize_train_state(s, a, p + 2)

            def shrink(s=state, a=axes, p=p):
                return resize_train_state(s, a, max(1, p - 2))

            # time the FULL resized TrainState (params + opt + comm leaves)
            # — fencing a single leaf stopped the clock with most of the
            # resize still in flight
            us_grow = _time(grow, n=5)
            us_shrink = _time(shrink, n=5)

            ck = os.path.join(tmp, f"p{p}")
            host = jax.tree.map(np.asarray, state)
            t0 = time.time()
            save_sharded(ck, host, topology={"p": p}, n_shards=2)
            us_save = (time.time() - t0) * 1e6
            t0 = time.time()
            restored, _ = restore_sharded(ck, state)
            jax.block_until_ready(restored)
            us_restore = (time.time() - t0) * 1e6

            ac = AsyncCheckpointer()
            t0 = time.time()
            ac.save(os.path.join(tmp, f"p{p}_async"), state,
                    topology={"p": p}, n_shards=2)
            us_async_call = (time.time() - t0) * 1e6
            t0 = time.time()
            ac.close()
            us_async_wait = (time.time() - t0) * 1e6

            records.append({
                "workers": p, "state_bytes": state_bytes,
                "us_resize_grow": round(us_grow, 1),
                "us_resize_shrink": round(us_shrink, 1),
                "us_save_sharded": round(us_save, 1),
                "us_restore_sharded": round(us_restore, 1),
                "us_async_save_call": round(us_async_call, 1),
                "us_async_save_wait": round(us_async_wait, 1)})
            emit(f"elastic_resize_grow_p{p}", us_grow,
                 f"{state_bytes >> 10}KiB")
            emit(f"elastic_ckpt_save_p{p}", us_save,
                 f"{state_bytes >> 10}KiB")
            emit(f"elastic_ckpt_async_call_p{p}", us_async_call,
                 f"hidden={round(us_async_wait, 1)}us")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": "elastic", "records": records}, f, indent=2)
    emit("elastic_bench_json", 0.0, out_path)
    return records


def run_serve(fast: bool = False, out_path: str = None):
    """Serving throughput: tokens/s vs batch size vs cache dtype vs engine.

    Two engines over the same smoke model (gemma3 — its sliding-window
    layers exercise the paged ring blocks): the legacy monolithic-cache
    Python token loop (``ServeEngine``, one jitted decode dispatch per
    token) and the continuous-batching paged engine (``ContinuousEngine``,
    the whole decode chunk is one jitted ``lax.while_loop``). Emits CSV rows
    and ``BENCH_serve.json``; paged rows carry ``speedup_vs_pyloop``. Both
    engines run with stop-token checking on (``eos_id=-1``, which never
    fires, so every request runs its full budget): the Python loop must
    read each token back to host to test it, while the while_loop's
    done-flags compile into the loop. That — plus attending only over
    block-table columns backed by reserved blocks, where the monolithic
    cache attends over its whole provisioned ``max_len`` — is the
    structural win; CPU numbers are indicative."""
    import dataclasses
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.data import lm_batch
    from repro.models import init_params
    from repro.serve import ContinuousEngine, ServeEngine

    if out_path is None:
        out_path = os.path.join(RESULTS_DIR, "BENCH_serve.json")
    cfg0 = get_smoke_config("gemma3-1b")
    params, _ = init_params(cfg0, jax.random.key(0))
    prompt_len, max_len = 8, 256
    n_new = 16 if fast else 128
    batches = (1, 8) if fast else (1, 2, 4, 8)
    dtypes = ([("bf16", jnp.bfloat16)] if fast else
              [("bf16", jnp.bfloat16), ("f32", jnp.float32)])

    records = []
    for dt_name, dt in dtypes:
        cfg = cfg0 if dt_name == "bf16" else dataclasses.replace(
            cfg0, compute_dtype="float32")
        for b in batches:
            prompts = np.asarray(
                lm_batch(b, b, prompt_len, cfg.vocab_size)["tokens"])

            legacy = ServeEngine(cfg, params, max_len=max_len,
                                 cache_dtype=dt)
            legacy.generate(prompts, n_new, eos_id=-1)   # compile
            t0 = time.time()
            legacy.generate(prompts, n_new, eos_id=-1)
            wall = time.time() - t0
            mono_tok_s = b * n_new / wall

            eng = ContinuousEngine(cfg, params, n_slots=b, max_len=max_len,
                                   block_size=16, cache_dtype=dt,
                                   chunk=n_new, eos_id=-1)
            eng.generate(prompts, n_new)                 # compile (same
            # token budget as the timed run: the paged engine buckets its
            # block-table width by blocks actually reserved)
            t0 = time.time()
            eng.generate(prompts, n_new)
            wall = time.time() - t0
            paged_tok_s = b * n_new / wall

            for engine, tok_s in (("monolithic_pyloop", mono_tok_s),
                                  ("paged_whileloop", paged_tok_s)):
                rec = {"arch": "gemma3-1b", "engine": engine, "batch": b,
                       "cache_dtype": dt_name, "n_new": n_new,
                       "prompt_len": prompt_len,
                       "tokens_per_s": round(tok_s, 1),
                       "us_per_token": round(1e6 / tok_s, 1)}
                if engine == "paged_whileloop":
                    rec["speedup_vs_pyloop"] = round(
                        paged_tok_s / mono_tok_s, 2)
                records.append(rec)
                emit(f"serve_{engine}_b{b}_{dt_name}", 1e6 / tok_s,
                     f"tok/s={tok_s:.0f}")

    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": "serve", "records": records}, f, indent=2)
    emit("serve_bench_json", 0.0, out_path)
    return records


def run_extra(fast: bool = False):
    """fused_ce + ssd_chunk microbenchmarks (appended kernels)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.fused_ce import fused_ce, fused_ce_ref
    from repro.kernels.ssd_chunk import ssd_chunk, ssd_chunk_ref

    key = jax.random.key(1)
    t, v = (1024, 32768) if fast else (2048, 65536)
    logits = jax.random.normal(key, (t, v), jnp.bfloat16)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (t,), 0, v)
    f_k = jax.jit(lambda l, y: fused_ce(l, y))
    f_r = jax.jit(lambda l, y: fused_ce_ref(l, y))
    emit("kernel_fused_ce_interp", _time(f_k, logits, labels, n=3),
         f"shape={t}x{v}")
    emit("kernel_fused_ce_ref_xla", _time(f_r, logits, labels, n=3),
         f"shape={t}x{v}")

    b, nc, L, nh, hd, ds = (1, 8, 64, 8, 64, 128) if fast else \
        (2, 16, 64, 16, 64, 128)
    xs = jax.random.normal(jax.random.fold_in(key, 6),
                           (b, nc, L, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2),
                                           (b, nc, L, nh)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (nh,)))
    B = jax.random.normal(jax.random.fold_in(key, 4), (b, nc, L, ds))
    C = jax.random.normal(jax.random.fold_in(key, 5), (b, nc, L, ds))
    f_k = jax.jit(lambda *t: ssd_chunk(*t)[0])
    f_r = jax.jit(lambda *t: ssd_chunk_ref(*t)[0])
    emit("kernel_ssd_chunk_interp", _time(f_k, xs, dt, a, B, C, n=3),
         f"b{b}xnc{nc}xL{L}xnh{nh}")
    emit("kernel_ssd_chunk_ref_xla", _time(f_r, xs, dt, a, B, C, n=3),
         f"b{b}xnc{nc}xL{L}xnh{nh}")


def main():
    """CLI: ``python -m benchmarks.kernel_bench [sweep] [--fast]`` — run one
    named sweep (``run_pipeline``, ``run_backend_matrix``, ...) or the whole
    module (the CI smoke uses ``run_pipeline --fast`` to keep
    ``BENCH_pipeline.json`` generatable)."""
    import argparse
    sweeps = {"run": run, "run_backends": run_backends,
              "run_backend_matrix": run_backend_matrix,
              "run_async": run_async, "run_pipeline": run_pipeline,
              "run_policies": run_policies, "run_extra": run_extra,
              "run_elastic": run_elastic, "run_serve": run_serve}
    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("sweep", nargs="?", default="run", choices=sorted(sweeps))
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    sweeps[args.sweep](fast=args.fast)


if __name__ == "__main__":
    main()
