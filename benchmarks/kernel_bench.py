"""Kernel microbenchmarks: wagg / decode_attn / rmsnorm vs their pure-jnp
references (interpret mode on CPU — relative numbers are indicative only;
the BlockSpec tiling is the TPU deployment artifact)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.decode_attn import decode_attn, decode_attn_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm
from repro.kernels.wagg import wagg, wagg_ref


def _time(fn, *args, n=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run(fast: bool = False):
    key = jax.random.key(0)

    # wagg: a 16-worker 4M-element parameter block
    p, n = 16, 1 << 20 if fast else 1 << 22
    x = jax.random.normal(key, (p, n), jnp.float32)
    theta = jax.nn.softmax(jnp.arange(p, dtype=jnp.float32))
    f_kernel = jax.jit(lambda x, t: wagg(x, t, 0.9))
    f_ref = jax.jit(lambda x, t: wagg_ref(x, t, 0.9))
    emit("kernel_wagg_interp", _time(f_kernel, x, theta, n=5),
         f"shape={p}x{n}")
    emit("kernel_wagg_ref_xla", _time(f_ref, x, theta, n=5),
         f"shape={p}x{n}")

    # decode_attn: gemma-style kv=1 over a 8k cache
    b, kv, g, hd, S = 2, 1, 4, 128, 4096 if fast else 8192
    q = jax.random.normal(key, (b, kv, g, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kv, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, S, kv, hd))
    cl = jnp.int32(S)
    f_kernel = jax.jit(lambda q, k, v: decode_attn(q, k, v, cl))
    f_ref = jax.jit(lambda q, k, v: decode_attn_ref(q, k, v, cl))
    emit("kernel_decode_attn_interp", _time(f_kernel, q, kc, vc, n=5),
         f"cache={S}")
    emit("kernel_decode_attn_ref_xla", _time(f_ref, q, kc, vc, n=5),
         f"cache={S}")

    # rmsnorm over a (4096, 2048) activation
    rows = 1024 if fast else 4096
    x = jax.random.normal(key, (rows, 2048), jnp.bfloat16)
    s = jnp.ones((2048,), jnp.float32)
    f_kernel = jax.jit(lambda x, s: rmsnorm(x, s))
    f_ref = jax.jit(lambda x, s: rmsnorm_ref(x, s))
    emit("kernel_rmsnorm_interp", _time(f_kernel, x, s, n=5), f"rows={rows}")
    emit("kernel_rmsnorm_ref_xla", _time(f_ref, x, s, n=5), f"rows={rows}")

    run_extra(fast=fast)
    run_backends(fast=fast)


def run_backends(fast: bool = False):
    """Sweep every registered aggregation backend (core/backends.py) over a
    shared worker-stacked leaf — the apples-to-apples comparison the registry
    exists for. Interpret-mode/1-device numbers are indicative only."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.core.backends import (AggregationContext, available_backends,
                                     get_backend)

    p, n = 8, (1 << 18 if fast else 1 << 20)
    x = jax.random.normal(jax.random.key(2), (p, n), jnp.float32)
    theta = jax.nn.softmax(jnp.arange(p, dtype=jnp.float32))
    axes = {"w": ("worker", None)}
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    ctx = AggregationContext(mesh=mesh, comm_dtype=jnp.float32, n_pods=2)

    for name in available_backends():
        backend = get_backend(name)
        fn = jax.jit(lambda x, t, b=backend: b.aggregate(
            {"w": x}, axes, t, 0.9, ctx=ctx)["w"])
        # pallas interpret mode is orders slower: fewer reps, same protocol
        reps = 2 if name == "pallas_wagg" else 5
        emit(f"agg_backend_{name}", _time(fn, x, theta, n=reps),
             f"shape={p}x{n}")


def run_extra(fast: bool = False):
    """fused_ce + ssd_chunk microbenchmarks (appended kernels)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.fused_ce import fused_ce, fused_ce_ref
    from repro.kernels.ssd_chunk import ssd_chunk, ssd_chunk_ref

    key = jax.random.key(1)
    t, v = (1024, 32768) if fast else (2048, 65536)
    logits = jax.random.normal(key, (t, v), jnp.bfloat16)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (t,), 0, v)
    f_k = jax.jit(lambda l, y: fused_ce(l, y))
    f_r = jax.jit(lambda l, y: fused_ce_ref(l, y))
    emit("kernel_fused_ce_interp", _time(f_k, logits, labels, n=3),
         f"shape={t}x{v}")
    emit("kernel_fused_ce_ref_xla", _time(f_r, logits, labels, n=3),
         f"shape={t}x{v}")

    b, nc, L, nh, hd, ds = (1, 8, 64, 8, 64, 128) if fast else \
        (2, 16, 64, 16, 64, 128)
    xs = jax.random.normal(key, (b, nc, L, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2),
                                           (b, nc, L, nh)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (nh,)))
    B = jax.random.normal(jax.random.fold_in(key, 4), (b, nc, L, ds))
    C = jax.random.normal(jax.random.fold_in(key, 5), (b, nc, L, ds))
    f_k = jax.jit(lambda *t: ssd_chunk(*t)[0])
    f_r = jax.jit(lambda *t: ssd_chunk_ref(*t)[0])
    emit("kernel_ssd_chunk_interp", _time(f_k, xs, dt, a, B, C, n=3),
         f"b{b}xnc{nc}xL{L}xnh{nh}")
    emit("kernel_ssd_chunk_ref_xla", _time(f_r, xs, dt, a, B, C, n=3),
         f"b{b}xnc{nc}xL{L}xnh{nh}")
