"""Beyond-paper algorithmic extensions (recorded separately from the
faithful repro, per the assignment):

* annealed Boltzmann temperature — the paper frames its weights via
  simulated annealing (Sec. 3.2) but keeps a_tilde fixed; we cool
  T = 1/a_tilde over rounds (equal-weight exploration -> best-worker
  exploitation) using the method's own machinery.
* sample-order search ablation — WASGD+ with vs without Judge/OrderGen.
* bf16 communication payload — numerically-equivalent-to-tolerance
  aggregation with half the ring bytes (also lowered in §Perf).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, model, train_custom, train_run
from repro.configs import TrainConfig, WASGDConfig
from repro.data import OrderedDataset
from repro.train import Trainer


def _run_cfg(wcfg: WASGDConfig, rounds: int, order: bool, seed=0):
    X, y = dataset(seed)
    params, axes, loss_fn, apply_fn = model(seed)
    tcfg = TrainConfig(learning_rate=0.05, wasgd=wcfg)
    ds = OrderedDataset({"x": X, "y": y}, 4, wcfg.tau, 8, n_segments=2,
                        seed=11)
    tr = Trainer(loss_fn, params, axes, tcfg, 4, rule="wasgd")
    tr.run(ds.batches(), rounds,
           order_state=ds.order if order else None,
           segment_fn=ds.segment_of_round if order else None)
    import jax.numpy as jnp
    from repro.core import take_worker
    from repro.models import cnn
    fp = take_worker(tr.state.params, tr.axes, 0)
    full = float(cnn.classification_loss(apply_fn(fp, jnp.asarray(X[:2048])),
                                         jnp.asarray(y[:2048])))
    return full, tr


def run(fast: bool = False):
    rounds = 12 if fast else 25
    reps = 2 if fast else 3

    # 1. temperature annealing
    for name, wcfg in [
        ("constant_T1", WASGDConfig(tau=8, a_tilde=1.0)),
        ("anneal_r0.2", WASGDConfig(tau=8, a_tilde=1.0, a_schedule="anneal",
                                    anneal_rate=0.2)),
        ("anneal_r1.0", WASGDConfig(tau=8, a_tilde=1.0, a_schedule="anneal",
                                    anneal_rate=1.0)),
    ]:
        t0 = time.time()
        losses = [_run_cfg(wcfg, rounds, order=True, seed=r)[0]
                  for r in range(reps)]
        emit(f"beyond_anneal_{name}", (time.time() - t0) / reps / rounds * 1e6,
             f"full_loss={np.mean(losses):.4f};std={np.std(losses):.4f}")

    # 2. order-search ablation
    for name, order in [("order_search_on", True), ("order_search_off", False)]:
        t0 = time.time()
        losses = [_run_cfg(WASGDConfig(tau=8, a_tilde=1.0), rounds, order,
                           seed=r)[0] for r in range(reps)]
        emit(f"beyond_{name}", (time.time() - t0) / reps / rounds * 1e6,
             f"full_loss={np.mean(losses):.4f};std={np.std(losses):.4f}")

    # 3. bf16 aggregation payload — accuracy parity check
    t0 = time.time()
    base = [_run_cfg(WASGDConfig(tau=8), rounds, True, seed=r)[0]
            for r in range(reps)]
    bf16 = [_run_cfg(WASGDConfig(tau=8, comm_dtype="bfloat16"), rounds, True,
                     seed=r)[0] for r in range(reps)]
    emit("beyond_bf16_comm", (time.time() - t0) / reps / rounds / 2 * 1e6,
         f"f32_loss={np.mean(base):.4f};bf16_loss={np.mean(bf16):.4f};"
         f"delta={np.mean(bf16) - np.mean(base):+.4f}")
