"""Paper Figs. 8-11 — convergence of WASGD+ against all six baselines
(SGD, SPSGD, EASGD, OMWU, MMWU, WASGD) at several worker counts."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, train_run

METHODS = [
    ("sgd", dict(rule="seq", order_search=False)),            # p=1 semantics
    ("spsgd", dict(rule="spsgd", order_search=False)),
    ("easgd", dict(rule="easgd", easgd_alpha=0.9 / 16, order_search=False)),
    ("omwu", dict(rule="omwu", order_search=False)),
    ("mmwu", dict(rule="mmwu", order_search=False)),
    ("wasgd", dict(rule="wasgd", strategy="inverse", beta=1.0,
                   order_search=False)),
    ("wasgd+", dict(rule="wasgd", strategy="boltzmann", beta=0.9,
                    a_tilde=1.0, order_search=True)),
]


def run(fast: bool = False):
    rounds = 12 if fast else 25
    results = {}
    for p in ([4] if fast else [4, 8]):
        for name, kw in METHODS:
            t0 = time.time()
            res = train_run(p=p, tau=8, b_local=8, rounds=rounds, **kw)
            results[(name, p)] = res
            emit(f"fig8_{name}_p{p}", (time.time() - t0) / rounds * 1e6,
                 f"final_loss={res['final_loss']:.4f};acc={res['acc']:.3f};"
                 f"train_loss={res['train_loss_full']:.4f}")

        ours = results[("wasgd+", p)]["final_loss"]
        beats = sum(results[(n, p)]["final_loss"] >= ours - 1e-9
                    for n, _ in METHODS if n != "wasgd+")
        emit(f"fig8_claim_wasgdplus_rank_p{p}", 0.0,
             f"beats={beats}/6_baselines")
        v1 = results[("wasgd", p)]["final_loss"]
        emit(f"fig8_claim_plus_improves_v1_p{p}", 0.0,
             f"holds={ours <= v1 + 1e-9}")
    return results
