"""Paper Fig. 6 — weight-estimation accuracy vs sample budget m (Eq. 27).

theta_true comes from the full training loss per worker (Eq. 20); theta_est
from the free m-sample recorder (Eq. 26). Error = sum_i |theta_i - theta*_i|
in [0, 2]; m=100 should match m=1000 while costing 10x less.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import dataset, emit, model
from repro.core import take_worker
from repro.core.energy import estimation_error
from repro.core.weights import boltzmann_weights
from repro.configs import TrainConfig, WASGDConfig
from repro.data import OrderedDataset
from repro.models import cnn
from repro.train import Trainer


def run(fast: bool = False):
    X, y = dataset(0)
    params, axes, loss_fn, apply_fn = model(0)
    p, tau, b_local = 4, 8, 8
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=tau))
    ds = OrderedDataset({"x": X, "y": y}, p, tau, b_local, n_segments=1)
    tr = Trainer(loss_fn, params, axes, tcfg, p)
    it = ds.batches()
    # a few warmup rounds so workers diverge
    for _ in range(3 if fast else 6):
        tr.state, metrics = tr._step(tr.state, next(it))

    # theta_true: full-dataset loss per worker (Eq. 20)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    h_true = []
    for w in range(p):
        pw = take_worker(tr.state.params, tr.axes, w)
        h_true.append(float(cnn.classification_loss(
            apply_fn(pw, Xj), yj)) * len(X))
    theta_true = boltzmann_weights(jnp.asarray(h_true), 1.0)

    rng = np.random.default_rng(0)
    results = {}
    for m in [1, 10, 100, 1000]:
        t0 = time.time()
        errs = []
        for rep in range(5):
            idx = rng.integers(0, len(X), size=m)
            h_est = []
            for w in range(p):
                pw = take_worker(tr.state.params, tr.axes, w)
                h_est.append(float(cnn.classification_loss(
                    apply_fn(pw, Xj[idx]), yj[idx])) * m)
            theta_est = boltzmann_weights(jnp.asarray(h_est), 1.0)
            errs.append(float(estimation_error(theta_est, theta_true)))
        results[m] = (float(np.mean(errs)), float(np.std(errs)))
        emit(f"fig6_m{m}", (time.time() - t0) / 5 * 1e6,
             f"error={results[m][0]:.4f};std={results[m][1]:.4f}")

    ok = (results[100][0] <= results[1][0] + 1e-9
          and results[100][1] <= results[1][1] + 1e-9)
    emit("fig6_claim_m100_beats_m1", 0.0, f"holds={ok}")
    return results
