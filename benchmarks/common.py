"""Shared benchmark harness: the paper's experimental setup at CPU scale.

Synthetic classification (standing in for MNIST/Fashion-MNIST — no network
access in this container) + the paper's MLP/CNN models, trained with any of
the seven methods of Sec. 5.2.2. Every benchmark module emits CSV rows via
``emit`` so ``python -m benchmarks.run`` produces one machine-readable
artifact per paper figure.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, WASGDConfig
from repro.data import OrderedDataset, make_classification, make_images
from repro.models import cnn
from repro.models.param import build
from repro.train import Trainer

N_TRAIN = 8192
D_FEAT = 64
N_CLASSES = 10


@functools.lru_cache(maxsize=4)
def dataset(seed: int = 0, images: bool = False):
    if images:
        X, y = make_images(seed, N_TRAIN, N_CLASSES)
    else:
        X, y = make_classification(seed, N_TRAIN, d=D_FEAT,
                                   n_classes=N_CLASSES, noise=0.25)
    return X, y


def model(seed: int = 0, images: bool = False):
    if images:
        params = cnn.init_cnn6(jax.random.key(seed), N_CLASSES)
        axes = jax.tree.map(lambda x: tuple(None for _ in x.shape), params)
        apply_fn = cnn.cnn6_apply
    else:
        params, axes = build(functools.partial(
            cnn.mlp_init, d_in=D_FEAT, d_hidden=128, n_classes=N_CLASSES),
            jax.random.key(seed))
        apply_fn = cnn.mlp_apply

    def loss_fn(p, batch):
        return cnn.classification_loss(apply_fn(p, batch["x"]),
                                       batch["y"]), {}

    return params, axes, loss_fn, apply_fn


def sequential_batches(X, y, p: int, tau: int, b_local: int):
    """Worker-major batches that PRESERVE the dataset's sample order (for the
    Fig. 3 order-effect experiment): worker w walks its contiguous shard of
    the given order cyclically, no reshuffling."""
    n = len(X)
    per_round = tau * b_local
    starts = [w * (n // p) for w in range(p)]
    r = 0
    while True:
        idx = np.empty((p, per_round), np.int64)
        for w in range(p):
            base = (starts[w] + r * per_round) % n
            idx[w] = (base + np.arange(per_round)) % n
        flat = idx.reshape(-1)
        yield {"x": X[flat], "y": y[flat]}
        r += 1


def train_custom(rule: str, batches, rounds: int, *, p: int = 4, tau: int = 8,
                 beta: float = 0.9, a_tilde: float = 1.0,
                 strategy: str = "boltzmann", policy: str = "",
                 lr: float = 0.05, seed: int = 0,
                 order_state=None, segment_fn=None, images: bool = False,
                 eval_data=None,
                 easgd_alpha: Optional[float] = None) -> Dict:
    params, axes, loss_fn, apply_fn = model(seed, images)
    tcfg = TrainConfig(
        learning_rate=lr, optimizer="sgd",
        wasgd=WASGDConfig(tau=tau, beta=beta, a_tilde=a_tilde,
                          strategy=strategy, policy=policy))
    tr = Trainer(loss_fn, params, axes, tcfg, p, rule=rule,
                 easgd_alpha=easgd_alpha)
    t0 = time.time()
    tr.run(batches, rounds, order_state=order_state, segment_fn=segment_fn)
    wall = time.time() - t0

    from repro.core import take_worker
    final_params = take_worker(tr.state.params, tr.axes, 0)
    Xe, ye = eval_data if eval_data is not None else dataset(seed, images)
    logits = apply_fn(final_params, jnp.asarray(Xe[:2048]))
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(ye[:2048])).mean())
    full_loss = float(cnn.classification_loss(logits, jnp.asarray(ye[:2048])))
    return {
        "losses": tr.losses(),
        "final_loss": float(np.mean(tr.losses()[-3:])),
        "train_loss_full": full_loss,
        "acc": acc,
        "wall": wall,
        "history": tr.history,
    }


def train_run(rule: str, *, p: int = 4, tau: int = 8, b_local: int = 8,
              rounds: int = 20, beta: float = 0.9, a_tilde: float = 1.0,
              strategy: str = "boltzmann", policy: str = "",
              lr: float = 0.05, seed: int = 0,
              order_search: bool = True, order_seed: int = 7,
              images: bool = False, dataset_override=None,
              easgd_alpha: Optional[float] = None) -> Dict:
    """One training run over the order-managed pipeline."""
    if dataset_override is not None:
        X, y = dataset_override
    else:
        X, y = dataset(seed, images)
    ds = OrderedDataset({"x": X, "y": y}, p, tau, b_local, n_segments=2,
                        seed=order_seed)
    return train_custom(
        rule, ds.batches(), rounds, p=p, tau=tau, beta=beta,
        a_tilde=a_tilde, strategy=strategy, policy=policy, lr=lr, seed=seed,
        order_state=ds.order if order_search else None,
        segment_fn=ds.segment_of_round if order_search else None,
        images=images, eval_data=(X, y), easgd_alpha=easgd_alpha)


_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    """The ``name,us_per_call,derived`` CSV contract of benchmarks.run."""
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def all_rows() -> List[str]:
    return list(_ROWS)
