"""§Roofline table generator: reads the dry-run JSONL artifacts and prints
the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck — the machine-readable version of EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS_GLOB = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun_*.jsonl")


def load_records():
    recs = {}
    for path in sorted(glob.glob(RESULTS_GLOB)):
        for line in open(path):
            r = json.loads(line)
            if r.get("variant", "baseline") != "baseline":
                continue                 # §Perf variants have their own table
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return recs


def run(fast: bool = False):
    recs = load_records()
    if not recs:
        emit("roofline_table", 0.0, "no dryrun artifacts yet — run "
             "python -m repro.launch.dryrun --all --out results/dryrun.jsonl")
        return
    ok = sum(r["ok"] for r in recs.values())
    emit("roofline_combinations", 0.0, f"ok={ok}/{len(recs)}")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if not r["ok"]:
            emit(f"roofline_{arch}_{shape}_{mesh}", 0.0,
                 f"FAILED:{r['error'][:60]}")
            continue
        rf = r["roofline"]
        emit(f"roofline_{arch}_{shape}_{mesh}",
             rf["compute_s"] * 1e6,
             f"mem_s={rf['memory_s']:.4f};coll_s={rf['collective_s']:.5f};"
             f"dom={rf['dominant'].replace('_s','')};"
             f"useful={r['useful_flops_frac']:.2f}")
