"""Repo tooling (static analysis, trace audits). Not shipped with the
``repro`` package — run from the repo root:

    python -m tools.reprolint src tests benchmarks
    python tools/trace_audit.py --fast
"""
