"""Summarize a telemetry JSONL run (repro.obs JsonlSink output).

Renders, from the typed events of one run:

* per-phase round breakdown (RoundTrace): mean / p50 / p95 per phase,
  host staging and fenced total;
* theta-entropy-over-rounds and worker-assessment stats
  (WorkerAssessment): the paper's Property 1 equal -> best annealing is
  the entropy trajectory printed here;
* serving latency percentiles (ServeSample): TTFT p50/p90/p99,
  inter-token latency, tokens/s, block-pool occupancy, queue depth;
* membership changes, checkpoint durations, hot-swap staleness.

    PYTHONPATH=src python tools/obs_report.py results/run.jsonl [--json]

``--json`` emits the summary as one JSON object (machine-readable; the
golden-output test pins this shape).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

_HERE = os.path.dirname(os.path.abspath(__file__))
if os.path.dirname(_HERE) not in sys.path:   # direct `python tools/...` run
    sys.path.insert(0, os.path.dirname(_HERE))

from tools.reprolint.registry import ensure_src_on_path

ensure_src_on_path()

import numpy as np                             # noqa: E402

from repro.obs import read_events              # noqa: E402


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _dist(xs: List[float]) -> Dict[str, float]:
    return {"mean": float(np.mean(xs)), "p50": _pct(xs, 50),
            "p95": _pct(xs, 95)}


def summarize(events: List) -> Dict:
    """The whole report as one plain dict (seconds everywhere)."""
    by = {}
    for e in events:
        by.setdefault(e.kind, []).append(e)
    out: Dict = {"n_events": len(events)}

    rounds = by.get("round_trace", [])
    if rounds:
        phase_names: List[str] = []
        for e in rounds:
            for nm in e.phases:
                if nm not in phase_names:
                    phase_names.append(nm)
        out["rounds"] = {
            "n": len(rounds),
            "detail": sorted({e.detail for e in rounds}),
            "total_s": _dist([e.total_s for e in rounds]),
            "host_staging_s": _dist([e.host_staging_s for e in rounds]),
            "phases": {nm: _dist([e.phases[nm] for e in rounds
                                  if nm in e.phases])
                       for nm in phase_names},
        }

    assess = by.get("worker_assessment", [])
    if assess:
        ent = [e.theta_entropy for e in assess]
        theta_max = [max(e.theta) for e in assess if e.theta]
        act = [sum(e.active) / len(e.active) for e in assess
               if e.active is not None]
        out["assessment"] = {
            "n": len(assess),
            "policy": sorted({e.policy for e in assess}),
            "theta_entropy": {"first": float(ent[0]), "last": float(ent[-1]),
                              "min": float(min(ent)),
                              "max": float(max(ent))},
            "top_worker_share": (_dist(theta_max) if theta_max else None),
            "active_fraction": (_dist(act) if act else None),
        }

    serve = by.get("serve_sample", [])
    if serve:
        ttft = [t for e in serve for t in e.ttft_s]
        e2e = [t for e in serve for t in e.e2e_s]
        tokens = sum(e.tokens for e in serve)
        chunk_s = sum(e.chunk_s for e in serve)
        out["serve"] = {
            "n_samples": len(serve),
            "tokens": tokens,
            "tokens_per_s": (tokens / chunk_s) if chunk_s else 0.0,
            "itl_s": _dist([e.itl_s for e in serve]),
            "ttft_s": ({"p50": _pct(ttft, 50), "p90": _pct(ttft, 90),
                        "p99": _pct(ttft, 99)} if ttft else None),
            "e2e_s": ({"p50": _pct(e2e, 50), "p90": _pct(e2e, 90)}
                      if e2e else None),
            "occupancy": _dist([e.occupancy for e in serve]),
            "queue_depth_max": max(e.queue_depth for e in serve),
            "admitted": sum(e.admitted for e in serve),
            "finished": sum(e.finished for e in serve),
        }

    member = by.get("membership_change", [])
    if member:
        out["membership"] = [
            {"round": e.round, "old_p": e.old_p, "new_p": e.new_p}
            for e in member]

    ckpt = by.get("checkpoint_save", [])
    if ckpt:
        out["checkpoints"] = {
            "n": len(ckpt),
            "duration_s": _dist([e.duration_s for e in ckpt]),
            "total_bytes": int(sum(e.nbytes for e in ckpt)),
        }

    swaps = by.get("hot_swap", [])
    if swaps:
        since = [e.rounds_since_last for e in swaps
                 if e.rounds_since_last is not None]
        out["hot_swaps"] = {
            "n": len(swaps),
            "mean_drift_l2": float(np.mean([e.param_drift_l2
                                            for e in swaps])),
            "mean_rounds_since_last": (float(np.mean(since)) if since
                                       else None),
            "tokens_under_prev": int(sum(e.tokens_under_prev
                                         for e in swaps)),
        }
    return out


def _ms(s: float) -> str:
    return f"{s * 1e3:9.3f} ms"


def render(summary: Dict) -> str:
    lines = [f"telemetry summary: {summary['n_events']} events"]
    r = summary.get("rounds")
    if r:
        lines.append(f"\nrounds: {r['n']}  (detail: "
                     f"{', '.join(r['detail'])})")
        lines.append(f"  {'phase':<14s} {'mean':>12s} {'p50':>12s} "
                     f"{'p95':>12s}")
        rows = [("host_staging", r["host_staging_s"])]
        rows += list(r["phases"].items())
        rows.append(("total", r["total_s"]))
        for nm, d in rows:
            lines.append(f"  {nm:<14s} {_ms(d['mean'])} {_ms(d['p50'])} "
                         f"{_ms(d['p95'])}")
    a = summary.get("assessment")
    if a:
        ent = a["theta_entropy"]
        lines.append(f"\nworker assessment: {a['n']} rounds  policy="
                     f"{', '.join(a['policy'])}")
        lines.append(f"  theta entropy: first={ent['first']:.4f} "
                     f"last={ent['last']:.4f} min={ent['min']:.4f} "
                     f"max={ent['max']:.4f}")
        if a.get("top_worker_share"):
            lines.append(f"  top worker share: "
                         f"mean={a['top_worker_share']['mean']:.4f}")
        if a.get("active_fraction"):
            lines.append(f"  active fraction (Alg. 4): "
                         f"mean={a['active_fraction']['mean']:.4f}")
    s = summary.get("serve")
    if s:
        lines.append(f"\nserve: {s['n_samples']} samples  "
                     f"{s['tokens']} tokens  "
                     f"{s['tokens_per_s']:.1f} tok/s  "
                     f"admitted={s['admitted']} finished={s['finished']}")
        if s.get("ttft_s"):
            t = s["ttft_s"]
            lines.append(f"  TTFT: p50={_ms(t['p50'])} p90={_ms(t['p90'])} "
                         f"p99={_ms(t['p99'])}")
        lines.append(f"  ITL: mean={_ms(s['itl_s']['mean'])} "
                     f"p95={_ms(s['itl_s']['p95'])}")
        lines.append(f"  occupancy: mean={s['occupancy']['mean']:.3f} "
                     f"queue depth max={s['queue_depth_max']}")
    m = summary.get("membership")
    if m:
        chg = ", ".join(f"r{e['round']}: {e['old_p']}->{e['new_p']}"
                        for e in m)
        lines.append(f"\nmembership changes: {len(m)}  ({chg})")
    c = summary.get("checkpoints")
    if c:
        lines.append(f"\ncheckpoints: {c['n']}  "
                     f"mean={c['duration_s']['mean']:.3f}s  "
                     f"{c['total_bytes'] / 1e6:.1f} MB total")
    h = summary.get("hot_swaps")
    if h:
        lines.append(f"\nhot swaps: {h['n']}  "
                     f"mean drift L2={h['mean_drift_l2']:.4f}  "
                     f"tokens under stale params="
                     f"{h['tokens_under_prev']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="telemetry JSONL file (JsonlSink output)")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as one JSON object")
    args = ap.parse_args(argv)
    events = list(read_events(args.path))
    if not events:
        print(f"no events in {args.path}", file=sys.stderr)
        return 1
    summary = summarize(events)
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
