"""Bridge from the linter to the LIVE repro registries.

SPEC001 validates every ``"schedule:codec"`` / policy-grammar string
literal in the tree against the registries as they exist *right now*
(``core.backends``/``core.codecs``/``core.weights``) — so a registry
rename cannot orphan a spec string in a test, a benchmark or a config
without the lint run going red. That requires importing the package at
lint time; ``load_bridge`` puts ``src/`` on ``sys.path`` relative to the
repo root so ``python -m tools.reprolint`` works from a bare checkout.

The tests construct a ``Bridge`` by hand (or around a temp registry entry)
to prove drift detection without touching the real registries.
"""
from __future__ import annotations

import dataclasses
import os
import sys
from typing import Callable, FrozenSet

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         os.pardir, os.pardir))


@dataclasses.dataclass(frozen=True)
class Bridge:
    schedules: FrozenSet[str]
    codecs: FrozenSet[str]
    backends: FrozenSet[str]            # aliases + monolithic registrations
    policies: FrozenSet[str]
    resolve_spec: Callable[[str], object]    # raises KeyError on unknown
    parse_policy: Callable[[str], object]    # raises ValueError on unknown

    def validate_backend_spec(self, s: str) -> str:
        """'' when ``s`` resolves, else the failure message."""
        try:
            self.resolve_spec(s)
            return ""
        except KeyError as e:
            return str(e).strip("'\"")

    def validate_policy_spec(self, s: str) -> str:
        try:
            self.parse_policy(s)
            return ""
        except (ValueError, TypeError) as e:
            return str(e)


def ensure_src_on_path():
    src = os.path.join(REPO_ROOT, "src")
    if os.path.isdir(src) and src not in sys.path:
        sys.path.insert(0, src)


def load_bridge() -> Bridge:
    """Import the live registries. Raises ImportError where repro (or jax)
    is genuinely unavailable — SPEC001 silently skipping would defeat the
    rule, so the CLI surfaces that as a hard error."""
    ensure_src_on_path()
    from repro.core import backends, codecs, weights
    return Bridge(
        schedules=frozenset(backends.available_schedules()),
        codecs=frozenset(codecs.available_codecs()),
        backends=frozenset(backends.available_backends()),
        policies=frozenset(weights.available_policies()),
        resolve_spec=backends.resolve_spec,
        parse_policy=weights.parse_policy,
    )
