"""CLI: ``python -m tools.reprolint [paths...]``.

Exit 0 on a clean tree, 1 on findings, 2 on environment failure (the live
registries would not import — SPEC001 cannot run, which is itself a
failure: silently skipping the registry check is how spec strings rot).
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from tools.reprolint import ALL_RULES, lint_paths, load_bridge, render


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="JAX-aware static analysis for this repo "
                    "(rule table: tools/reprolint/rules.py)")
    ap.add_argument("paths", nargs="*",
                    default=["src", "tests", "benchmarks"],
                    help="files or directories to lint "
                         "(default: src tests benchmarks)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run "
                         f"(default: all of {','.join(ALL_RULES)})")
    ap.add_argument("--no-registry", action="store_true",
                    help="skip importing the live registries "
                         "(disables SPEC001; for unit tests/offline runs)")
    ap.add_argument("--quiet", action="store_true",
                    help="findings only, no fix hints")
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = rules - set(ALL_RULES)
        if unknown:
            print(f"unknown rule id(s): {sorted(unknown)}", file=sys.stderr)
            return 2

    bridge = None
    if not args.no_registry and (rules is None or "SPEC001" in rules):
        try:
            bridge = load_bridge()
        except Exception as e:  # noqa: BLE001 - report any import failure
            print(f"reprolint: cannot import live registries for SPEC001: "
                  f"{type(e).__name__}: {e}", file=sys.stderr)
            print("(run with --no-registry to lint without SPEC001)",
                  file=sys.stderr)
            return 2

    findings = lint_paths(list(args.paths), bridge=bridge, rules=rules)
    out = render(findings, verbose_hints=not args.quiet)
    if out:
        print(out)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
