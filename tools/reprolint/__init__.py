"""reprolint — a JAX-aware static-analysis pass for this repo.

Mechanizes the bug classes PRs 1-8 kept fixing by hand; see ``rules.py``
for the rule table and ``README.md`` ("Static analysis") for usage.

    python -m tools.reprolint src tests benchmarks
"""
from __future__ import annotations

from typing import List, Optional, Set

from tools.reprolint.registry import Bridge, load_bridge
from tools.reprolint.report import Finding, render
from tools.reprolint.rules import ALL_RULES, lint_source
from tools.reprolint.walker import (SourceFile, iter_python_files,
                                    load_source)

__all__ = ["Bridge", "Finding", "ALL_RULES", "lint_text", "lint_paths",
           "load_bridge", "render"]


def lint_text(text: str, path: str = "<memory>",
              bridge: Optional[Bridge] = None,
              rules: Optional[Set[str]] = None) -> List[Finding]:
    """Lint a source string. ``bridge=None`` skips SPEC001 (tests inject a
    hand-built Bridge; the CLI always loads the live one)."""
    sf = load_source(path, text=text)
    assert sf is not None
    return lint_source(sf, bridge, rules)


def lint_paths(paths: List[str], bridge: Optional[Bridge] = None,
               rules: Optional[Set[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_python_files(paths):
        try:
            sf = load_source(path)
        except SyntaxError as e:
            findings.append(Finding("PARSE", path, e.lineno or 0,
                                    f"syntax error: {e.msg}"))
            continue
        if sf is None:
            findings.append(Finding("PARSE", path, 0, "unreadable file"))
            continue
        findings.extend(lint_source(sf, bridge, rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
