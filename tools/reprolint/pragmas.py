"""Per-line suppression pragmas.

The ONLY suppression mechanism is a pragma on the offending line itself —
there is no baseline file, so the tree must actually be clean:

    self._exc = e   # reprolint: allow=THR001 -- single-ref write is atomic
                    #   under the GIL; held and re-raised on the caller

Format: ``# reprolint: allow=RULE[,RULE...] -- <justification>``. The
justification is mandatory — a pragma without one is itself a finding
(PRAGMA001), so every suppression in the tree documents WHY the hazard is
intentional, not just that someone silenced it.

Placement: a trailing pragma suppresses its own physical line; a pragma on
a standalone comment line suppresses the next code line (so long
statements keep their justification readable above them).
"""
from __future__ import annotations

import re
import tokenize
import io
from typing import Dict, List, Set, Tuple

from tools.reprolint.report import Finding

_PRAGMA_RE = re.compile(
    r"#\s*reprolint:\s*allow\s*=\s*"
    r"(?P<rules>[A-Z0-9]+(?:\s*,\s*[A-Z0-9]+)*)"
    r"(?P<reason>\s*--\s*\S.*)?")

_ANY_PRAGMA_RE = re.compile(r"#\s*reprolint\b")


def collect(text: str, path: str) -> Tuple[Dict[int, Set[str]],
                                           List[Finding]]:
    """Scan ``text`` for suppression pragmas.

    Returns ``(allowed, findings)``: ``allowed[line]`` is the set of rule
    ids suppressed on that physical line; malformed or justification-free
    pragmas come back as PRAGMA001 findings. Pragmas are read from real
    comment tokens (not string literals), so a fixture string CONTAINING a
    pragma does not suppress anything in the file that holds it.
    """
    allowed: Dict[int, Set[str]] = {}
    findings: List[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return allowed, findings
    _trivial = {tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
                tokenize.INDENT, tokenize.DEDENT, tokenize.ENDMARKER}
    code_lines = sorted({t.start[0] for t in tokens
                         if t.type not in _trivial})
    for i, tok in enumerate(tokens):
        if tok.type != tokenize.COMMENT \
                or not _ANY_PRAGMA_RE.search(tok.string):
            continue
        line = tok.start[0]
        m = _PRAGMA_RE.search(tok.string)
        if m is None:
            findings.append(Finding(
                "PRAGMA001", path, line,
                f"unparsable reprolint pragma {tok.string.strip()!r}"))
            continue
        rules = {r.strip() for r in m.group("rules").split(",")}
        if not m.group("reason"):
            findings.append(Finding(
                "PRAGMA001", path, line,
                f"pragma suppressing {sorted(rules)} carries no "
                f"justification (append ' -- <why>')"))
            continue
        standalone = not any(t.start[0] == line and t.type not in _trivial
                             for t in tokens[:i])
        target = line
        if standalone:
            nxt = [ln for ln in code_lines if ln > line]
            if nxt:
                target = nxt[0]
        allowed.setdefault(target, set()).update(rules)
    return allowed, findings


def apply(findings: List[Finding], allowed: Dict[int, Set[str]]
          ) -> List[Finding]:
    """Drop findings whose (line, rule) is suppressed. PRAGMA001 itself is
    not suppressible — fixing the pragma is the only way out."""
    out = []
    for f in findings:
        if f.rule != "PRAGMA001" and f.rule in allowed.get(f.line, ()):
            continue
        out.append(f)
    return out
