"""The rule set — each rule is a bug class this repo actually shipped.

RNG001   key consume-before-split / multi-consume (PR 8: the legacy serve
         engine sampled from a key and THEN split it, correlating the
         first sampled token with the rest of the stream).
JIT001   host-sync constructs (``.item()``, ``.tolist()``, ``np.*``,
         ``print``, ``float()``/``int()`` on non-static values) inside
         functions reachable from a jit/shard_map/pallas/lax-control-flow
         trace site (per-module call graph).
PAL001   ``interpret=`` pinned to a literal in a Pallas entry point instead
         of derived from the backend (PR 7: ``wagg`` hardcoded
         ``interpret=True`` and silently ran interpret mode on TPUs).
SPEC001  ``"schedule:codec"`` / policy-grammar string literals that no
         longer resolve against the live registries (PR 1's class of
         silently-dropped config knobs, generalized to renames).
DT001    narrowing casts (f32 -> bf16/f16/int8/...) outside the codec and
         checkpoint layers (PR 6: ``restore`` silently cast every leaf).
THR001   attributes written from a worker-thread entry point — a
         ``threading.Thread`` target or a method handed to a
         ``concurrent.futures`` executor via ``.submit(self.m, ...)`` —
         and read from foreign-thread methods with no lock/event in the
         class (the ``RoundPrefetcher``/``AsyncCheckpointer``/
         ``JsonlSink`` hazard family).

Suppression is per-line pragma only (``tools/reprolint/pragmas.py``).
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from tools.reprolint.callgraph import ModuleGraph
from tools.reprolint.registry import Bridge
from tools.reprolint.report import Finding
from tools.reprolint.walker import SourceFile, _dotted

ALL_RULES = ("RNG001", "JIT001", "PAL001", "SPEC001", "DT001", "THR001",
             "PRAGMA001")


# ---------------------------------------------------------------------------
# RNG001 — key multi-consumption
# ---------------------------------------------------------------------------

# jax.random functions that DERIVE rather than consume: passing a key to
# these any number of times is the intended discipline.
_RNG_NON_CONSUMING = {"fold_in", "key_data", "wrap_key_data", "clone",
                      "key_impl"}
# value-producing jax.random calls whose result binds a fresh key
_RNG_CREATORS = {"key", "PRNGKey", "split", "fold_in", "clone",
                 "wrap_key_data"}
# parameter names treated as incoming keys (a helper that consumes its key
# parameter twice is the same bug one frame down)
_KEY_PARAM_RE = re.compile(r"^(key|rng|prng_key|[a-z0-9_]*_key)$")


def _jax_random_fn(call: ast.Call) -> Optional[str]:
    d = _dotted(call.func)
    if d is None:
        return None
    parts = d.split(".")
    if len(parts) >= 2 and parts[-2] in ("random", "jr"):
        return parts[-1]
    return None


class _RngScope:
    """Env maps name -> (consume_count, is_local). ``is_local`` keys were
    bound from a jax.random creation in this scope, so ANY call receiving
    them consumes; parameter-originated keys (``is_local=False``) only
    count jax.random consumptions — a stdlib ``random.Random`` parameter
    named ``rng`` reused across helper calls is not a JAX key hazard."""

    def __init__(self, sf: SourceFile, findings: List[Finding]):
        self.sf = sf
        self.findings = findings
        self._reported: Set[Tuple[int, str]] = set()

    # -- env helpers -------------------------------------------------------

    @staticmethod
    def _merge_max(into: Dict[str, Tuple[int, bool]],
                   *branches: Dict[str, Tuple[int, bool]]):
        names = set(into)
        for b in branches:
            names |= set(b)
        for n in names:
            vals = [b[n] for b in branches if n in b]
            if n in into:
                vals.append(into[n])
            if vals:
                into[n] = (max(v[0] for v in vals),
                           any(v[1] for v in vals))
        return into

    def _report(self, name: str, node: ast.AST):
        key = (node.lineno, name)
        if key in self._reported:
            return
        self._reported.add(key)
        self.findings.append(Finding(
            "RNG001", self.sf.path, node.lineno,
            f"PRNG key {name!r} consumed more than once (sampled/split "
            f"again without re-splitting or fold_in)"))

    # -- expression scan ---------------------------------------------------

    def _scan_expr(self, node: Optional[ast.AST],
                   env: Dict[str, Tuple[int, bool]]):
        if node is None:
            return
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            fn = _jax_random_fn(sub)
            if fn in _RNG_NON_CONSUMING:
                continue
            args = list(sub.args) + [kw.value for kw in sub.keywords]
            for a in args:
                if isinstance(a, ast.Name) and a.id in env:
                    count, is_local = env[a.id]
                    if not is_local and fn is None:
                        continue
                    env[a.id] = (count + 1, is_local)
                    if count + 1 >= 2:
                        self._report(a.id, sub)

    # -- binding -----------------------------------------------------------

    @staticmethod
    def _is_rng_creation(value: ast.AST) -> bool:
        if isinstance(value, ast.Call):
            fn = _jax_random_fn(value)
            return fn in _RNG_CREATORS
        if isinstance(value, ast.Subscript):
            return _RngScope._is_rng_creation(value.value)
        return False

    def _bind_target(self, target: ast.AST, creates: bool,
                     env: Dict[str, Tuple[int, bool]]):
        if isinstance(target, ast.Name):
            if creates:
                env[target.id] = (0, True)
            else:
                env.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind_target(el, creates, env)

    # -- statements --------------------------------------------------------

    def scan_stmts(self, stmts: List[ast.stmt], env: Dict[str, int]):
        for s in stmts:
            self.scan_stmt(s, env)

    def scan_stmt(self, s: ast.stmt, env: Dict[str, int]):
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            return                       # nested scopes analyzed separately
        if isinstance(s, ast.Assign):
            self._scan_expr(s.value, env)
            creates = self._is_rng_creation(s.value)
            for t in s.targets:
                self._bind_target(t, creates, env)
        elif isinstance(s, (ast.AugAssign, ast.AnnAssign)):
            self._scan_expr(getattr(s, "value", None), env)
            if isinstance(s, ast.AnnAssign) and s.value is not None:
                self._bind_target(s.target,
                                  self._is_rng_creation(s.value), env)
        elif isinstance(s, ast.If):
            self._scan_expr(s.test, env)
            b1, b2 = dict(env), dict(env)
            self.scan_stmts(s.body, b1)
            self.scan_stmts(s.orelse, b2)
            env.clear()
            self._merge_max(env, b1, b2)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self._scan_expr(s.iter, env)
            self._bind_target(s.target, False, env)
            # two symbolic iterations: a key bound OUTSIDE the loop and
            # consumed once per iteration without rebinding crosses 2.
            self.scan_stmts(s.body, env)
            self.scan_stmts(s.body, env)
            self.scan_stmts(s.orelse, env)
        elif isinstance(s, ast.While):
            self._scan_expr(s.test, env)
            self.scan_stmts(s.body, env)
            self.scan_stmts(s.body, env)
            self.scan_stmts(s.orelse, env)
        elif isinstance(s, (ast.Try, getattr(ast, "TryStar", ast.Try))):
            body_env = dict(env)
            self.scan_stmts(s.body, body_env)
            self.scan_stmts(s.orelse, body_env)
            handler_envs = []
            for h in s.handlers:
                he = dict(env)
                self.scan_stmts(h.body, he)
                handler_envs.append(he)
            env.clear()
            self._merge_max(env, body_env, *handler_envs)
            self.scan_stmts(s.finalbody, env)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                self._scan_expr(item.context_expr, env)
            self.scan_stmts(s.body, env)
        elif isinstance(s, ast.Delete):
            for t in s.targets:
                self._bind_target(t, False, env)
        else:
            for child in ast.iter_child_nodes(s):
                if isinstance(child, ast.expr):
                    self._scan_expr(child, env)


def rng001(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    scopes: List[Tuple[List[ast.stmt], Dict[str, Tuple[int, bool]]]] = []
    scopes.append((sf.tree.body, {}))
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            env: Dict[str, Tuple[int, bool]] = {}
            a = node.args
            for p in (a.posonlyargs + a.args + a.kwonlyargs):
                if _KEY_PARAM_RE.match(p.arg):
                    env[p.arg] = (0, False)
            scopes.append((node.body, env))
    for body, env in scopes:
        _RngScope(sf, findings).scan_stmts(body, env)
    return findings


# ---------------------------------------------------------------------------
# JIT001 — host-sync constructs in traced functions
# ---------------------------------------------------------------------------

def _walk_own_body(fn_node: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body, excluding nested function/class defs (they are
    their own call-graph nodes)."""
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


def _static_argnames(fn_node: ast.AST) -> Set[str]:
    """Names declared static in a jit decorator on this def — ``float(x)``
    on a static arg is host work on a Python scalar, not a traced sync."""
    out: Set[str] = set()
    for dec in getattr(fn_node, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                if isinstance(kw.value, (ast.Tuple, ast.List)):
                    for el in kw.value.elts:
                        if isinstance(el, ast.Constant) \
                                and isinstance(el.value, str):
                            out.add(el.value)
                elif isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, str):
                    out.add(kw.value.value)
    return out


_HOST_SYNC_METHODS = {"item", "tolist"}
_HOST_CAST_BUILTINS = {"float", "int", "bool"}


def jit001(sf: SourceFile, graph: ModuleGraph) -> List[Finding]:
    findings: List[Finding] = []
    for info in graph.traced_functions():
        statics = _static_argnames(info.node)
        for node in _walk_own_body(info.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in _HOST_SYNC_METHODS:
                findings.append(Finding(
                    "JIT001", sf.path, node.lineno,
                    f".{f.attr}() in {info.qualname!r}, which is reachable "
                    f"from a jit/trace site — device->host sync"))
                continue
            d = _dotted(f)
            if d is not None and d.split(".")[0] in sf.numpy_aliases:
                findings.append(Finding(
                    "JIT001", sf.path, node.lineno,
                    f"{d}(...) in traced function {info.qualname!r} — "
                    f"numpy runs on the host (trace-time work or a forced "
                    f"transfer)"))
                continue
            if isinstance(f, ast.Name) and f.id == "print":
                findings.append(Finding(
                    "JIT001", sf.path, node.lineno,
                    f"print() in traced function {info.qualname!r} — "
                    f"executes at trace time only (use jax.debug.print)"))
                continue
            if isinstance(f, ast.Name) and f.id in _HOST_CAST_BUILTINS \
                    and node.args:
                a = node.args[0]
                if isinstance(a, ast.Constant):
                    continue
                if isinstance(a, ast.Name) and a.id in statics:
                    continue
                findings.append(Finding(
                    "JIT001", sf.path, node.lineno,
                    f"{f.id}(...) on a non-static value in traced function "
                    f"{info.qualname!r} — forces concretization "
                    f"(device->host sync under jit)"))
    return findings


# ---------------------------------------------------------------------------
# PAL001 — hardcoded interpret= in Pallas entry points
# ---------------------------------------------------------------------------

def pal001(sf: SourceFile) -> List[Finding]:
    if not sf.imports_pallas:
        return []
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            pos = a.posonlyargs + a.args
            defaults = [None] * (len(pos) - len(a.defaults)) \
                + list(a.defaults)
            pairs = list(zip(pos, defaults)) \
                + list(zip(a.kwonlyargs, a.kw_defaults))
            for arg, default in pairs:
                if arg.arg == "interpret" \
                        and isinstance(default, ast.Constant) \
                        and isinstance(default.value, bool):
                    findings.append(Finding(
                        "PAL001", sf.path, node.lineno,
                        f"{node.name!r} defaults interpret="
                        f"{default.value} — hardcoded literal instead of "
                        f"backend-derived (default None, resolve via "
                        f"jax.default_backend())"))
        elif isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d is None or d.rsplit(".", 1)[-1] != "pallas_call":
                continue
            for kw in node.keywords:
                if kw.arg == "interpret" \
                        and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, bool):
                    findings.append(Finding(
                        "PAL001", sf.path, node.lineno,
                        f"pallas_call(interpret={kw.value.value}) — "
                        f"hardcoded literal instead of backend-derived"))
    return findings


# ---------------------------------------------------------------------------
# SPEC001 — registry-validated spec strings
# ---------------------------------------------------------------------------

_SPEC_RE = re.compile(r"^[A-Za-z_]\w*:[A-Za-z_]\w*$")
_POLICY_SEG_RE = re.compile(r"^[A-Za-z_]\w*(\(.*\))?$")
_POLICY_NAME_RE = re.compile(r"^[A-Za-z_]\w*")


def spec001(sf: SourceFile, bridge: Bridge) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Constant) \
                or not isinstance(node.value, str) \
                or id(node) in sf.docstrings:
            continue
        s = node.value
        if not s or len(s) > 80:
            continue
        if _SPEC_RE.match(s):
            sched, codec = s.split(":", 1)
            # Only strings ANCHORED to a registry are spec candidates: a
            # registered schedule on the left, or a registered codec on the
            # right ("file:line"-shaped strings never anchor). Anchored but
            # unresolvable = a rename/typo orphaned it.
            if sched in bridge.schedules or sched in bridge.backends \
                    or codec in bridge.codecs:
                msg = bridge.validate_backend_spec(s)
                if msg:
                    findings.append(Finding(
                        "SPEC001", sf.path, node.lineno,
                        f"spec string {s!r} does not resolve: {msg}"))
        else:
            parts = [p.strip() for p in s.split("|")]
            looks_grammar = ("|" in s and all(
                p and _POLICY_SEG_RE.match(p) for p in parts)) \
                or (len(parts) == 1 and "(" in s
                    and _POLICY_SEG_RE.match(parts[0]) is not None)
            if not looks_grammar:
                continue
            names = {m.group(0) for m in
                     (_POLICY_NAME_RE.match(p) for p in parts if p) if m}
            if not (names & bridge.policies):
                continue
            msg = bridge.validate_policy_spec(s)
            if msg:
                findings.append(Finding(
                    "SPEC001", sf.path, node.lineno,
                    f"policy spec {s!r} does not parse against the live "
                    f"registry: {msg}"))
    return findings


# ---------------------------------------------------------------------------
# DT001 — narrowing casts outside codec/checkpoint modules
# ---------------------------------------------------------------------------

_NARROW_DTYPES = {"bfloat16", "float16", "int8", "int4", "uint8",
                  "float8_e4m3fn", "float8_e5m2"}


def _dt001_exempt(path: str) -> bool:
    norm = path.replace(os.sep, "/")
    return norm.endswith("/codecs.py") or "/checkpoint/" in norm


def dt001(sf: SourceFile) -> List[Finding]:
    if _dt001_exempt(sf.path):
        return []
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call) \
                or not isinstance(node.func, ast.Attribute) \
                or node.func.attr != "astype":
            continue
        targets = list(node.args[:1]) + [kw.value for kw in node.keywords
                                         if kw.arg == "dtype"]
        for t in targets:
            name = None
            if isinstance(t, ast.Attribute):
                name = t.attr
            elif isinstance(t, ast.Constant) and isinstance(t.value, str):
                name = t.value
            if name in _NARROW_DTYPES:
                findings.append(Finding(
                    "DT001", sf.path, node.lineno,
                    f".astype({name}) — narrowing cast outside the codec/"
                    f"checkpoint layers loses precision silently"))
    return findings


# ---------------------------------------------------------------------------
# THR001 — unsynchronized cross-thread attribute traffic
# ---------------------------------------------------------------------------

_SYNC_PRIMITIVES = {"Lock", "RLock", "Condition", "Event", "Semaphore",
                    "BoundedSemaphore", "Barrier"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def thr001(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for cls in (n for n in ast.walk(sf.tree) if isinstance(n, ast.ClassDef)):
        methods: Dict[str, ast.AST] = {
            m.name: m for m in cls.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
        thread_targets: Set[str] = set()
        has_sync = False
        for node in ast.walk(cls):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            last = d.rsplit(".", 1)[-1] if d else ""
            if last == "Thread":
                for kw in node.keywords:
                    if kw.arg == "target":
                        attr = _self_attr(kw.value)
                        if attr and attr in methods:
                            thread_targets.add(attr)
            elif last == "submit" and node.args:
                # concurrent.futures executors run the submitted callable
                # on a pool thread: pool.submit(self.m, ...) makes self.m a
                # worker-side entry point exactly like Thread(target=...)
                attr = _self_attr(node.args[0])
                if attr and attr in methods:
                    thread_targets.add(attr)
            elif last in _SYNC_PRIMITIVES:
                has_sync = True
        if not thread_targets or has_sync:
            continue
        # transitive closure of worker-side methods via self.m() calls
        worker = set(thread_targets)
        frontier = list(thread_targets)
        while frontier:
            m = frontier.pop()
            for node in ast.walk(methods[m]):
                if isinstance(node, ast.Call):
                    attr = _self_attr(node.func)
                    if attr in methods and attr not in worker:
                        worker.add(attr)
                        frontier.append(attr)
        writes: Dict[str, int] = {}
        for m in worker:
            for node in ast.walk(methods[m]):
                tgts = []
                if isinstance(node, ast.Assign):
                    tgts = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    tgts = [node.target]
                for t in tgts:
                    elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                        else [t]
                    for el in elts:
                        attr = _self_attr(el)
                        if attr:
                            writes.setdefault(attr, node.lineno)
        if not writes:
            continue
        readers: Dict[str, Set[str]] = {}
        for name, m in methods.items():
            if name in worker:
                continue
            for node in ast.walk(m):
                attr = _self_attr(node)
                if attr in writes and isinstance(node.ctx, ast.Load):
                    readers.setdefault(attr, set()).add(name)
        for attr, who in sorted(readers.items()):
            findings.append(Finding(
                "THR001", sf.path, writes[attr],
                f"self.{attr} is written from thread target(s) "
                f"{sorted(thread_targets)} and read from "
                f"{sorted(who)} with no Lock/Event in class "
                f"{cls.name!r}"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_source(sf: SourceFile, bridge: Optional[Bridge],
                rules: Optional[Set[str]] = None) -> List[Finding]:
    from tools.reprolint import pragmas
    graph = ModuleGraph(sf.tree)
    findings: List[Finding] = []
    table = {
        "RNG001": lambda: rng001(sf),
        "JIT001": lambda: jit001(sf, graph),
        "PAL001": lambda: pal001(sf),
        "SPEC001": (lambda: spec001(sf, bridge)) if bridge else lambda: [],
        "DT001": lambda: dt001(sf),
        "THR001": lambda: thr001(sf),
    }
    for rule, fn in table.items():
        if rules is None or rule in rules:
            findings.extend(fn())
    if rules is None or "PRAGMA001" in rules:
        findings.extend(sf.pragma_findings)
    return pragmas.apply(findings, sf.allowed)
