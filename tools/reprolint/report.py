"""Finding record + per-rule fix hints.

Every rule in ``tools/reprolint/rules.py`` was distilled from a bug this
repo actually shipped and later fixed (the PR that fixed it is named in the
hint); a finding is therefore never style — it is "this shape has broken
this codebase before".
"""
from __future__ import annotations

import dataclasses
from typing import List


# One-line fix hints, keyed by rule id. Kept here (not in the rule bodies)
# so the CLI, the README table and the tests share a single source.
HINTS = {
    "RNG001": "derive a fresh key per consumer: key, sub = "
              "jax.random.split(key) BEFORE the first sample, or "
              "jax.random.fold_in(key, step) per use (PR 8's legacy-engine "
              "consume-then-split bug)",
    "JIT001": "host-sync construct in a jit/shard_map/pallas-reachable "
              "function: move it outside the traced region, or use jnp/"
              "lax equivalents (.item()/np.*/print force a device sync or "
              "bake host work into the trace)",
    "PAL001": "derive interpret from the backend at call time "
              "(interpret=None + jax.default_backend() != 'tpu'), never a "
              "hardcoded literal (PR 7: wagg silently pinned TPU callers "
              "to interpret mode)",
    "SPEC001": "spec string does not resolve against the live registries "
               "(core.backends/core.codecs/core.weights) — a registry "
               "rename orphaned it, or it carries a typo",
    "DT001": "narrowing cast (f32 -> bf16/f16/int8) outside the codec/"
             "checkpoint layers: route through a PayloadCodec, or mark it "
             "intentional with '# reprolint: allow=DT001 -- <why>' (PR 6: "
             "restore() silently cast every leaf)",
    "THR001": "attribute written by a background-thread method and read "
              "from foreign-thread methods with no Lock/Event in the "
              "class: guard it, or justify the lock-free design with a "
              "pragma",
    "PRAGMA001": "suppression pragmas must carry a justification: "
                 "'# reprolint: allow=<RULE> -- <why this is intentional>'",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    @property
    def hint(self) -> str:
        return HINTS.get(self.rule, "")

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def render(findings: List[Finding], verbose_hints: bool = True) -> str:
    out = []
    seen_rules = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        out.append(f.format())
        if f.rule not in seen_rules:
            seen_rules.append(f.rule)
    if verbose_hints and findings:
        out.append("")
        for r in seen_rules:
            if HINTS.get(r):
                out.append(f"  {r}: {HINTS[r]}")
    return "\n".join(out)
