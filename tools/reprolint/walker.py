"""File discovery + parsed-source container.

A ``SourceFile`` bundles everything a rule needs: path, text, AST, the
per-line pragma table, and small shared lookups (import aliases, docstring
node ids) so each rule does not re-derive them.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterator, List, Optional, Set

from tools.reprolint import pragmas
from tools.reprolint.report import Finding

SKIP_DIRS = {".git", "__pycache__", ".github", "results", "node_modules",
             ".claude"}


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c" for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class SourceFile:
    path: str
    text: str
    tree: ast.Module
    allowed: Dict[int, Set[str]]            # line -> suppressed rule ids
    pragma_findings: List[Finding]

    def __post_init__(self):
        self.numpy_aliases: Set[str] = set()
        self.imports_pallas = False
        self._collect_imports()
        self.docstrings: Set[int] = set()   # id()s of docstring Constant nodes
        self._collect_docstrings()

    def _collect_imports(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        self.numpy_aliases.add(a.asname or "numpy")
                    if a.name.startswith("jax.experimental.pallas"):
                        self.imports_pallas = True
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod == "jax.experimental" and any(
                        a.name == "pallas" for a in node.names):
                    self.imports_pallas = True
                if mod.startswith("jax.experimental.pallas"):
                    self.imports_pallas = True

    def _collect_docstrings(self):
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                body = node.body
                if body and isinstance(body[0], ast.Expr) \
                        and isinstance(body[0].value, ast.Constant) \
                        and isinstance(body[0].value.value, str):
                    self.docstrings.add(id(body[0].value))


def load_source(path: str, text: Optional[str] = None
                ) -> Optional[SourceFile]:
    """Parse one file into a ``SourceFile``; None on read failure (a parse
    failure still returns, carrying the syntax error as a finding via
    ``tree=None`` is NOT done — unparsable files are reported by lint())."""
    if text is None:
        try:
            with open(path, encoding="utf-8") as f:
                text = f.read()
        except OSError:
            return None
    tree = ast.parse(text, filename=path)
    allowed, pfinds = pragmas.collect(text, path)
    return SourceFile(path=path, text=text, tree=tree, allowed=allowed,
                      pragma_findings=pfinds)


def iter_python_files(paths: List[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in SKIP_DIRS)
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)
