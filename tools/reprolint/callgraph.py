"""Per-module call graph rooted at jit/shard_map/pallas trace sites.

JIT001 needs to know which functions' bodies end up inside a traced
program. XLA cannot check this statically and the failure is silent (a
``print`` traces to nothing, an ``np.*`` call bakes trace-time host work
into a hot path, ``.item()`` forces a device sync per call) — so we build,
per module, the set of locally-defined functions reachable from any
trace-inducing call site:

* decorators: ``@jax.jit``, ``@functools.partial(jax.jit, ...)``, ``@pjit``
* call sites: ``jax.jit(f)``, ``shard_map(f, ...)``, ``pl.pallas_call(k)``,
  ``jax.lax.{scan,while_loop,fori_loop,cond,switch}``, ``jax.grad`` /
  ``value_and_grad`` / ``vmap`` / ``checkpoint`` / ``remat`` — anything
  that traces its function argument.
* edges: bare-name calls to module-local functions, and ``self.m()`` calls
  to same-class methods.

Cross-module reachability is deliberately out of scope (the issue scopes
the graph per module); a function jitted by ANOTHER module is that
module's entry and gets scanned when the jit site's module is linted only
if locally resolvable.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from tools.reprolint.walker import _dotted

# Call-site wrappers that trace their function-valued arguments. Keyed by
# the LAST dotted component, so ``jax.jit``, ``jax.lax.scan``,
# ``pl.pallas_call`` and bare ``shard_map`` all match. Values: which
# positional args are (or contain) traced functions; None = all args.
TRACING_WRAPPERS: Dict[str, Optional[Tuple[int, ...]]] = {
    "jit": (0,),
    "pjit": (0,),
    "pmap": (0,),
    "vmap": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
    "checkpoint": (0,),
    "remat": (0,),
    "shard_map": (0,),
    "pallas_call": (0,),
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": None,          # every function-valued operand traces
    "switch": None,
}

# decorator heads that mean "this def is traced"
_JIT_DECORATOR_HEADS = {"jit", "pjit", "pmap"}


@dataclasses.dataclass
class FuncInfo:
    node: ast.AST                       # FunctionDef | AsyncFunctionDef
    name: str
    qualname: str
    class_name: Optional[str]


class ModuleGraph:
    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.functions: List[FuncInfo] = []
        self.by_name: Dict[str, List[FuncInfo]] = {}
        self.by_class: Dict[Tuple[str, str], FuncInfo] = {}
        self._collect(tree, qual="", class_name=None)
        self._entries: Optional[Set[int]] = None

    # -- collection --------------------------------------------------------

    def _collect(self, node: ast.AST, qual: str, class_name: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{qual}.{child.name}" if qual else child.name
                info = FuncInfo(child, child.name, q, class_name)
                self.functions.append(info)
                self.by_name.setdefault(child.name, []).append(info)
                if class_name is not None:
                    self.by_class.setdefault((class_name, child.name), info)
                self._collect(child, q, class_name)
            elif isinstance(child, ast.ClassDef):
                q = f"{qual}.{child.name}" if qual else child.name
                self._collect(child, q, class_name=child.name)
            else:
                self._collect(child, qual, class_name)

    # -- entry detection ---------------------------------------------------

    @staticmethod
    def _wrapper_key(func_expr: ast.AST) -> Optional[str]:
        dotted = _dotted(func_expr)
        if dotted is None:
            return None
        last = dotted.rsplit(".", 1)[-1]
        if last not in TRACING_WRAPPERS:
            return None
        # ``tree.map``-style false friends: only trust bare names or roots
        # that look like jax/lax/pl/pallas/functools-free chains.
        if last in ("scan", "while_loop", "fori_loop", "cond", "switch"):
            if "." in dotted and not (
                    ".lax." in f".{dotted}" or dotted.startswith("lax.")):
                return None
        return last

    def _funcs_named(self, name: str) -> List[FuncInfo]:
        return self.by_name.get(name, [])

    def _mark_traced_arg(self, arg: ast.AST, entries: Set[int]):
        """A function-valued operand of a tracing wrapper: bare name,
        ``functools.partial(f, ...)``, or a list/tuple of either
        (``lax.switch`` branch lists)."""
        if isinstance(arg, ast.Name):
            for info in self._funcs_named(arg.id):
                entries.add(id(info.node))
        elif isinstance(arg, ast.Call):
            d = _dotted(arg.func)
            if d is not None and d.rsplit(".", 1)[-1] == "partial" \
                    and arg.args:
                self._mark_traced_arg(arg.args[0], entries)
        elif isinstance(arg, (ast.List, ast.Tuple)):
            for el in arg.elts:
                self._mark_traced_arg(el, entries)

    def _decorator_is_jit(self, dec: ast.AST) -> bool:
        d = _dotted(dec)
        if d is not None:
            return d.rsplit(".", 1)[-1] in _JIT_DECORATOR_HEADS
        if isinstance(dec, ast.Call):
            head = _dotted(dec.func)
            if head is None:
                return False
            last = head.rsplit(".", 1)[-1]
            if last in _JIT_DECORATOR_HEADS:
                return True
            if last == "partial" and dec.args:
                inner = _dotted(dec.args[0])
                return inner is not None and \
                    inner.rsplit(".", 1)[-1] in _JIT_DECORATOR_HEADS
        return False

    def entry_nodes(self) -> Set[int]:
        """id()s of function nodes handed directly to a tracing wrapper."""
        entries: Set[int] = set()
        for info in self.functions:
            if any(self._decorator_is_jit(d)
                   for d in info.node.decorator_list):
                entries.add(id(info.node))
        for call in (n for n in ast.walk(self.tree)
                     if isinstance(n, ast.Call)):
            key = self._wrapper_key(call.func)
            if key is None:
                continue
            argpos = TRACING_WRAPPERS[key]
            args = (call.args if argpos is None
                    else [call.args[i] for i in argpos
                          if i < len(call.args)])
            for a in args:
                self._mark_traced_arg(a, entries)
        return entries

    # -- reachability ------------------------------------------------------

    def _callees(self, info: FuncInfo) -> Set[int]:
        out: Set[int] = set()
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name):
                for cand in self._funcs_named(f.id):
                    out.add(id(cand.node))
            elif isinstance(f, ast.Attribute) \
                    and isinstance(f.value, ast.Name) \
                    and f.value.id == "self" and info.class_name:
                cand = self.by_class.get((info.class_name, f.attr))
                if cand is not None:
                    out.add(id(cand.node))
        return out

    def traced_functions(self) -> List[FuncInfo]:
        """Every locally-defined function reachable from a trace site."""
        if self._entries is None:
            self._entries = self.entry_nodes()
        by_id = {id(f.node): f for f in self.functions}
        reach: Set[int] = set()
        frontier = [i for i in self._entries if i in by_id]
        while frontier:
            cur = frontier.pop()
            if cur in reach:
                continue
            reach.add(cur)
            for nxt in self._callees(by_id[cur]):
                if nxt not in reach:
                    frontier.append(nxt)
        return [by_id[i] for i in sorted(reach, key=lambda i:
                                         by_id[i].node.lineno)]
