"""Jit trace/transfer audit over the live backend registry.

For every registered ``schedule:codec`` spec (``core.backends.
available_specs()``), masked and unmasked, this tool jits one aggregation
round and runs it twice under ``jax.transfer_guard("disallow")``:

* any implicit device<->host transfer raises (explicit ``jax.device_put``
  staging is still allowed) — catching the ``np.*``-in-hot-path family of
  bugs that JIT001 finds statically, but end to end;
* the second call must hit the jit cache — a retrace means some argument
  or closure leaks a trace-unstable Python value (shape-dependent branch,
  fresh lambda, unhashable static) and the "steady-state" round is paying
  compile time every call.

Beyond the aggregation grid, two composite hot paths get the same
two-call treatment end to end:

* the serve decode chunk — ``ContinuousEngine._chunk``, the jitted
  ``lax.while_loop`` every token rides through, exercised on a warm
  engine with requests still in flight;
* the pipelined-round seam — ``build_train_step(..., pipeline="parity")``
  with its primed carry, the steady-state round of a prefetch-overlapped
  run.

Results persist to ``results/AUDIT_trace.json``. Specs that cannot run in
this process's device context (mesh schedules without enough devices,
``hierarchical`` without pods) are recorded as skipped with the reason —
never silently dropped. Exit is non-zero on any failure.

    PYTHONPATH=src python tools/trace_audit.py [--fast]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
if os.path.dirname(_HERE) not in sys.path:   # direct `python tools/...` run
    sys.path.insert(0, os.path.dirname(_HERE))

from tools.reprolint.registry import REPO_ROOT, ensure_src_on_path

ensure_src_on_path()

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
import numpy as np                             # noqa: E402
from jax.sharding import Mesh, NamedSharding   # noqa: E402
from jax.sharding import PartitionSpec as P    # noqa: E402

from repro.core import aggregate as agg        # noqa: E402
from repro.core import backends as B           # noqa: E402

W = 4          # worker dimension: divisible by n_pods=2 and by 1/2/4 shards
BETA = 0.7


def _build_fixture(d: int):
    key = jax.random.key(0)
    params = {
        "blk": {"w": jax.random.normal(key, (W, d), jnp.float32)},
        "head": jax.random.normal(jax.random.fold_in(key, 1), (W, 33)),
        "shared": jnp.ones((3, 2), jnp.float32),
    }
    axes = {"blk": {"w": ("worker", None)},
            "head": ("worker", None),
            "shared": ("shared", None)}
    theta = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(key, 2), (W,)))
    active = jnp.array([1, 1, 0, 1], jnp.bool_)
    return params, axes, theta, active


def _build_mesh():
    devs = jax.devices()
    n = max(k for k in (1, 2, 4) if k <= len(devs))
    return Mesh(np.array(devs[:n]), ("data",))


def _audit_one(spec: str, masked: bool, params, axes, theta, active, mesh):
    sched_name, _ = B.resolve_spec(spec)
    sched = B._SCHEDULES[sched_name]
    n_pods = 2 if sched_name == "hierarchical" else 1
    if masked and not getattr(sched, "supports_mask", True):
        return {"spec": spec, "masked": masked, "status": "skipped",
                "reason": f"schedule {sched_name!r} has no masked path"}
    if not B._spec_runnable(sched_name, mesh, n_pods, W,
                            require_mask=masked):
        return {"spec": spec, "masked": masked, "status": "skipped",
                "reason": f"not runnable here (devices={mesh.size}, "
                          f"n_pods={n_pods}, w={W})"}

    backend = B.get_backend(spec)
    ctx0 = B.AggregationContext(
        mesh=mesh if sched.needs_mesh else None, n_pods=n_pods)
    traces = {"n": 0}

    if masked:
        def call(p, t, a):
            traces["n"] += 1       # python body runs per TRACE, not per call
            c = dataclasses.replace(ctx0, active=a)
            return backend.aggregate(p, axes, t, BETA, ctx=c)
        args = (params, theta, active)
    else:
        def call(p, t):
            traces["n"] += 1
            return backend.aggregate(p, axes, t, BETA, ctx=ctx0)
        args = (params, theta)

    fn = jax.jit(call)
    # Explicit staging (the guard allows jax.device_put). Mesh schedules
    # get worker leaves pre-sharded along the mesh axis — the trainer's
    # steady state — so the jitted round contains no implicit reshard.
    if sched.needs_mesh:
        shard = NamedSharding(mesh, P("data"))
        rep = NamedSharding(mesh, P())
        leaves_ax, treedef = jax.tree_util.tree_flatten(
            axes, is_leaf=agg._axes_is_leaf)
        placed = [
            jax.device_put(x, shard if agg.is_worker_leaf(ax) else rep)
            for ax, x in zip(leaves_ax, treedef.flatten_up_to(params))]
        args = (jax.tree_util.tree_unflatten(treedef, placed),) \
            + tuple(jax.device_put(a, rep) for a in args[1:])
    else:
        args = jax.device_put(args)
    entry = {"spec": spec, "masked": masked}
    try:
        with jax.transfer_guard("disallow"):
            out1 = jax.block_until_ready(fn(*args))
            after_first = traces["n"]
            out2 = jax.block_until_ready(fn(*args))
            retraces = traces["n"] - after_first
    except Exception as e:  # noqa: BLE001 - any guard/trace failure is a find
        entry.update(status="failed",
                     error=f"{type(e).__name__}: {e}")
        return entry
    drift = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(out1), jax.tree.leaves(out2)))
    entry.update(status="ok" if retraces == 0 else "failed",
                 traces_first_call=after_first, retraces=retraces,
                 call_drift=drift)
    if retraces:
        entry["error"] = (f"{retraces} retrace(s) on an identical second "
                          f"call — the round recompiles every step")
    return entry


def _audit_serve_chunk():
    """Two identical calls of a warm ``ContinuousEngine._chunk`` under the
    transfer guard: the decode while_loop must neither touch the host nor
    recompile between chunks of the same batch shape."""
    from repro.configs import get_smoke_config
    from repro.data import lm_batch
    from repro.models import init_params
    from repro.serve import ContinuousEngine

    entry = {"spec": "serve:decode_chunk", "masked": False}
    try:
        cfg = dataclasses.replace(get_smoke_config("gemma3-1b"),
                                  compute_dtype="float32")
        params, _ = init_params(cfg, jax.random.key(0))
        eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64,
                               block_size=8, cache_dtype=jnp.float32,
                               chunk=4)
        prompts = np.asarray(lm_batch(0, 2, 8, cfg.vocab_size)["tokens"])
        for i in range(2):
            # budgets far beyond one chunk: rows stay active across the
            # audited calls, so the loop body really runs both times
            eng.submit(prompts[i], n_new=40, seed=i)
        eng.step()                    # warm: prefill + admit + first chunk
        assert eng.n_running == 2, "fixture finished during warmup"

        # replicate step()'s exact argument staging for the chunk call
        tables = eng.cache.tables
        full = tables.get("full")
        w = eng.cache.used_width()
        if full is not None and w is not None and w < full.shape[1]:
            tables = {**tables, "full": full[:, :w]}
        stop_early = jax.device_put(jnp.asarray(False))
        before = eng._chunk._cache_size()
        with jax.transfer_guard("disallow"):
            out1 = jax.block_until_ready(
                eng._chunk(eng.params, eng.cache.pools, tables, eng._st,
                           stop_early, max_steps=eng.chunk))
            out2 = jax.block_until_ready(
                eng._chunk(eng.params, eng.cache.pools, tables, eng._st,
                           stop_early, max_steps=eng.chunk))
        misses = eng._chunk._cache_size() - before
        steps1, steps2 = int(out1[2]), int(out2[2])
    except Exception as e:  # noqa: BLE001 - any guard/trace failure is a find
        entry.update(status="failed", error=f"{type(e).__name__}: {e}")
        return entry
    entry.update(status="ok" if misses == 0 and steps1 == steps2 == 4
                 else "failed",
                 cache_misses=misses, steps_per_chunk=steps1)
    if entry["status"] == "failed":
        entry["error"] = (f"{misses} cache miss(es) / steps "
                          f"{steps1}/{steps2} on identical warm chunks")
    return entry


def _audit_pipelined_seam():
    """Two identical calls of a primed ``pipeline='parity'`` round under the
    transfer guard: the seam (staged next-first-microbatch carried through
    the aggregation phase gap) must not leak host values into the trace."""
    import functools as ft

    from repro.configs import WASGDConfig
    from repro.data import OrderedDataset, first_microbatch, \
        make_classification
    from repro.models import cnn
    from repro.models.param import build
    from repro.optim import make_optimizer
    from repro.train.state import init_state
    from repro.train.step import build_train_step, init_comm_state

    entry = {"spec": "pipeline:parity_seam", "masked": False}
    try:
        w, tau, bl = W, 2, 4
        X, y = make_classification(0, 256, d=16, n_classes=4)
        params0, axes0 = build(ft.partial(cnn.mlp_init, d_in=16, d_hidden=32,
                                          n_classes=4), jax.random.key(0))
        from repro.core import replicate_workers
        params, axes = replicate_workers(params0, axes0, w)

        def loss_fn(p, b):
            return cnn.classification_loss(cnn.mlp_apply(p, b["x"]),
                                           b["y"]), {}

        wcfg = WASGDConfig(tau=tau, backend="einsum:f32")
        opt = make_optimizer("sgd", 0.05, 0.0, 0.0)
        step = build_train_step(loss_fn, opt, axes, wcfg, w,
                                pipeline="parity")
        traces = {"n": 0}

        def call(state, batch, nf, carry):
            traces["n"] += 1       # python body runs per TRACE, not per call
            return step(state, batch, nf, carry)

        fn = jax.jit(call)
        ds = OrderedDataset({"x": X, "y": y}, w, tau, bl, seed=3)
        gen = ds.batches()
        b0, b1 = next(gen), next(gen)
        comm = init_comm_state("wasgd", params, axes, w, wcfg=wcfg)
        state = init_state(params, opt.init(params), w, comm)
        carry = jax.block_until_ready(jax.jit(step.primer)(state.params, b0))
        batch = jax.device_put(b0)
        nf = jax.device_put(first_microbatch(b1, w, tau))
        with jax.transfer_guard("disallow"):
            jax.block_until_ready(fn(state, batch, nf, carry))
            after_first = traces["n"]
            jax.block_until_ready(fn(state, batch, nf, carry))
            retraces = traces["n"] - after_first
    except Exception as e:  # noqa: BLE001 - any guard/trace failure is a find
        entry.update(status="failed", error=f"{type(e).__name__}: {e}")
        return entry
    entry.update(status="ok" if retraces == 0 else "failed",
                 traces_first_call=after_first, retraces=retraces)
    if retraces:
        entry["error"] = (f"{retraces} retrace(s) on an identical second "
                          f"pipelined round")
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="small leaves (CI); same spec coverage")
    ap.add_argument("--out", default=os.path.join(
        REPO_ROOT, "results", "AUDIT_trace.json"))
    args = ap.parse_args(argv)

    d = 1024 if args.fast else 16384
    params, axes, theta, active = _build_fixture(d)
    mesh = _build_mesh()

    results = []
    for spec in B.available_specs():
        for masked in (False, True):
            entry = _audit_one(spec, masked, params, axes, theta, active,
                               mesh)
            results.append(entry)
            tag = entry["status"].upper()
            extra = entry.get("error") or entry.get("reason") or \
                f"retraces={entry.get('retraces')}"
            print(f"[{tag:7s}] {spec:22s} masked={int(masked)}  {extra}")

    for entry in (_audit_serve_chunk(), _audit_pipelined_seam()):
        results.append(entry)
        tag = entry["status"].upper()
        extra = entry.get("error") or \
            f"misses={entry.get('cache_misses', entry.get('retraces'))}"
        print(f"[{tag:7s}] {entry['spec']:22s} masked=0  {extra}")

    failed = [r for r in results if r["status"] == "failed"]
    skipped = [r for r in results if r["status"] == "skipped"]
    report = {
        "generated_by": "tools/trace_audit.py",
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "fast": args.fast,
        "devices": len(jax.devices()),
        "mesh_devices": mesh.size,
        "backend": jax.default_backend(),
        "w": W,
        "leaf_d": d,
        "n_specs": len(B.available_specs()),
        "n_ok": sum(r["status"] == "ok" for r in results),
        "n_skipped": len(skipped),
        "n_failed": len(failed),
        "results": results,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\n{report['n_ok']} ok, {len(skipped)} skipped, "
          f"{len(failed)} failed -> {args.out}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
