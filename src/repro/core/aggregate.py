"""Weighted aggregation — the paper's communication step (Eq. 10):

    x_i  <-  (1 - beta) * x_i  +  beta * sum_j theta_j * x_j

applied to every parameter leaf that carries the leading ``worker``
dimension. Leaves without a worker dimension (expert-parallel single copies,
DESIGN.md §4.1) pass through unchanged.

Under SPMD with the worker dimension sharded over ("pod","data") the einsum
lowers to one θ-weighted all-reduce over the worker axis — the TPU-native
equivalent of the paper's send-to-all exchange. Beyond-paper variants:

* ``quantize``      — int8 payload: aggregate in int8 with a per-leaf scale,
                      4x fewer collective bytes, error fed back locally.
* ``sharded``       — reduce-scatter + local FMA + all-gather (same bytes on
                      a ring but exposes overlap; useful with hierarchical).
* Pallas ``wagg``   — fused (1-β)x + β·Σθx single-pass kernel for the local
                      FMA part (kernels/wagg).

These primitives are selected uniformly through the aggregation backend
registry (``core/backends.py``); prefer ``WASGDConfig.backend`` /
``aggregate_with`` over calling the variant kwargs here directly.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp


def is_worker_leaf(axes_leaf) -> bool:
    return isinstance(axes_leaf, tuple) and len(axes_leaf) > 0 \
        and axes_leaf[0] == "worker"


def _axes_is_leaf(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def fma_late_join(x: jax.Array, m: jax.Array, beta,
                  active: Optional[jax.Array] = None) -> jax.Array:
    """The worker-local half of Eq. 10: ``(1-beta) x + beta m``, plus the
    Alg. 4 late-join — inactive workers adopt the aggregate ``m`` (their
    theta is 0, so ``m`` already excludes them). ``active=None`` (the
    synchronous path) places no mask in the program at all. Shared by every
    schedule's ``finalize`` (core/backends.py) and the fused shard_map
    entry points (core/shardmap_agg.py)."""
    out = (1.0 - beta) * x.astype(jnp.float32) + beta * m[None]
    if active is not None:
        mask = active.reshape(active.shape + (1,) * (x.ndim - 1))
        out = jnp.where(mask, out, jnp.broadcast_to(m[None], out.shape))
    return out.astype(x.dtype)


def aggregate_leaf(x: jax.Array, theta: jax.Array, beta: float | jax.Array,
                   quantize: bool = False, comm_dtype=jnp.float32,
                   n_pods: int = 1) -> jax.Array:
    """One leaf (w, ...) -> (w, ...).

    ``comm_dtype=bf16`` halves the worker-axis all-reduce payload (the
    tensordot operand is what rides the ring). ``n_pods>1`` splits the
    reduction into a pod-local stage and a tiny cross-pod stage so the DCN
    hop carries pre-reduced partials (hierarchical 2-hop).
    """
    theta = theta.astype(jnp.float32)
    if quantize:
        # int8 aggregation payload with a per-leaf symmetric scale.
        scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        # reprolint: allow=DT001 -- legacy int8 path; the symmetric scale
        # two lines up makes the narrowing explicit and round-trips to f32
        agg = jnp.tensordot(theta, q.astype(jnp.int8).astype(jnp.float32),
                            axes=1) * scale
    elif n_pods > 1 and x.shape[0] % n_pods == 0:
        w = x.shape[0]
        xr = x.reshape(n_pods, w // n_pods, *x.shape[1:]).astype(comm_dtype)
        tr = theta.reshape(n_pods, w // n_pods)
        partial = jnp.einsum("pw...,pw->p...", xr,
                             tr.astype(comm_dtype))       # pod-local reduce
        agg = partial.astype(jnp.float32).sum(axis=0)     # cross-pod reduce
    else:
        agg = jnp.tensordot(theta.astype(comm_dtype), x.astype(comm_dtype),
                            axes=1).astype(jnp.float32)
    out = (1.0 - beta) * x.astype(jnp.float32) + beta * agg[None]
    return out.astype(x.dtype)


def weighted_aggregate(params: Dict, axes: Dict, theta: jax.Array,
                       beta: float | jax.Array, *, quantize: bool = False,
                       comm_dtype=jnp.float32, n_pods: int = 1,
                       leaf_fn: Optional[Callable] = None) -> Dict:
    """Apply Eq. 10 to all worker leaves of ``params``.

    ``leaf_fn(x, theta, beta)`` overrides the per-leaf computation (used to
    swap in the Pallas ``wagg`` kernel).
    """
    fn = leaf_fn if leaf_fn is not None else (
        lambda x, t, b: aggregate_leaf(x, t, b, quantize=quantize,
                                       comm_dtype=comm_dtype,
                                       n_pods=n_pods))

    def visit(x, ax):
        if is_worker_leaf(ax):
            return fn(x, theta, beta)
        return x

    return jax.tree.map(visit, params, axes,
                        is_leaf=lambda n: _axes_is_leaf(n))


def map_worker_leaves(fn: Callable, params: Dict, axes: Dict) -> Dict:
    def visit(x, ax):
        return fn(x) if is_worker_leaf(ax) else x
    return jax.tree.map(visit, params, axes, is_leaf=_axes_is_leaf)


def worker_in_axes(axes: Dict):
    """vmap ``in_axes`` pytree: 0 for worker leaves, None for shared leaves."""
    return jax.tree.map(lambda ax: 0 if is_worker_leaf(ax) else None, axes,
                        is_leaf=_axes_is_leaf)


def strip_worker_axis(axes: Dict) -> Dict:
    """Logical-axes tree for a single worker's slice (vmap's view)."""
    return jax.tree.map(
        lambda ax: tuple(ax[1:]) if is_worker_leaf(ax) else ax,
        axes, is_leaf=_axes_is_leaf)


def take_worker(params: Dict, axes: Dict, i: int) -> Dict:
    """Extract worker ``i``'s parameter copy (serving / checkpoint export)."""
    return jax.tree.map(
        lambda x, ax: x[i] if is_worker_leaf(ax) else x,
        params, axes, is_leaf=lambda n: _axes_is_leaf(n))


def resize_worker_leaves(params: Dict, axes: Dict, new_p: int,
                         theta: Optional[jax.Array] = None) -> Dict:
    """Grow/shrink every worker-stacked leaf to ``new_p`` rows.

    The membership contract (core/membership.py): worker ``i`` keeps slot
    ``i`` for ``i < min(old_p, new_p)`` — survivors are bitwise-preserved —
    a shrink drops the tail slots, and a grow appends newcomers whose row
    is the **aggregate** ``m = sum_j theta_j x_j`` over the surviving
    workers (``theta=None`` = equal weights): exactly the state an Alg. 4
    late-joiner adopts, so a freshly joined worker starts from the
    consensus model instead of a stale or random copy. Leaves without a
    worker axis (expert-parallel single copies) pass through unchanged.
    """
    if new_p < 1:
        raise ValueError(f"resize needs new_p >= 1, got {new_p}")

    def visit(x, ax):
        if not is_worker_leaf(ax):
            return x
        old_p = x.shape[0]
        if new_p <= old_p:
            return x[:new_p]
        t = (jnp.full((old_p,), 1.0 / old_p, jnp.float32) if theta is None
             else theta.astype(jnp.float32))
        m = jnp.tensordot(t, x.astype(jnp.float32), axes=1)
        newcomers = jnp.broadcast_to(
            m[None], (new_p - old_p,) + x.shape[1:]).astype(x.dtype)
        return jnp.concatenate([x, newcomers], axis=0)

    return jax.tree.map(visit, params, axes, is_leaf=_axes_is_leaf)


def replicate_workers(params: Dict, axes: Dict, n_workers: int,
                      expert_copies: bool = False):
    """Single-copy params -> (w, ...) worker copies (+ updated axes tree).

    Expert leaves stay single-copy (expert-parallel, DESIGN.md §4.1) unless
    ``expert_copies`` — the "worker" expert-sharding policy where experts
    join the weighted aggregation (§Perf, memory permitting)."""
    def rep(x, ax):
        if not expert_copies and isinstance(ax, tuple) and "experts" in ax:
            return x
        return jnp.broadcast_to(x[None], (n_workers,) + x.shape)

    def rep_ax(ax):
        if not expert_copies and isinstance(ax, tuple) and "experts" in ax:
            return ax
        return ("worker",) + tuple(ax)

    new_params = jax.tree.map(rep, params, axes, is_leaf=_axes_is_leaf)
    new_axes = jax.tree.map(rep_ax, axes, is_leaf=_axes_is_leaf)
    return new_params, new_axes
