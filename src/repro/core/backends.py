"""Two-axis aggregation API: collective *schedule* x payload *codec*.

The paper's contribution is a single communication rule,

    x_i  <-  (1 - beta) * x_i  +  beta * sum_j theta_j * x_j        (Eq. 10)

but how fast it runs is the product of two orthogonal choices: where the
collectives go (the **schedule**) and what bytes they carry (the **codec**).
This module keeps one registry per axis and composes them on demand —
``WASGDConfig.backend`` accepts a spec string

    "<schedule>:<codec>"        e.g. "rs_ag:int8", "hierarchical:bf16"

or a bare ``"<schedule>"`` (codec derived from ``ctx.comm_dtype``, i.e. the
legacy ``WASGDConfig.comm_dtype`` knob keeps working), or ``"auto"``
(``select_auto_spec``: pick the spec per (total worker-leaf bytes, mesh
size) from recorded ``benchmarks/kernel_bench.py`` measurements, with a
size heuristic as fallback).

Schedules (the placement axis)
==============================

Every schedule is *phased* — ``prepare -> reduce phase(s) -> finalize`` —
and the phases of all worker leaves are sequenced together, so a
multi-phase schedule exposes a seam BETWEEN its collectives. The optional
``overlap=`` hook (a nullary compute thunk) runs exactly there: for
``rs_ag`` the thunk's ops land between the reduce-scatter and the
all-gather, so independent compute (the next round's first forward, metric
reductions, ...) can hide the second collective. The thunk may return any
pytree — the pipelined train step (train/step.py) stages the next round's
first microbatch plus its speculative Judge forward through the seam as a
dict. The thunk never feeds the aggregate, so the produced params are
identical with or without it.

``einsum``        The reference. pjit tensordot over the worker axis; XLA
                  derives the theta-weighted all-reduce. 1 reduce phase.
``hierarchical``  2-hop: pod-local reduce (phase 1, carries the codec
                  payload), tiny cross-pod reduce (phase 2, always f32).
                  Uses ``ctx.n_pods``; fails loud on a degenerate pod count.
``shard_map``     Explicit ``lax.psum`` under ``shard_map``. 1 reduce
                  phase. Requires ``ctx.mesh``.
``rs_ag``         reduce-scatter (phase 1) + all-gather (phase 2) + local
                  FMA. Same ring bytes as one all-reduce, payload pinned to
                  the codec's wire dtype, and the two phases straddle the
                  ``overlap=`` thunk. Requires ``ctx.mesh``.
``pallas_wagg``   Fused Pallas TPU kernel for the local FMA
                  (``kernels/wagg``): codec decode + Alg. 4 mask + Eq. 10
                  FMA in one VMEM pass instead of three-plus HBM round
                  trips — the quantized specs (``pallas_wagg:int8``/
                  ``:int4``) skip the separate decode program entirely.
                  Composes with every codec; interpret mode on CPU.

Codecs (the payload axis) live in ``core/codecs.py``: ``f32``, ``bf16``,
``int8`` (the old ``quantized`` backend), ``int4`` (stochastic rounding).
Each documents a per-element ``error_bound`` the composition-grid test
holds every pair to.

Alias table (old name -> spec)
==============================

    einsum           einsum        (codec from ctx.comm_dtype)
    quantized        einsum:int8
    hierarchical     hierarchical  (codec from ctx.comm_dtype)
    shard_map        shard_map:f32
    rs_ag            rs_ag         (codec from ctx.comm_dtype)
    pallas_wagg      pallas_wagg:f32
    async_einsum     einsum        -- the Alg. 4 mask is not a separate
    async_shard_map  shard_map:f32    backend anymore: EVERY composed spec
    async_rs_ag      rs_ag            honors ``ctx.active`` in its finalize
                                      (stragglers late-join the aggregate),
                                      so the async family composes with any
                                      codec (e.g. "hierarchical:int8" or
                                      "pallas_wagg:int8" under a straggler
                                      mask).

Legacy boolean knobs also compose now: ``quantize_comm=True`` +
``sharded_aggregate=True`` resolves to ``"rs_ag:int8"`` instead of silently
dropping the mesh schedule, and ``hierarchical=True`` with ``n_pods=1``
raises instead of silently running the flat einsum path
(``backend_name_from_config``).

Adding a schedule
=================

    from repro.core.backends import register_schedule

    @register_schedule
    class MySchedule:
        name = "my_sched"
        needs_mesh = False
        n_phases = 1
        def prepare(self, x, theta, codec, ctx): ...
        def reduce_phase(self, i, state, theta, codec, ctx): ...
        def finalize(self, state, x, theta, beta, codec, ctx): ...

Every ``"my_sched:<codec>"`` spec becomes selectable through
``WASGDConfig.backend`` and is picked up by the composition-grid parity
test (``tests/test_composition_grid.py``) and the
``benchmarks/kernel_bench.py`` matrix sweep. ``register_backend`` remains
for monolithic one-off backends (a plain
``fn(params, axes, theta, beta, ctx)``) that do not decompose into the two
axes.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import warnings
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import aggregate as agg
from repro.core import codecs as codecs_mod
from repro.core import shardmap_agg as smagg
from repro.core.aggregate import fma_late_join
from repro.core.codecs import (PayloadCodec, available_codecs,
                               codec_for_dtype, get_codec, register_codec)


# ---------------------------------------------------------------------------
# Context + protocols
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregationContext:
    """Orthogonal knobs every schedule/codec receives (and may ignore).

    ``mesh``       physical mesh for schedules that place explicit collectives.
    ``comm_dtype`` payload dtype for specs that leave the codec axis open
                   (``f32``/``bf16`` -> the matching codec).
    ``n_pods``     pod count for the hierarchical 2-hop.
    ``active``     (w,) bool activity mask for Alg. 4 (may be a tracer);
                   ``None`` = all workers active (no mask in the program).
    ``key``        optional PRNG key for stochastic codecs (``int4``);
                   ``None`` = a fixed fold-in (deterministic).
    ``leaf_index`` position of the current worker leaf in the flattened
                   tree; set per-leaf by ``ComposedBackend.aggregate`` so
                   stochastic codecs draw DISTINCT noise for identical-
                   content leaves (zero-inits, tied embeddings).
    """
    mesh: Optional[Mesh] = None
    comm_dtype: Any = jnp.float32
    n_pods: int = 1
    active: Optional[jax.Array] = None
    key: Optional[jax.Array] = None
    leaf_index: Optional[int] = None


DEFAULT_CONTEXT = AggregationContext()


@runtime_checkable
class AggregatorBackend(Protocol):
    """One implementation of the Eq. 10 communication step."""
    name: str
    needs_mesh: bool

    def aggregate(self, params: Dict, axes: Dict, theta: jax.Array,
                  beta, *, ctx: AggregationContext = DEFAULT_CONTEXT) -> Dict:
        ...


@runtime_checkable
class AggregationSchedule(Protocol):
    """The placement axis: where the worker-axis collectives go.

    ``n_phases`` reduce phases run in sequence over ALL worker leaves; the
    ``overlap=`` thunk (ComposedBackend.aggregate) runs after phase 0, i.e.
    between the two collectives of a 2-phase schedule. ``codecs`` (optional
    tuple) restricts the payload axis; ``None`` means every registered
    codec composes.
    """
    name: str
    needs_mesh: bool
    n_phases: int

    def prepare(self, x: jax.Array, theta: jax.Array, codec: PayloadCodec,
                ctx: AggregationContext) -> Dict:
        ...

    def reduce_phase(self, i: int, state: Dict, theta: jax.Array,
                     codec: PayloadCodec, ctx: AggregationContext) -> Dict:
        ...

    def finalize(self, state: Dict, x: jax.Array, theta: jax.Array, beta,
                 codec: PayloadCodec, ctx: AggregationContext) -> jax.Array:
        ...


class _FnBackend:
    """Adapter turning a plain ``fn(params, axes, theta, beta, ctx)`` into an
    ``AggregatorBackend`` (the monolithic escape hatch)."""

    def __init__(self, name: str, fn: Callable, needs_mesh: bool = False):
        self.name = name
        self.needs_mesh = needs_mesh
        self._fn = fn

    def aggregate(self, params, axes, theta, beta, *,
                  ctx: AggregationContext = DEFAULT_CONTEXT):
        if self.needs_mesh and ctx.mesh is None:
            raise ValueError(
                f"aggregation backend {self.name!r} places explicit "
                f"collectives and needs ctx.mesh (pass mesh= through "
                f"communicate/wasgd_rule, or use the 'einsum' family)")
        return self._fn(params, axes, theta, beta, ctx)

    def __repr__(self):
        return f"AggregatorBackend({self.name!r})"


# ---------------------------------------------------------------------------
# Built-in schedules
# ---------------------------------------------------------------------------

class _EinsumSchedule:
    """Reference: pjit tensordot; XLA derives the theta-weighted all-reduce."""
    name = "einsum"
    needs_mesh = False
    n_phases = 1
    codecs = None
    supports_mask = True

    def prepare(self, x, theta, codec, ctx):
        payload, aux = codec.encode(x, ctx)
        return {"payload": payload, "aux": aux}

    def reduce_phase(self, i, state, theta, codec, ctx):
        rd = codec.reduce_dtype
        m = jnp.tensordot(theta.astype(rd), state["payload"].astype(rd),
                          axes=1).astype(jnp.float32)
        return {"m": m, "aux": state["aux"]}

    def finalize(self, state, x, theta, beta, codec, ctx):
        m = codec.decode_reduced(state["m"], state["aux"])
        return fma_late_join(x, m, beta, ctx.active)


class _HierarchicalSchedule:
    """2-hop: pod-local reduce (phase 1, codec payload), cross-pod reduce
    (phase 2, f32) — the DCN hop carries pre-reduced partials. With a
    quantizing codec the pod-local hop carries the integer payload and only
    the tiny cross-pod hop rides f32."""
    name = "hierarchical"
    needs_mesh = False
    n_phases = 2
    codecs = None
    supports_mask = True

    def validate(self, theta, ctx):
        # Fail clear instead of silently taking the flat einsum path: the old
        # n_pods guard swallowed a misconfigured 2-hop and ran a different
        # computation without warning.
        w = theta.shape[0]
        if ctx.n_pods < 2 or w % ctx.n_pods:
            raise ValueError(
                f"'hierarchical' schedule needs ctx.n_pods >= 2 dividing the "
                f"worker count (got n_pods={ctx.n_pods}, workers={w}); set "
                f"WASGDConfig.n_pods or use the 'einsum' schedule")

    def prepare(self, x, theta, codec, ctx):
        payload, aux = codec.encode(x, ctx)
        w = payload.shape[0]
        xr = payload.reshape(ctx.n_pods, w // ctx.n_pods, *payload.shape[1:])
        return {"xr": xr, "aux": aux}

    def reduce_phase(self, i, state, theta, codec, ctx):
        if i == 0:                                   # pod-local hop
            rd = codec.reduce_dtype
            w = theta.shape[0]
            tr = theta.reshape(ctx.n_pods, w // ctx.n_pods)
            partial = jnp.einsum("pw...,pw->p...", state["xr"].astype(rd),
                                 tr.astype(rd))
            return {"partial": partial, "aux": state["aux"]}
        m = state["partial"].astype(jnp.float32).sum(axis=0)   # cross-pod hop
        return {"m": m, "aux": state["aux"]}

    def finalize(self, state, x, theta, beta, codec, ctx):
        m = codec.decode_reduced(state["m"], state["aux"])
        return fma_late_join(x, m, beta, ctx.active)


class _ShardMapSchedule:
    """Explicit ``lax.psum`` under shard_map — the form to reach for when
    collective placement matters. One reduce phase."""
    name = "shard_map"
    needs_mesh = True
    n_phases = 1
    codecs = None
    supports_mask = True

    def prepare(self, x, theta, codec, ctx):
        payload, aux = codec.encode(x, ctx)
        return {"payload": payload, "aux": aux}

    def reduce_phase(self, i, state, theta, codec, ctx):
        m = smagg.all_reduce_m_phase(state["payload"], theta, ctx.mesh,
                                     reduce_dtype=codec.reduce_dtype)
        return {"m": m, "aux": state["aux"]}

    def finalize(self, state, x, theta, beta, codec, ctx):
        m = codec.decode_reduced(state["m"], state["aux"])
        return fma_late_join(x, m, beta, ctx.active)


class _RsAgSchedule:
    """reduce-scatter (phase 1) + all-gather (phase 2) + local FMA. Same
    ring bytes as one all-reduce, but the payload dtype is pinned and the
    ``overlap=`` thunk runs between the two collectives.

    Dtype codecs pin the *ring partial* (the legacy ``comm_dtype`` cast on
    the scattered operand); quantizing codecs encode the *operand* and let
    the partial ride in ``reduce_dtype`` — partial sums of integer payloads
    are fractional, so re-quantizing them per hop would compound error.
    """
    name = "rs_ag"
    needs_mesh = True
    n_phases = 2
    codecs = None
    supports_mask = True

    def prepare(self, x, theta, codec, ctx):
        p = smagg.mesh_worker_shards(ctx.mesh)
        if codec.quantizing:
            payload, aux = codec.encode(x, ctx)
            wire = codec.reduce_dtype
        else:
            payload, aux = x, None
            wire = codec.wire_dtype
        flat, n = smagg.flatten_pad(payload, p)
        return {"flat": flat, "aux": aux, "n": n, "wire": wire}

    def reduce_phase(self, i, state, theta, codec, ctx):
        if i == 0:
            m_scat = smagg.reduce_scatter_phase(state["flat"], theta,
                                                ctx.mesh,
                                                wire_dtype=state["wire"])
            return {**state, "m_scat": m_scat}
        m = smagg.all_gather_phase(state["m_scat"], ctx.mesh)
        return {**state, "m": m}

    def finalize(self, state, x, theta, beta, codec, ctx):
        m = codec.decode_reduced(state["m"], state["aux"])
        flat_x, n = smagg.flatten_pad(x, smagg.mesh_worker_shards(ctx.mesh))
        out = fma_late_join(flat_x, m, beta, ctx.active)
        return out[:, :n].reshape(x.shape)


class _PallasWaggSchedule:
    """Fused Pallas TPU kernel for the local FMA (kernels/wagg), v2: codec
    decode + the Alg. 4 activity mask + the Eq. 10 FMA in ONE VMEM pass.

    The codec's wire tiles (int8-carried int4/int8, bf16) ride into the
    kernel as-is — the per-leaf scalar scale (``aux``) is folded into theta
    by ``wagg_fused_leaf`` — and are widened to f32 in VMEM, so the
    quantized specs cost one HBM round trip instead of encode/reduce/decode
    as three separate XLA programs. ``ctx.active`` selects the late-join
    rows in the same pass. Meshless (local FMA); interpret mode off-TPU.
    """
    name = "pallas_wagg"
    needs_mesh = False
    n_phases = 1
    codecs = ("f32", "bf16", "int8", "int4")
    supports_mask = True        # v2: the kernel applies the late-join in-pass

    def prepare(self, x, theta, codec, ctx):
        if codec.name == "f32":
            # the payload IS x: the kernel streams x once, not twice.
            return {"payload": None, "aux": None}
        payload, aux = codec.encode(x, ctx)
        return {"payload": payload, "aux": aux}

    def reduce_phase(self, i, state, theta, codec, ctx):
        return state    # the fused kernel is the reduce; nothing rides a wire

    def finalize(self, state, x, theta, beta, codec, ctx):
        from repro.kernels.wagg.ops import wagg_fused_leaf   # lazy: optional
        return wagg_fused_leaf(x, state["payload"], state["aux"], theta,
                               beta, active=ctx.active)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

_SCHEDULES: Dict[str, AggregationSchedule] = {}
_REGISTRY: Dict[str, AggregatorBackend] = {}     # monolithic one-off backends
_COMPOSED: Dict[str, "ComposedBackend"] = {}     # resolved spec cache

# old name -> (schedule, codec-or-None). None = derive from ctx.comm_dtype,
# which is exactly how the legacy backends honored WASGDConfig.comm_dtype.
_ALIASES: Dict[str, Tuple[str, Optional[str]]] = {
    "einsum": ("einsum", None),
    "quantized": ("einsum", "int8"),
    "hierarchical": ("hierarchical", None),
    "shard_map": ("shard_map", "f32"),
    "rs_ag": ("rs_ag", None),
    "pallas_wagg": ("pallas_wagg", "f32"),
    # Alg. 4 family: same schedules — every composed spec honors ctx.active.
    "async_einsum": ("einsum", None),
    "async_shard_map": ("shard_map", "f32"),
    "async_rs_ag": ("rs_ag", None),
}


def register_schedule(schedule, *, overwrite: bool = False):
    """Register an ``AggregationSchedule`` (instance or class) by its name."""
    obj = schedule() if isinstance(schedule, type) else schedule
    if obj.name in _SCHEDULES and not overwrite:
        raise ValueError(f"aggregation schedule {obj.name!r} already "
                         f"registered; pass overwrite=True to replace")
    _SCHEDULES[obj.name] = obj
    _COMPOSED.clear()
    return schedule


def register_backend(name: str, fn: Optional[Callable] = None, *,
                     needs_mesh: bool = False, overwrite: bool = False):
    """Register a monolithic aggregation backend under ``name``.

    Usable as a decorator (``@register_backend("my_backend")``) over a
    function ``fn(params, axes, theta, beta, ctx)``, or called directly with
    an object already satisfying the ``AggregatorBackend`` protocol. For
    anything that decomposes into placement x payload, prefer
    ``register_schedule`` / ``register_codec`` so it composes.
    """
    def _register(obj):
        taken = name in _REGISTRY or name in _ALIASES or name in _SCHEDULES
        if taken and not overwrite:
            raise ValueError(f"aggregation backend {name!r} already "
                             f"registered; pass overwrite=True to replace")
        if hasattr(obj, "aggregate"):
            backend = obj
            if needs_mesh and not getattr(obj, "needs_mesh", False):
                # honor needs_mesh for object backends too: wrap so the
                # promised clear missing-mesh error fires at trace time.
                backend = _FnBackend(
                    name,
                    lambda p, a, t, b, ctx: obj.aggregate(p, a, t, b,
                                                          ctx=ctx),
                    needs_mesh=True)
        else:
            backend = _FnBackend(name, obj, needs_mesh=needs_mesh)
        _REGISTRY[name] = backend
        return obj

    if fn is not None:
        return _register(fn)
    return _register


register_schedule(_EinsumSchedule())
register_schedule(_HierarchicalSchedule())
register_schedule(_ShardMapSchedule())
register_schedule(_RsAgSchedule())
register_schedule(_PallasWaggSchedule())


# ---------------------------------------------------------------------------
# Spec resolution + the composed backend
# ---------------------------------------------------------------------------

def resolve_spec(name: str) -> Tuple[str, Optional[str]]:
    """``alias | schedule | schedule:codec`` -> (schedule, codec-or-None).

    ``None`` codec means "derive from ctx.comm_dtype at aggregate time".
    Raises ``KeyError`` with the known names on anything unresolvable.
    """
    if name in _ALIASES:
        return _ALIASES[name]
    if ":" in name:
        sched, codec = name.split(":", 1)
        if sched not in _SCHEDULES:
            raise KeyError(
                f"unknown aggregation schedule {sched!r} in spec {name!r}; "
                f"known schedules: {sorted(_SCHEDULES)}")
        if codec not in available_codecs():
            raise KeyError(
                f"unknown payload codec {codec!r} in spec {name!r}; "
                f"known codecs: {list(available_codecs())}")
        return sched, codec
    if name in _SCHEDULES:
        return name, None
    raise KeyError(
        f"unknown aggregation backend {name!r}; known names: "
        f"{sorted(set(_ALIASES) | set(_REGISTRY))}, or compose a "
        f"'<schedule>:<codec>' spec from schedules {sorted(_SCHEDULES)} "
        f"x codecs {list(available_codecs())}")


def canonical_spec(name: str) -> str:
    """Normalize an alias/spec to ``schedule[:codec]`` form."""
    sched, codec = resolve_spec(name)
    return sched if codec is None else f"{sched}:{codec}"


class ComposedBackend:
    """schedule x codec, exposed through the ``AggregatorBackend`` protocol.

    ``aggregate`` runs each reduce phase across ALL worker leaves before the
    next one, and fires the ``overlap=`` thunk after phase 0 — between the
    two collectives of a 2-phase schedule (rs_ag: after every leaf's
    reduce-scatter, before any all-gather). With ``overlap=`` the return
    value is ``(params, overlap_result)`` — the thunk's result may be any
    pytree (staged batches ride the seam, not just scalars); the thunk
    cannot feed the aggregate, so params are identical either way.
    """

    def __init__(self, schedule: AggregationSchedule,
                 codec_name: Optional[str], name: str):
        self.schedule = schedule
        self.codec_name = codec_name
        self.name = name
        self.needs_mesh = schedule.needs_mesh

    def _codec(self, ctx: AggregationContext) -> PayloadCodec:
        codec = (get_codec(self.codec_name) if self.codec_name
                 else codec_for_dtype(ctx.comm_dtype))
        supported = getattr(self.schedule, "codecs", None)
        if supported is not None and codec.name not in supported:
            raise ValueError(
                f"schedule {self.schedule.name!r} composes only with codecs "
                f"{list(supported)}, not {codec.name!r} "
                f"(spec {self.name!r})")
        return codec

    def aggregate(self, params, axes, theta, beta, *,
                  ctx: AggregationContext = DEFAULT_CONTEXT, overlap=None):
        if self.needs_mesh and ctx.mesh is None:
            raise ValueError(
                f"aggregation backend {self.name!r} places explicit "
                f"collectives and needs ctx.mesh (pass mesh= through "
                f"communicate/wasgd_rule, or use the 'einsum' family)")
        codec = self._codec(ctx)
        validate = getattr(self.schedule, "validate", None)
        if validate is not None:
            validate(theta, ctx)

        theta = theta.astype(jnp.float32)
        leaves_ax, treedef = jax.tree_util.tree_flatten(
            axes, is_leaf=agg._axes_is_leaf)
        leaves_x = treedef.flatten_up_to(params)
        idx = [i for i, ax in enumerate(leaves_ax) if agg.is_worker_leaf(ax)]

        sched = self.schedule
        # Per-leaf context: the flatten position rides in ctx.leaf_index so
        # stochastic codecs decorrelate identical-content leaves.
        ctxs = {i: dataclasses.replace(ctx, leaf_index=i) for i in idx}
        states = {i: sched.prepare(leaves_x[i], theta, codec, ctxs[i])
                  for i in idx}
        overlap_out = None
        for phase in range(sched.n_phases):
            states = {i: sched.reduce_phase(phase, st, theta, codec, ctxs[i])
                      for i, st in states.items()}
            if phase == 0 and overlap is not None:
                overlap_out = overlap()
        out = list(leaves_x)
        for i in idx:
            out[i] = sched.finalize(states[i], leaves_x[i], theta, beta,
                                    codec, ctxs[i])
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if overlap is None:
            return tree
        return tree, overlap_out

    def __repr__(self):
        return f"ComposedBackend({self.name!r})"


def get_backend(name: str) -> AggregatorBackend:
    if name in _REGISTRY:                 # monolithic one-offs win their name
        return _REGISTRY[name]
    if name == "auto":
        raise KeyError(
            "backend 'auto' is resolved per parameter tree; go through "
            "aggregate_from_config, or call select_auto_spec(params, axes, "
            "mesh) and get_backend the result")
    if name not in _COMPOSED:
        sched_name, codec_name = resolve_spec(name)
        _COMPOSED[name] = ComposedBackend(_SCHEDULES[sched_name], codec_name,
                                          name)
    return _COMPOSED[name]


def available_backends() -> Tuple[str, ...]:
    """Selectable *names* (aliases + monolithic registrations). The full
    composable grid is ``available_specs()``."""
    return tuple(sorted(set(_ALIASES) | set(_REGISTRY)))


def available_schedules() -> Tuple[str, ...]:
    return tuple(sorted(_SCHEDULES))


def available_specs() -> Tuple[str, ...]:
    """Every composable ``schedule:codec`` spec (the composition grid)."""
    out = []
    for s in sorted(_SCHEDULES):
        supported = getattr(_SCHEDULES[s], "codecs", None)
        for c in available_codecs():
            if supported is None or c in supported:
                out.append(f"{s}:{c}")
    return tuple(out)


def aggregate_with(name: str, params: Dict, axes: Dict, theta: jax.Array,
                   beta, *, ctx: AggregationContext = DEFAULT_CONTEXT,
                   overlap: Optional[Callable] = None) -> Dict:
    """One-shot convenience: ``get_backend(name).aggregate(...)``.

    With ``overlap=`` (a nullary compute thunk) the return value is
    ``(params, overlap_result)`` and the thunk's ops are placed between the
    schedule's collective phases (monolithic backends run it after their
    single aggregate call).
    """
    backend = get_backend(name)
    if overlap is None:
        return backend.aggregate(params, axes, theta, beta, ctx=ctx)
    if isinstance(backend, ComposedBackend):
        return backend.aggregate(params, axes, theta, beta, ctx=ctx,
                                 overlap=overlap)
    out = backend.aggregate(params, axes, theta, beta, ctx=ctx)
    return out, overlap()


def aggregate_from_config(wcfg, params: Dict, axes: Dict, theta: jax.Array,
                          *, beta=None, mesh: Optional[Mesh] = None,
                          leaf_fn=None,
                          overlap: Optional[Callable] = None) -> Dict:
    """Apply Eq. 10 with the backend + context a ``WASGDConfig`` selects.

    The single config->backend resolution shared by ``communicate`` and
    ``train/step.py:wasgd_rule`` — every knob (``backend`` spec/legacy
    booleans, ``comm_dtype``, ``n_pods``, ``mesh``) reaches the computation
    through here. ``backend="auto"`` resolves per parameter tree
    (``select_auto_spec``). ``beta`` defaults to ``wcfg.beta``; ``leaf_fn``
    is the legacy escape hatch that bypasses the registry; ``overlap`` is
    the compute thunk threaded between collective phases (returns
    ``(params, overlap_result)`` when set).
    """
    beta = wcfg.beta if beta is None else beta
    if leaf_fn is not None:
        out = agg.weighted_aggregate(params, axes, theta, beta,
                                     leaf_fn=leaf_fn)
        return out if overlap is None else (out, overlap())
    name = backend_name_from_config(wcfg)
    if name == "auto":
        name = select_auto_spec(params, axes, mesh, n_pods=wcfg.n_pods)
    return aggregate_with(name, params, axes, theta, beta,
                          ctx=context_from_config(wcfg, mesh),
                          overlap=overlap)


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------

def backend_name_from_config(wcfg) -> str:
    """Resolve ``WASGDConfig`` to a backend name or composed spec.

    An explicit ``wcfg.backend`` wins. Otherwise the legacy boolean knobs
    COMPOSE instead of shadowing each other: the booleans pick the schedule
    (``hierarchical`` > ``sharded_aggregate`` > einsum, the old priority)
    and ``quantize_comm`` picks the int8 codec on top — so
    ``quantize_comm=True, sharded_aggregate=True`` is ``"rs_ag:int8"``, not
    a silently-dropped mesh schedule. Degenerate combinations fail loud:
    ``hierarchical=True`` with ``n_pods < 2`` used to fall through to the
    flat einsum path without a word; it now raises.
    """
    explicit = getattr(wcfg, "backend", "")
    if explicit:
        return explicit
    sched = "einsum"
    if wcfg.hierarchical:
        if wcfg.n_pods < 2:
            raise ValueError(
                "WASGDConfig(hierarchical=True) with n_pods < 2 is a "
                "degenerate 2-hop (the old resolver silently ran the flat "
                "einsum path instead); set n_pods >= 2 dividing the worker "
                "count, or drop hierarchical=True")
        if wcfg.sharded_aggregate:
            warnings.warn(
                "hierarchical=True and sharded_aggregate=True name two "
                "different schedules; taking 'hierarchical' (the legacy "
                "priority) — set WASGDConfig.backend to an explicit "
                "'<schedule>:<codec>' spec to silence this",
                stacklevel=2)
        sched = "hierarchical"
    elif wcfg.sharded_aggregate:
        sched = "rs_ag"
    if wcfg.quantize_comm:
        return f"{sched}:int8"
    return sched


def context_from_config(wcfg, mesh: Optional[Mesh] = None
                        ) -> AggregationContext:
    return AggregationContext(mesh=mesh,
                              comm_dtype=jnp.dtype(wcfg.comm_dtype),
                              n_pods=wcfg.n_pods)


# ---------------------------------------------------------------------------
# backend="auto": measurement-driven spec selection
# ---------------------------------------------------------------------------

# Anchored to the repo root (this file lives at src/repro/core/), NOT the
# process cwd: the old cwd-relative "results/..." default made
# backend="auto" silently fall back to the size heuristic whenever the
# process wasn't launched from the repo root. The REPRO_BENCH_TABLE env var
# overrides the default per process (read at selection time, so deployments
# can point at a table recorded on the target hardware).
REPO_ROOT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), os.pardir, os.pardir, os.pardir))
AUTO_BENCH_PATH = os.path.join(REPO_ROOT, "results",
                               "BENCH_backend_matrix.json")
BENCH_TABLE_ENV = "REPRO_BENCH_TABLE"


def _default_table_path() -> str:
    return os.environ.get(BENCH_TABLE_ENV) or AUTO_BENCH_PATH


_MISSING_TABLE_WARNED = set()

# Nearest-measurement cutoff (log-space distance over bytes x mesh-size).
# ~3.0 = a ~20x mismatch in the (bytes * devices) product: beyond that a
# recorded point says nothing about this workload and the size heuristic is
# more trustworthy than an extrapolated measurement.
AUTO_MAX_LOG_DIST = 3.0

_AUTO_TABLE_CACHE: Dict = {}


def _load_auto_table(path: str):
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return None
    key = (os.path.abspath(path), mtime)
    if key not in _AUTO_TABLE_CACHE:
        _AUTO_TABLE_CACHE.clear()
        try:
            with open(path) as f:
                _AUTO_TABLE_CACHE[key] = json.load(f).get("records", [])
        except (OSError, ValueError):
            _AUTO_TABLE_CACHE[key] = None
    return _AUTO_TABLE_CACHE[key]


def worker_leaf_bytes(params: Dict, axes: Dict) -> int:
    """Total bytes of the worker-stacked leaves — the collective payload the
    auto-selector sizes the schedule against."""
    leaves_ax, treedef = jax.tree_util.tree_flatten(
        axes, is_leaf=agg._axes_is_leaf)
    leaves_x = treedef.flatten_up_to(params)
    return sum(x.size * jnp.dtype(x.dtype).itemsize
               for x, ax in zip(leaves_x, leaves_ax)
               if agg.is_worker_leaf(ax))


def _worker_dim(params: Dict, axes: Dict) -> Optional[int]:
    """Worker count w from the first worker-stacked leaf (None if none)."""
    leaves_ax, treedef = jax.tree_util.tree_flatten(
        axes, is_leaf=agg._axes_is_leaf)
    leaves_x = treedef.flatten_up_to(params)
    for x, ax in zip(leaves_x, leaves_ax):
        if agg.is_worker_leaf(ax):
            return int(x.shape[0])
    return None


def _spec_runnable(sched_name: str, mesh: Optional[Mesh], n_pods: int,
                   w: Optional[int], require_mask: bool) -> bool:
    """Can this schedule run in the caller's context? The auto-selector must
    never hand back a spec that fails at trace time: mesh schedules need a
    mesh whose worker shards divide w, hierarchical needs pods, and async
    rounds need a masked (late-join) path."""
    sched = _SCHEDULES[sched_name]
    if require_mask and not getattr(sched, "supports_mask", True):
        return False
    if sched_name == "hierarchical" and (
            n_pods < 2 or (w is not None and w % n_pods)):
        return False
    if sched.needs_mesh:
        if mesh is None:
            return False
        if w is not None and w % smagg.mesh_worker_shards(mesh):
            return False
    return True


def select_auto_spec(params: Dict, axes: Dict,
                     mesh: Optional[Mesh] = None,
                     table_path: Optional[str] = None,
                     n_pods: int = 1,
                     require_mask: bool = False) -> str:
    """``backend="auto"``: pick a ``schedule:codec`` spec for this tree.

    Prefers recorded measurements (``benchmarks/kernel_bench.py:
    run_backend_matrix`` -> ``AUTO_BENCH_PATH``, anchored to the repo root;
    override per process with the ``REPRO_BENCH_TABLE`` env var; a missing
    table warns once per path): among non-overlap rows
    whose (payload bytes, mesh size) point is nearest in log-space to this
    tree's, take the fastest spec that can RUN here (``_spec_runnable``:
    mesh schedules need a mesh whose worker shards divide w,
    ``hierarchical`` needs ``n_pods >= 2``, and ``require_mask=True`` — the
    Alg. 4 rounds — excludes schedules without a late-join path). Falls
    back to a size heuristic: small trees are latency-bound (one fused f32
    all-reduce); large trees are bandwidth-bound (halve the ring bytes; on
    a real mesh, expose the rs_ag phases for overlap). Selection is static
    per shapes, so a jitted round resolves it once at trace time.
    """
    table_path = _default_table_path() if table_path is None else table_path
    total = worker_leaf_bytes(params, axes)
    w = _worker_dim(params, axes)
    n_dev = mesh.size if mesh is not None else 1
    records = _load_auto_table(table_path)
    if records is None and table_path not in _MISSING_TABLE_WARNED:
        _MISSING_TABLE_WARNED.add(table_path)
        warnings.warn(
            f"backend='auto': no bench table at {table_path}; falling back "
            f"to the size heuristic. Record one with "
            f"benchmarks/kernel_bench.py run_backend_matrix, or point the "
            f"{BENCH_TABLE_ENV} env var at an existing table.",
            stacklevel=2)
    if records:
        cands = []
        for r in records:
            spec, us = r.get("spec"), r.get("us_per_call")
            if not spec or us is None or r.get("overlap"):
                continue
            try:
                sched_name, _ = resolve_spec(spec)
            except KeyError:
                continue
            if not _spec_runnable(sched_name, mesh, n_pods, w, require_mask):
                continue
            dist = (abs(math.log(max(r.get("total_bytes", 1), 1))
                        - math.log(max(total, 1)))
                    + abs(math.log(max(r.get("mesh_devices", 1), 1))
                          - math.log(max(n_dev, 1))))
            if dist > AUTO_MAX_LOG_DIST:
                # a measurement ~20x away in (bytes x mesh) says nothing
                # about this workload; prefer the heuristic over
                # extrapolating a single far-off point.
                continue
            cands.append((dist, float(us), spec))
        if cands:
            nearest = min(c[0] for c in cands)
            return min((c for c in cands if c[0] <= nearest + 1e-9),
                       key=lambda c: c[1])[2]
    if total < (1 << 22):
        return "einsum:f32"
    if mesh is not None and mesh.size > 1 \
            and _spec_runnable("rs_ag", mesh, n_pods, w, require_mask):
        return "rs_ag:bf16"
    return "einsum:bf16"


__all__ = [
    "AggregationContext", "AggregationSchedule", "AggregatorBackend",
    "ComposedBackend", "DEFAULT_CONTEXT", "AUTO_BENCH_PATH",
    "BENCH_TABLE_ENV", "REPO_ROOT",
    "aggregate_from_config", "aggregate_with", "available_backends",
    "available_codecs", "available_schedules", "available_specs",
    "backend_name_from_config", "canonical_spec", "context_from_config",
    "get_backend", "get_codec", "register_backend", "register_codec",
    "register_schedule", "resolve_spec", "select_auto_spec",
    "worker_leaf_bytes",
]
