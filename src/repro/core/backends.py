"""Aggregation backend registry: one pluggable seam for the Eq. 10 step.

The paper's contribution is a single communication rule,

    x_i  <-  (1 - beta) * x_i  +  beta * sum_j theta_j * x_j        (Eq. 10)

but the repo grows several *implementations* of it — different lowerings,
payload dtypes, and schedules. This module is the seam they all plug into,
in the spirit of ``configs/registry.py``: every implementation is an
``AggregatorBackend`` registered under a string name, selected end-to-end by
``WASGDConfig.backend`` (``core/wasgd.py:communicate``, ``train/step.py``,
``core/async_sim.py``, benchmarks, examples).

Registered backends
===================

``einsum``        The reference. pjit tensordot over the worker axis; XLA
                  derives the theta-weighted all-reduce. Honors
                  ``ctx.comm_dtype`` (bf16 halves ring bytes).
``quantized``     int8 aggregation payload with a per-leaf symmetric scale
                  (~4x fewer collective bytes; quantization error stays
                  local). ``ctx.comm_dtype`` is ignored — the payload is
                  already int8.
``hierarchical``  2-hop reduction: pod-local partial reduce, then a tiny
                  cross-pod reduce so the DCN hop carries pre-reduced
                  partials. Uses ``ctx.n_pods`` and ``ctx.comm_dtype``.
``shard_map``     Explicit ``lax.psum`` under ``shard_map`` — the form to
                  reach for when collective scheduling matters. Requires
                  ``ctx.mesh``.
``rs_ag``         reduce-scatter + local FMA + all-gather schedule. Same
                  ring bytes as one all-reduce, but the payload dtype is
                  pinned to ``ctx.comm_dtype`` (XLA can't re-associate it
                  away) and the phases can overlap with neighboring compute.
                  Requires ``ctx.mesh``.
``pallas_wagg``   Fused Pallas TPU kernel for the local FMA
                  (``kernels/wagg``): one VMEM pass instead of three HBM
                  round trips. Interpret mode on CPU.

``async_einsum`` / ``async_shard_map`` / ``async_rs_ag``
                  Alg. 4 (p-of-(p+b)) counterparts registered by
                  ``core/async_device.py``: theta is masked (stragglers get
                  exactly 0) and inactive workers late-join the aggregate.
                  The activity mask rides in ``ctx.active``; ``None`` means
                  all-active, degenerating to the synchronous update.

Composition rules
=================

The backend name picks the *aggregation rule / schedule*; orthogonal knobs
ride in the ``AggregationContext`` so they compose instead of shadowing each
other:

* ``ctx.comm_dtype``  — payload dtype for the worker-axis collective
  (``einsum``, ``hierarchical``, ``rs_ag``).
* ``ctx.n_pods``      — pod count for the ``hierarchical`` 2-hop.
* ``ctx.mesh``        — physical mesh, required by the ``shard_map`` /
  ``rs_ag`` backends (they place explicit collectives).

``backend_name_from_config`` derives the name from the legacy boolean knobs
(``quantize_comm`` -> ``quantized``, ``hierarchical`` -> ``hierarchical``,
``sharded_aggregate`` -> ``rs_ag``) when ``WASGDConfig.backend`` is unset,
so existing configs select the same computation. One deliberate behavior
change: ``sharded_aggregate=True`` used to be silently ignored outside
``train/step.py``; it now routes to ``rs_ag``, which needs a mesh — pass
``mesh=`` through ``communicate``/``wasgd_rule``/``Trainer``.

Adding a backend
================

    from repro.core.backends import register_backend

    @register_backend("my_sched")
    def _my_sched(params, axes, theta, beta, ctx):
        ...return the updated params tree...

Then set ``WASGDConfig(backend="my_sched")`` — it is immediately selectable
through ``communicate``/``train/step.py`` and picked up by the shared
numerical-parity test (``tests/test_backends.py``) and the
``benchmarks/kernel_bench.py`` backend sweep. Backends that place explicit
collectives should pass ``needs_mesh=True`` so a missing ``ctx.mesh`` fails
with a clear error at trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import aggregate as agg
from repro.core import shardmap_agg as smagg


# ---------------------------------------------------------------------------
# Context + protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregationContext:
    """Orthogonal knobs every backend receives (and may ignore).

    ``mesh``       physical mesh for backends that place explicit collectives.
    ``comm_dtype`` payload dtype riding the worker-axis collective.
    ``n_pods``     pod count for the hierarchical 2-hop.
    ``active``     (w,) bool activity mask for the ``async_*`` family
                   (may be a tracer); ``None`` = all workers active.
    """
    mesh: Optional[Mesh] = None
    comm_dtype: Any = jnp.float32
    n_pods: int = 1
    active: Optional[jax.Array] = None


DEFAULT_CONTEXT = AggregationContext()


@runtime_checkable
class AggregatorBackend(Protocol):
    """One implementation of the Eq. 10 communication step."""
    name: str
    needs_mesh: bool

    def aggregate(self, params: Dict, axes: Dict, theta: jax.Array,
                  beta, *, ctx: AggregationContext = DEFAULT_CONTEXT) -> Dict:
        ...


class _FnBackend:
    """Adapter turning a plain ``fn(params, axes, theta, beta, ctx)`` into an
    ``AggregatorBackend``."""

    def __init__(self, name: str, fn: Callable, needs_mesh: bool = False):
        self.name = name
        self.needs_mesh = needs_mesh
        self._fn = fn

    def aggregate(self, params, axes, theta, beta, *,
                  ctx: AggregationContext = DEFAULT_CONTEXT):
        if self.needs_mesh and ctx.mesh is None:
            raise ValueError(
                f"aggregation backend {self.name!r} places explicit "
                f"collectives and needs ctx.mesh (pass mesh= through "
                f"communicate/wasgd_rule, or use the 'einsum' family)")
        return self._fn(params, axes, theta, beta, ctx)

    def __repr__(self):
        return f"AggregatorBackend({self.name!r})"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, AggregatorBackend] = {}


def register_backend(name: str, fn: Optional[Callable] = None, *,
                     needs_mesh: bool = False, overwrite: bool = False):
    """Register an aggregation backend under ``name``.

    Usable as a decorator (``@register_backend("einsum")``) over a function
    ``fn(params, axes, theta, beta, ctx)``, or called directly with an object
    already satisfying the ``AggregatorBackend`` protocol.
    """
    def _register(obj):
        if name in _REGISTRY and not overwrite:
            raise ValueError(f"aggregation backend {name!r} already "
                             f"registered; pass overwrite=True to replace")
        if hasattr(obj, "aggregate"):
            backend = obj
            if needs_mesh and not getattr(obj, "needs_mesh", False):
                # honor needs_mesh for object backends too: wrap so the
                # promised clear missing-mesh error fires at trace time.
                backend = _FnBackend(
                    name,
                    lambda p, a, t, b, ctx: obj.aggregate(p, a, t, b,
                                                          ctx=ctx),
                    needs_mesh=True)
        else:
            backend = _FnBackend(name, obj, needs_mesh=needs_mesh)
        _REGISTRY[name] = backend
        return obj

    if fn is not None:
        return _register(fn)
    return _register


def get_backend(name: str) -> AggregatorBackend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown aggregation backend {name!r}; "
                       f"known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def aggregate_with(name: str, params: Dict, axes: Dict, theta: jax.Array,
                   beta, *, ctx: AggregationContext = DEFAULT_CONTEXT) -> Dict:
    """One-shot convenience: ``get_backend(name).aggregate(...)``."""
    return get_backend(name).aggregate(params, axes, theta, beta, ctx=ctx)


def aggregate_from_config(wcfg, params: Dict, axes: Dict, theta: jax.Array,
                          *, beta=None, mesh: Optional[Mesh] = None,
                          leaf_fn=None) -> Dict:
    """Apply Eq. 10 with the backend + context a ``WASGDConfig`` selects.

    The single config→backend resolution shared by ``communicate`` and
    ``train/step.py:wasgd_rule`` — every knob (``backend``/legacy booleans,
    ``comm_dtype``, ``n_pods``, ``mesh``) reaches the computation through
    here. ``beta`` defaults to ``wcfg.beta``; ``leaf_fn`` is the legacy
    escape hatch that bypasses the registry.
    """
    beta = wcfg.beta if beta is None else beta
    if leaf_fn is not None:
        return agg.weighted_aggregate(params, axes, theta, beta,
                                      leaf_fn=leaf_fn)
    return aggregate_with(backend_name_from_config(wcfg), params, axes,
                          theta, beta, ctx=context_from_config(wcfg, mesh))


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------

def backend_name_from_config(wcfg) -> str:
    """Resolve ``WASGDConfig`` to a backend name.

    An explicit ``wcfg.backend`` wins; otherwise the legacy boolean knobs
    derive it (mutual priority: quantized > hierarchical > rs_ag > einsum,
    matching the old if/elif sprawl in ``core/aggregate.py``).
    """
    explicit = getattr(wcfg, "backend", "")
    if explicit:
        return explicit
    if wcfg.quantize_comm:
        return "quantized"
    if wcfg.hierarchical and wcfg.n_pods > 1:
        return "hierarchical"
    if wcfg.sharded_aggregate:
        return "rs_ag"
    return "einsum"


def context_from_config(wcfg, mesh: Optional[Mesh] = None
                        ) -> AggregationContext:
    return AggregationContext(mesh=mesh,
                              comm_dtype=jnp.dtype(wcfg.comm_dtype),
                              n_pods=wcfg.n_pods)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

@register_backend("einsum")
def _einsum(params, axes, theta, beta, ctx):
    return agg.weighted_aggregate(params, axes, theta, beta,
                                  comm_dtype=ctx.comm_dtype)


@register_backend("quantized")
def _quantized(params, axes, theta, beta, ctx):
    return agg.weighted_aggregate(params, axes, theta, beta, quantize=True)


@register_backend("hierarchical")
def _hierarchical(params, axes, theta, beta, ctx):
    # Fail clear (like needs_mesh) instead of silently taking the flat
    # einsum path: aggregate_leaf's n_pods guard would otherwise swallow a
    # misconfigured 2-hop and run a different computation without warning.
    w = theta.shape[0]
    if ctx.n_pods < 2 or w % ctx.n_pods:
        raise ValueError(
            f"'hierarchical' backend needs ctx.n_pods >= 2 dividing the "
            f"worker count (got n_pods={ctx.n_pods}, workers={w}); set "
            f"WASGDConfig.n_pods or use the 'einsum' backend")
    return agg.weighted_aggregate(params, axes, theta, beta,
                                  comm_dtype=ctx.comm_dtype,
                                  n_pods=ctx.n_pods)


@register_backend("shard_map", needs_mesh=True)
def _shard_map(params, axes, theta, beta, ctx):
    return smagg.weighted_aggregate_shard_map(params, axes, theta, beta,
                                              ctx.mesh,
                                              schedule="all_reduce")


@register_backend("rs_ag", needs_mesh=True)
def _rs_ag(params, axes, theta, beta, ctx):
    return smagg.weighted_aggregate_shard_map(params, axes, theta, beta,
                                              ctx.mesh, schedule="rs_ag",
                                              comm_dtype=ctx.comm_dtype)


@register_backend("pallas_wagg")
def _pallas_wagg(params, axes, theta, beta, ctx):
    from repro.kernels.wagg.ops import wagg_leaf   # lazy: kernels are optional
    return agg.weighted_aggregate(params, axes, theta, beta,
                                  leaf_fn=wagg_leaf)


__all__ = [
    "AggregationContext", "AggregatorBackend", "DEFAULT_CONTEXT",
    "aggregate_from_config", "aggregate_with", "available_backends",
    "backend_name_from_config", "context_from_config", "get_backend",
    "register_backend",
]
