"""Worker assessment — the **policy** axis of the aggregation API.

The paper's decentralized scheme stands on its weight evaluating function:
given per-worker loss energies ``h`` (shape ``(p,)``), produce normalized
aggregation weights ``theta`` (summing to 1). WASGD+ *is* WASGD with a
better one (Boltzmann, Eq. 13), and the design space is wider than one
scalar knob — so worker assessment is a registered, composable axis
(schedule x codec x **policy**), mirroring ``core/backends.py``.

Weight evaluating functions of the paper (the stateless *kernels*)
==================================================================

* ``boltzmann`` (WASGD+, Eq. 13): theta_i = softmax(-a_tilde * h_i / sum(h))
  — Property 1: a→0 gives equal weights, a→inf broadcasts the best worker.
* ``inverse`` (WASGD v1, Alg. 3): theta_i ∝ 1 / h_i.
* ``equal``: theta_i = 1/p (SimuParallelSGD-style averaging).
* ``best``: one-hot on the minimum energy (the a→inf limit).

The ``WeightPolicy`` protocol
=============================

A policy is a jit-traceable, optionally *stateful* assessment of the
workers::

    state          = policy.init_state(p)                  # a pytree
    theta, state   = policy(h, active, state, t)           # traced

``h`` is the ``(p,)`` energy vector, ``active`` an optional ``(p,)`` bool
mask (Alg. 4 rounds; ``None`` = everyone), ``state`` the policy's pytree
(``()`` when stateless) and ``t`` an optional round index (``None`` = read
the counter the state carries). Policy state rides ``comm_state`` through
the train step exactly like the Alg. 4 activity mask already does.

Spec grammar (``WASGDConfig.policy``)
=====================================

::

    spec   := stage ("|" stage)*
    stage  := name [ "(" arg ("," arg)* ")" ]
    arg    := [key "="] value          # ints / floats / bools / bare words

e.g. ``"boltzmann(a=8)|anneal(cosine)"``, ``"ema(0.9)|time_aware"``,
``"trimmed(1)|boltzmann(a=4)"``. Stages compose by *role* (the written
order only sequences stages of the same role):

``kernel``    boltzmann(a=) | inverse | equal | best — the weight
              evaluating function mapping (possibly transformed) energies
              to theta. At most one per spec; omitted -> ``boltzmann``
              with the config's ``a_tilde``.
``energy``    transforms of ``h`` before the kernel sees it:
              ``ema(decay=0.9)`` — per-worker EMA-smoothed energies (a
              stale-robust Eq. 26 estimate; bias-corrected, masked
              updates); ``time_aware(gamma=1.0)`` — scales energies by
              measured per-device round times (slow worker -> inflated
              energy -> smaller weight; Cheng et al. 2017 speed
              weighting), fed by ``observe_times``.
``mask``      refinements of the active set, robust to outlier workers:
              ``topk(k)`` — only the k lowest-energy active workers get
              weight; ``trimmed(k=1)`` — drop the k lowest AND k highest
              energy active workers (guarded: a round too small to trim
              keeps its mask).
``modifier``  ``anneal(kind, rate=, period=, peak=)`` — schedules the
              kernel's ``a`` over rounds t (the paper's equal→best
              Property 1 interpolation as a curriculum): ``linear``
              (a*(1+rate*t), the legacy ``a_schedule="anneal"``), ``exp``
              (a*e^{rate*t}), ``cosine`` (half-cosine ramp from a to
              a*peak over ``period`` rounds).

Legacy aliases (byte-for-byte identical theta)
==============================================

``WASGDConfig.strategy``/``a_tilde`` resolve through the same registry:
``strategy="boltzmann", a_tilde=x`` is the policy ``boltzmann(a=x)``;
``a_schedule="anneal"`` appends ``|anneal(linear, rate=anneal_rate)``. The
stateless kernels call the SAME free functions as always, so legacy configs
are bitwise-identical (tests/test_policy.py holds them to it).

Extending the axis::

    from repro.core.weights import register_policy

    @register_policy
    class MyTransform:
        name = "my_transform"
        role = "energy"            # kernel | energy | mask | modifier
        stateful = False
        def transform(self, h, active, state, t): return h, state

Every spec mentioning ``my_transform`` becomes selectable through
``WASGDConfig.policy`` and is validated at config construction.
"""
from __future__ import annotations

import inspect
import re
from typing import Any, Dict, List, Optional, Protocol, Tuple, \
    runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp

STRATEGIES = ("boltzmann", "inverse", "equal", "best")

POLICY_ROLES = ("kernel", "energy", "mask", "modifier")


# ---------------------------------------------------------------------------
# The paper's weight evaluating functions (stateless reference ops)
# ---------------------------------------------------------------------------

def normalize_energy(h: jax.Array) -> jax.Array:
    """h'_i = h_i / sum_j h_j (Eq. 12 normalization)."""
    h = h.astype(jnp.float32)
    return h / jnp.maximum(h.sum(), 1e-30)


def boltzmann_weights(h: jax.Array, a_tilde: float) -> jax.Array:
    """Eq. 13 — the Boltzmann weight evaluating function of WASGD+."""
    return jax.nn.softmax(-a_tilde * normalize_energy(h))


def inverse_weights(h: jax.Array) -> jax.Array:
    """WASGD v1: theta_i = (1/h_i) / sum_j (1/h_j)."""
    inv = 1.0 / jnp.maximum(h.astype(jnp.float32), 1e-30)
    return inv / inv.sum()


def equal_weights(p: int) -> jax.Array:
    return jnp.full((p,), 1.0 / p, jnp.float32)


def best_weights(h: jax.Array) -> jax.Array:
    return jax.nn.one_hot(jnp.argmin(h), h.shape[0], dtype=jnp.float32)


# ---------------------------------------------------------------------------
# All-False masks: reject early where the values are visible
# ---------------------------------------------------------------------------

def no_active_error() -> ValueError:
    """The shared empty-round error: host and device paths fail identically
    (``validate_active_rounds`` raises the per-round form of the same)."""
    return ValueError(
        "no active worker: an all-False activity mask has no Alg. 4 "
        "aggregate to late-join (masked theta would be the softmax of an "
        "all -inf row -> NaN); every round needs >= 1 active worker")


def _reject_concrete_all_false(active) -> None:
    """Raise ``no_active_error`` when a CONCRETE mask is all-False.

    Traced masks cannot be inspected (their values only exist at run time),
    so inside jit the documented contract stands: an all-False round yields
    NaNs rather than silently invented weights. Everywhere the mask is a
    host value — the numpy oracle, eager calls, schedule injection — the
    config error surfaces HERE, at the same point of the program, instead
    of as a numerical curiosity rounds later.
    """
    try:
        concrete = np.asarray(active)
    except Exception:                      # tracer: no values to check
        return
    if concrete.size and not concrete.any():
        raise no_active_error()


# ---------------------------------------------------------------------------
# Policy protocol + stage registry
# ---------------------------------------------------------------------------

@runtime_checkable
class WeightPolicy(Protocol):
    """One worker-assessment policy: stateful, jit-traceable theta."""

    name: str
    stateful: bool

    def init_state(self, p: int) -> Any:
        ...

    def __call__(self, h: jax.Array, active: Optional[jax.Array] = None,
                 state: Any = None, t: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Any]:
        ...

    def expand_state(self, state: Any, new_p: int) -> Any:
        """Re-shard the policy state across a membership resize
        (core/membership.py): worker ``i`` keeps slot ``i`` for
        ``i < min(old_p, new_p)``, a shrink drops the tail, and newcomers
        are re-initialized **from the aggregate** of the surviving workers
        — so EMA/time/anneal state survives elastic membership instead of
        resetting to round 0."""
        ...


_STAGES: Dict[str, type] = {}


def register_policy(cls=None, *, overwrite: bool = False):
    """Register a policy stage class by its ``name`` (usable as decorator).

    The class declares ``role`` (kernel | energy | mask | modifier) and the
    role's method (``weights`` / ``transform`` / ``refine`` / ``factor``);
    its ``__init__`` keywords become the stage's spec arguments.
    """
    def _register(c):
        name = getattr(c, "name", None)
        role = getattr(c, "role", None)
        if not name or role not in POLICY_ROLES:
            raise ValueError(
                f"policy stage {c!r} needs a `name` and a `role` in "
                f"{POLICY_ROLES}")
        if name in _STAGES and not overwrite:
            raise ValueError(f"weight policy {name!r} already registered; "
                             f"pass overwrite=True to replace")
        _STAGES[name] = c
        return c

    return _register(cls) if cls is not None else _register


def available_policies() -> Tuple[str, ...]:
    """Registered stage names (the vocabulary of the policy spec grammar)."""
    return tuple(sorted(_STAGES))


# ---------------------------------------------------------------------------
# Kernels (role "kernel"): the four paper strategies, masked + unmasked
# ---------------------------------------------------------------------------

@register_policy
class Boltzmann:
    """Eq. 13. ``a=None`` inherits the config's ``a_tilde`` at resolution."""
    name = "boltzmann"
    role = "kernel"
    stateful = False
    uses_a = True

    def __init__(self, a: Optional[float] = None):
        self.a = None if a is None else float(a)

    def weights(self, h, active, a):
        if active is None:
            return boltzmann_weights(h, a)
        # normalize over the ACTIVE energies, then softmax with inactive
        # logits at -inf == softmax over the compacted active subset.
        h = h.astype(jnp.float32)
        m = active.astype(jnp.float32)
        hn = h / jnp.maximum((m * h).sum(), 1e-30)
        return jax.nn.softmax(jnp.where(active, -a * hn, -jnp.inf))


@register_policy
class Inverse:
    name = "inverse"
    role = "kernel"
    stateful = False
    uses_a = False

    def weights(self, h, active, a):
        if active is None:
            return inverse_weights(h)
        h = h.astype(jnp.float32)
        inv = active.astype(jnp.float32) / jnp.maximum(h, 1e-30)
        return inv / jnp.maximum(inv.sum(), 1e-30)


@register_policy
class Equal:
    name = "equal"
    role = "kernel"
    stateful = False
    uses_a = False

    def weights(self, h, active, a):
        if active is None:
            return equal_weights(h.shape[0])
        m = active.astype(jnp.float32)
        return m / jnp.maximum(m.sum(), 1.0)


@register_policy
class Best:
    name = "best"
    role = "kernel"
    stateful = False
    uses_a = False

    def weights(self, h, active, a):
        if active is None:
            return best_weights(h)
        # argmin over active energies; ties break to the first active worker,
        # matching jnp.argmin over the compacted subset. An all-False mask
        # yields NaNs (0/0) like the other kernels, not a silent one-hot
        # on argmin-of-all-inf (worker 0).
        h = h.astype(jnp.float32)
        m = active.astype(jnp.float32)
        oh = jax.nn.one_hot(jnp.argmin(jnp.where(active, h, jnp.inf)),
                            h.shape[0], dtype=jnp.float32) * m
        return oh / oh.sum()


def _kernel(strategy: str):
    cls = _STAGES.get(strategy)
    if cls is None or getattr(cls, "role", None) != "kernel":
        kernels = [n for n, c in sorted(_STAGES.items())
                   if getattr(c, "role", None) == "kernel"]
        raise ValueError(f"unknown weighting strategy {strategy!r}; "
                         f"registered kernel policies: {kernels}")
    return cls()


# ---------------------------------------------------------------------------
# Energy transforms (role "energy")
# ---------------------------------------------------------------------------

@register_policy
class Ema:
    """Per-worker EMA over the loss energies — a stale-robust Eq. 26
    estimate: one noisy round no longer swings theta, and a worker's weight
    reflects its trajectory. Bias-corrected (round 0 evaluates to the raw
    energy); inactive workers' averages freeze (masked update), so a
    straggler re-joins with its pre-exclusion estimate intact."""
    name = "ema"
    role = "energy"
    stateful = True

    def __init__(self, decay: float = 0.9):
        decay = float(decay)
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"ema decay must be in [0, 1), got {decay}")
        self.decay = decay

    def init_state(self, p: int):
        return {"h_bar": jnp.zeros((p,), jnp.float32),
                "n": jnp.zeros((p,), jnp.float32)}

    def transform(self, h, active, state, t):
        h = h.astype(jnp.float32)
        m = (jnp.ones(h.shape, jnp.float32) if active is None
             else active.astype(jnp.float32))
        n = state["n"] + m
        h_bar = jnp.where(m > 0,
                          self.decay * state["h_bar"] + (1 - self.decay) * h,
                          state["h_bar"])
        corr = 1.0 - self.decay ** jnp.maximum(n, 1.0)
        h_hat = jnp.where(n > 0, h_bar / jnp.maximum(corr, 1e-30), h)
        return h_hat, {"h_bar": h_bar, "n": n}

    def expand_state(self, state, new_p: int):
        """Newcomers adopt the aggregate estimate: the mean accumulator
        (and observation count) over the surviving workers that have seen
        at least one round — a joiner weighs in with the fleet's consensus
        energy history, not a fresh round-0 estimate. If no survivor has
        observations the newcomers start fresh (zeros)."""
        h_bar, n = state["h_bar"], state["n"]
        old_p = h_bar.shape[0]
        if new_p <= old_p:
            return {"h_bar": h_bar[:new_p], "n": n[:new_p]}
        seen = n > 0
        denom = jnp.maximum(seen.sum(), 1).astype(jnp.float32)
        agg_h = jnp.where(seen, h_bar, 0.0).sum() / denom
        agg_n = jnp.where(seen, n, 0.0).sum() / denom
        grow = new_p - old_p
        return {"h_bar": jnp.concatenate(
                    [h_bar, jnp.full((grow,), agg_h, jnp.float32)]),
                "n": jnp.concatenate(
                    [n, jnp.full((grow,), agg_n, jnp.float32)])}


@register_policy
class TimeAware:
    """Weight workers by *measured speed* (Cheng et al. 2017): energies are
    scaled by ``(round_time / mean_active_round_time) ** gamma``, so a slow
    worker's energy inflates and its theta shrinks. The times come from
    ``observe_times`` — the on-device async driver records per-device round
    times and feeds them here (``run_parallel_sgd_on_device(
    measure_times=True)``), retiring the host ``StepTimeModel`` as the only
    signal. Until the first observation the transform is the identity."""
    name = "time_aware"
    role = "energy"
    stateful = True

    def __init__(self, gamma: float = 1.0):
        self.gamma = float(gamma)

    def init_state(self, p: int):
        return {"times": jnp.ones((p,), jnp.float32),
                "seen": jnp.zeros((), bool)}

    def transform(self, h, active, state, t):
        h = h.astype(jnp.float32)
        tm = state["times"]
        m = (jnp.ones(h.shape, jnp.float32) if active is None
             else active.astype(jnp.float32))
        mean = (m * tm).sum() / jnp.maximum(m.sum(), 1.0)
        scale = (tm / jnp.maximum(mean, 1e-30)) ** self.gamma
        return jnp.where(state["seen"], h * scale, h), state

    def observe(self, state, times):
        return {"times": jnp.asarray(times, jnp.float32),
                "seen": jnp.ones((), bool)}

    def expand_state(self, state, new_p: int):
        """Newcomers adopt the mean measured round time of the survivors
        (the aggregate speed estimate) until their own first observation;
        the ``seen`` flag is fleet-wide and carries over."""
        tm = state["times"]
        old_p = tm.shape[0]
        if new_p <= old_p:
            return {"times": tm[:new_p], "seen": state["seen"]}
        fill = jnp.full((new_p - old_p,), tm.mean(), jnp.float32)
        return {"times": jnp.concatenate([tm, fill]), "seen": state["seen"]}


# ---------------------------------------------------------------------------
# Mask refinements (role "mask"): robust to outlier workers
# ---------------------------------------------------------------------------

def _as_mask(h, active):
    return (jnp.ones(h.shape, bool) if active is None
            else active.astype(bool))


def _active_ranks(h, act):
    """Rank of each worker by energy among the ACTIVE set (stable ties);
    inactive workers rank past every active one."""
    key = jnp.where(act, h.astype(jnp.float32), jnp.inf)
    order = jnp.argsort(key, stable=True)
    return jnp.argsort(order, stable=True)


@register_policy
class TopK:
    """Keep only the k lowest-energy active workers (theta = 0 elsewhere).
    Rounds with fewer than k active workers keep them all."""
    name = "topk"
    role = "mask"
    stateful = False

    def __init__(self, k: int):
        k = int(k)
        if k < 1:
            raise ValueError(f"topk needs k >= 1, got {k}")
        self.k = k

    def refine(self, h, active):
        act = _as_mask(h, active)
        return act & (_active_ranks(h, act) < self.k)


@register_policy
class Trimmed:
    """Drop the k highest AND k lowest energy active workers before
    weighting — robust to both failure outliers (diverging loss) and
    too-good-to-be-true ones (a corrupted shard scoring near zero). A round
    with <= 2k active workers is left untrimmed rather than emptied."""
    name = "trimmed"
    role = "mask"
    stateful = False

    def __init__(self, k: int = 1):
        k = int(k)
        if k < 1:
            raise ValueError(f"trimmed needs k >= 1, got {k}")
        self.k = k

    def refine(self, h, active):
        act = _as_mask(h, active)
        ranks = _active_ranks(h, act)
        n_act = act.sum()
        keep = act & (ranks >= self.k) & (ranks < n_act - self.k)
        return jnp.where(n_act > 2 * self.k, keep, act)


# ---------------------------------------------------------------------------
# Kernel modifiers (role "modifier")
# ---------------------------------------------------------------------------

@register_policy
class Anneal:
    """Schedule the kernel's ``a`` over rounds t — the paper's Property 1
    interpolation (a→0 equal, a→inf best) as an explore→exploit curriculum.

    ``linear``  a * (1 + rate*t)           (the legacy ``a_schedule``)
    ``exp``     a * e^{rate*t}
    ``cosine``  a * (1 + (peak-1) * (1 - cos(pi * min(t/period, 1))) / 2)
                — smooth ramp from a to a*peak over ``period`` rounds.
    """
    name = "anneal"
    role = "modifier"
    stateful = True                      # needs the round counter t
    KINDS = ("linear", "exp", "cosine")

    def __init__(self, kind: str = "linear", rate: float = 0.05,
                 period: float = 100.0, peak: float = 100.0):
        if kind not in self.KINDS:
            raise ValueError(f"unknown anneal kind {kind!r}; "
                             f"known: {self.KINDS}")
        self.kind = kind
        self.rate = float(rate)
        self.period = float(period)
        self.peak = float(peak)

    def factor(self, t):
        t = jnp.asarray(t, jnp.float32)
        if self.kind == "linear":
            return 1.0 + self.rate * t
        if self.kind == "exp":
            return jnp.exp(self.rate * t)
        frac = jnp.clip(t / self.period, 0.0, 1.0)
        return 1.0 + (self.peak - 1.0) * 0.5 * (1.0 - jnp.cos(jnp.pi * frac))


# ---------------------------------------------------------------------------
# The composed pipeline policy
# ---------------------------------------------------------------------------

class PipelinePolicy:
    """A parsed policy spec: energy transforms -> mask refinements -> one
    (annealed) kernel. Fully jit-traceable; state is a flat dict keyed by
    stage position (``()`` when every stage is stateless), carrying the
    round counter ``t`` whenever a modifier needs it.
    """

    def __init__(self, stages: List[Any], default_a: float = 1.0,
                 spec: Optional[str] = None):
        kernels = [s for s in stages if s.role == "kernel"]
        if len(kernels) > 1:
            raise ValueError(
                f"policy spec names {len(kernels)} kernels "
                f"({[k.name for k in kernels]}); compose at most one "
                f"weight evaluating function per spec")
        self.kernel = kernels[0] if kernels else Boltzmann()
        self.energy_stages = [s for s in stages if s.role == "energy"]
        self.mask_stages = [s for s in stages if s.role == "mask"]
        self.modifiers = [s for s in stages if s.role == "modifier"]
        if self.modifiers and not getattr(self.kernel, "uses_a", False):
            raise ValueError(
                f"'{self.modifiers[0].name}' schedules the kernel's 'a', "
                f"but kernel '{self.kernel.name}' takes none; use the "
                f"'boltzmann' kernel (or drop the modifier)")
        a = getattr(self.kernel, "a", None)
        self.a = float(default_a) if a is None else float(a)
        self._needs_t = any(getattr(m, "stateful", False)
                            for m in self.modifiers)
        self.stateful = self._needs_t or any(
            getattr(s, "stateful", False)
            for s in self.energy_stages + self.mask_stages)
        self.name = spec if spec is not None else "|".join(
            s.name for s in stages) or self.kernel.name
        self.spec = self.name

    def _stage_key(self, i: int, stage) -> str:
        return f"s{i}_{stage.name}"

    def init_state(self, p: int):
        st = {}
        for i, s in enumerate(self.energy_stages):
            if getattr(s, "stateful", False):
                st[self._stage_key(i, s)] = s.init_state(p)
        if self._needs_t:
            st["t"] = jnp.zeros((), jnp.float32)
        return st if st else ()

    def __call__(self, h, active=None, state=None, t=None):
        h = jnp.asarray(h)
        if active is not None:
            _reject_concrete_all_false(active)
        if state is None or (isinstance(state, tuple) and not state):
            state = self.init_state(h.shape[0])   # fresh/empty -> round 0
        st = dict(state) if isinstance(state, dict) else {}
        if t is None:
            t = st.get("t", jnp.zeros((), jnp.float32))
        for i, s in enumerate(self.energy_stages):
            key = self._stage_key(i, s)
            h, sub = s.transform(h, active, st.get(key), t)
            if getattr(s, "stateful", False):
                st[key] = sub
        act = None if active is None else active.astype(bool)
        for s in self.mask_stages:
            act = s.refine(h, act)
        a_eff = self.a
        for m in self.modifiers:
            a_eff = a_eff * m.factor(t)
        theta = self.kernel.weights(h, act, a_eff)
        if self._needs_t:
            st["t"] = jnp.asarray(t, jnp.float32) + 1.0
        return theta, (st if st else ())

    def observe_times(self, state, times):
        """Feed measured per-device round times to the stages that consume
        them (``time_aware``); a no-op for every other pipeline."""
        if not isinstance(state, dict):
            return state
        st = dict(state)
        for i, s in enumerate(self.energy_stages):
            key = self._stage_key(i, s)
            if hasattr(s, "observe") and key in st:
                st[key] = s.observe(st[key], times)
        return st

    def expand_state(self, state, new_p: int):
        """Re-shard the composed policy state across a membership resize
        (``WorkerSet.resize`` — core/membership.py): each stateful stage's
        per-worker arrays keep the survivors' slots (bitwise) and fill
        newcomer slots from the stage's aggregate (the stage's own
        ``expand_state``, or the generic survivor-mean fallback); the round
        counter ``t`` — fleet state, not per-worker — carries over, so an
        anneal curriculum does not restart when membership changes."""
        if not isinstance(state, dict) or not state:
            return state                         # () — stateless pipeline
        st = dict(state)
        for i, s in enumerate(self.energy_stages):
            key = self._stage_key(i, s)
            if key not in st:
                continue
            if hasattr(s, "expand_state"):
                st[key] = s.expand_state(st[key], new_p)
            else:
                st[key] = _generic_expand_state(st[key], new_p)
        return st

    def __repr__(self):
        return f"WeightPolicy({self.spec!r})"


def _generic_expand_state(sub, new_p: int):
    """Fallback per-stage resize for custom stateful stages that declare no
    ``expand_state``: every array leaf is treated as per-worker along its
    leading dim — survivors keep slots, newcomers get the survivor mean;
    rank-0 leaves (counters, flags) pass through as fleet state."""
    def visit(x):
        x = jnp.asarray(x)
        if x.ndim == 0:
            return x
        old_p = x.shape[0]
        if new_p <= old_p:
            return x[:new_p]
        fill = jnp.broadcast_to(
            x.astype(jnp.float32).mean(axis=0)[None],
            (new_p - old_p,) + x.shape[1:]).astype(x.dtype)
        return jnp.concatenate([x, fill], axis=0)

    return jax.tree.map(visit, sub)


# ---------------------------------------------------------------------------
# Spec parsing + config resolution
# ---------------------------------------------------------------------------

_STAGE_RE = re.compile(r"([A-Za-z_]\w*)\s*(?:\((.*)\))?\s*$", re.S)


def _parse_value(tok: str):
    tok = tok.strip()
    low = tok.lower()
    if low in ("true", "false"):
        return low == "true"
    for conv in (int, float):
        try:
            return conv(tok)
        except ValueError:
            pass
    return tok


def _parse_args(argstr: Optional[str]):
    args, kwargs = [], {}
    if not argstr or not argstr.strip():
        return args, kwargs
    for tok in argstr.split(","):
        if "=" in tok:
            k, v = tok.split("=", 1)
            kwargs[k.strip()] = _parse_value(v)
        else:
            if kwargs:
                raise ValueError(
                    f"positional policy argument {tok.strip()!r} after a "
                    f"keyword argument")
            args.append(_parse_value(tok))
    return args, kwargs


def parse_policy(spec: str, default_a: float = 1.0) -> PipelinePolicy:
    """Parse a policy spec string into a ``PipelinePolicy``.

    Raises ``ValueError`` naming the registered policies on an unknown
    stage, and on malformed arguments — at parse time, i.e. at config
    construction, not deep inside tracing.
    """
    stages = []
    for part in spec.split("|"):
        part = part.strip()
        m = _STAGE_RE.match(part) if part else None
        if m is None:
            raise ValueError(
                f"malformed stage {part!r} in policy spec {spec!r}; "
                f"expected 'name' or 'name(arg, key=value, ...)'")
        name, argstr = m.group(1), m.group(2)
        cls = _STAGES.get(name)
        if cls is None:
            raise ValueError(
                f"unknown weight policy {name!r} in spec {spec!r}; "
                f"registered policies: {list(available_policies())}")
        args, kwargs = _parse_args(argstr)
        try:
            stage = cls(*args, **kwargs)
        except TypeError as e:
            sig = str(inspect.signature(cls.__init__)).replace("self, ", "") \
                .replace("self", "")
            raise ValueError(
                f"bad arguments for policy stage {part!r}: {e}; "
                f"{name} takes {sig}") from None
        stages.append(stage)
    return PipelinePolicy(stages, default_a=default_a, spec=spec)


def as_policy(policy, default_a: float = 1.0) -> WeightPolicy:
    """Spec string -> parsed pipeline; a policy object passes through."""
    if isinstance(policy, str):
        return parse_policy(policy, default_a=default_a)
    if isinstance(policy, WeightPolicy):
        return policy
    raise TypeError(f"expected a policy spec string or a WeightPolicy, "
                    f"got {type(policy).__name__}")


def policy_from_config(wcfg) -> PipelinePolicy:
    """Resolve a ``WASGDConfig``-shaped object to its ``WeightPolicy``.

    An explicit ``wcfg.policy`` spec wins (its kernel's missing ``a``
    defaults to ``wcfg.a_tilde``). Otherwise the legacy knobs alias in:
    ``strategy``/``a_tilde`` select the bare kernel, and
    ``a_schedule="anneal"`` appends the linear anneal modifier (only where
    the kernel has an ``a`` to anneal — matching the legacy rule, where the
    schedule was a no-op for a-less strategies).
    """
    spec = getattr(wcfg, "policy", "") or ""
    a = float(getattr(wcfg, "a_tilde", 1.0))
    if spec:
        return parse_policy(spec, default_a=a)
    strategy = getattr(wcfg, "strategy", "boltzmann")
    kernel_cls = _STAGES.get(strategy)
    if kernel_cls is None or getattr(kernel_cls, "role", None) != "kernel":
        _kernel(strategy)                          # raises the listing error
    if getattr(wcfg, "a_schedule", "constant") == "anneal" \
            and getattr(kernel_cls, "uses_a", False):
        rate = float(getattr(wcfg, "anneal_rate", 0.05))
        return parse_policy(f"{strategy}|anneal(linear, rate={rate})",
                            default_a=a)
    return parse_policy(strategy, default_a=a)


def validate_config_spec(strategy: str, policy: str = "") -> None:
    """Config-construction-time validation (``WASGDConfig.__post_init__``):
    an unknown strategy or unparsable policy spec fails HERE with the
    registered policy names, not deep inside tracing."""
    _kernel(strategy)
    if policy:
        parse_policy(policy)


# ---------------------------------------------------------------------------
# Legacy entry points (the stateless kernels, unchanged signatures)
# ---------------------------------------------------------------------------

def compute_theta(h: jax.Array, strategy: str = "boltzmann",
                  a_tilde: float = 1.0) -> jax.Array:
    return _kernel(strategy).weights(h, None, a_tilde)


def masked_compute_theta(h: jax.Array, active: jax.Array,
                         a_tilde: float = 1.0,
                         strategy: str = "boltzmann") -> jax.Array:
    """θ over the active workers only; exactly 0 for inactive ones.

    Traced counterpart of ``compute_theta(h[active])`` scattered back to the
    full worker width: ``active`` is a ``(p,)`` boolean *array* (it may be a
    tracer), so the p-of-(p+b) weighting of Alg. 4 jits as part of one
    on-device round (core/async_device.py). Inactive energies are excluded
    BEFORE the Eq. 12 normalization — see ``async_sim.masked_theta`` for why
    a sentinel-energy approach degenerates the Boltzmann weights. The
    signature deliberately mirrors that host-side twin's
    ``(losses, active, a_tilde, strategy)`` order.

    At least one worker must be active. A *concrete* all-False mask is
    rejected eagerly with the same error the async drivers raise at
    schedule injection (``validate_active_rounds``); a traced all-False
    mask — invisible until run time — keeps the documented contract of
    yielding NaNs (e.g. the softmax of an all ``-inf`` row) rather than
    silently inventing weights.
    """
    _reject_concrete_all_false(active)
    h = h.astype(jnp.float32)
    return _kernel(strategy).weights(h, active.astype(bool), a_tilde)


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

def theta_entropy(theta: jax.Array) -> jax.Array:
    """Diagnostic: entropy of the weight distribution (log p = equal)."""
    t = jnp.maximum(theta, 1e-30)
    return -(t * jnp.log(t)).sum()


def omega(theta: jax.Array) -> jax.Array:
    """omega = sum_i theta_i^2 (Lemma 2) — controls the aggregate variance."""
    return jnp.sum(jnp.square(theta))
