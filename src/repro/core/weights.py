"""Weight evaluating functions (paper Sec. 3.2).

Given per-worker loss energies ``h`` (shape ``(p,)``), produce normalized
aggregation weights ``theta`` (summing to 1):

* ``boltzmann`` (WASGD+, Eq. 13): theta_i = softmax(-a_tilde * h_i / sum(h))
  — Property 1: a→0 gives equal weights, a→inf broadcasts the best worker.
* ``inverse`` (WASGD v1, Alg. 3): theta_i ∝ 1 / h_i.
* ``equal``: theta_i = 1/p (SimuParallelSGD-style averaging).
* ``best``: one-hot on the minimum energy (the a→inf limit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STRATEGIES = ("boltzmann", "inverse", "equal", "best")


def normalize_energy(h: jax.Array) -> jax.Array:
    """h'_i = h_i / sum_j h_j (Eq. 12 normalization)."""
    h = h.astype(jnp.float32)
    return h / jnp.maximum(h.sum(), 1e-30)


def boltzmann_weights(h: jax.Array, a_tilde: float) -> jax.Array:
    """Eq. 13 — the Boltzmann weight evaluating function of WASGD+."""
    return jax.nn.softmax(-a_tilde * normalize_energy(h))


def inverse_weights(h: jax.Array) -> jax.Array:
    """WASGD v1: theta_i = (1/h_i) / sum_j (1/h_j)."""
    inv = 1.0 / jnp.maximum(h.astype(jnp.float32), 1e-30)
    return inv / inv.sum()


def equal_weights(p: int) -> jax.Array:
    return jnp.full((p,), 1.0 / p, jnp.float32)


def best_weights(h: jax.Array) -> jax.Array:
    return jax.nn.one_hot(jnp.argmin(h), h.shape[0], dtype=jnp.float32)


def compute_theta(h: jax.Array, strategy: str = "boltzmann",
                  a_tilde: float = 1.0) -> jax.Array:
    if strategy == "boltzmann":
        return boltzmann_weights(h, a_tilde)
    if strategy == "inverse":
        return inverse_weights(h)
    if strategy == "equal":
        return equal_weights(h.shape[0])
    if strategy == "best":
        return best_weights(h)
    raise ValueError(f"unknown weighting strategy {strategy!r}")


def theta_entropy(theta: jax.Array) -> jax.Array:
    """Diagnostic: entropy of the weight distribution (log p = equal)."""
    t = jnp.maximum(theta, 1e-30)
    return -(t * jnp.log(t)).sum()


def omega(theta: jax.Array) -> jax.Array:
    """omega = sum_i theta_i^2 (Lemma 2) — controls the aggregate variance."""
    return jnp.sum(jnp.square(theta))
