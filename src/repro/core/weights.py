"""Weight evaluating functions (paper Sec. 3.2).

Given per-worker loss energies ``h`` (shape ``(p,)``), produce normalized
aggregation weights ``theta`` (summing to 1):

* ``boltzmann`` (WASGD+, Eq. 13): theta_i = softmax(-a_tilde * h_i / sum(h))
  — Property 1: a→0 gives equal weights, a→inf broadcasts the best worker.
* ``inverse`` (WASGD v1, Alg. 3): theta_i ∝ 1 / h_i.
* ``equal``: theta_i = 1/p (SimuParallelSGD-style averaging).
* ``best``: one-hot on the minimum energy (the a→inf limit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STRATEGIES = ("boltzmann", "inverse", "equal", "best")


def normalize_energy(h: jax.Array) -> jax.Array:
    """h'_i = h_i / sum_j h_j (Eq. 12 normalization)."""
    h = h.astype(jnp.float32)
    return h / jnp.maximum(h.sum(), 1e-30)


def boltzmann_weights(h: jax.Array, a_tilde: float) -> jax.Array:
    """Eq. 13 — the Boltzmann weight evaluating function of WASGD+."""
    return jax.nn.softmax(-a_tilde * normalize_energy(h))


def inverse_weights(h: jax.Array) -> jax.Array:
    """WASGD v1: theta_i = (1/h_i) / sum_j (1/h_j)."""
    inv = 1.0 / jnp.maximum(h.astype(jnp.float32), 1e-30)
    return inv / inv.sum()


def equal_weights(p: int) -> jax.Array:
    return jnp.full((p,), 1.0 / p, jnp.float32)


def best_weights(h: jax.Array) -> jax.Array:
    return jax.nn.one_hot(jnp.argmin(h), h.shape[0], dtype=jnp.float32)


def masked_compute_theta(h: jax.Array, active: jax.Array,
                         a_tilde: float = 1.0,
                         strategy: str = "boltzmann") -> jax.Array:
    """θ over the active workers only; exactly 0 for inactive ones.

    Traced counterpart of ``compute_theta(h[active])`` scattered back to the
    full worker width: ``active`` is a ``(p,)`` boolean *array* (it may be a
    tracer), so the p-of-(p+b) weighting of Alg. 4 jits as part of one
    on-device round (core/async_device.py). Inactive energies are excluded
    BEFORE the Eq. 12 normalization — see ``async_sim.masked_theta`` for why
    a sentinel-energy approach degenerates the Boltzmann weights. The
    signature deliberately mirrors that host-side twin's
    ``(losses, active, a_tilde, strategy)`` order.

    At least one worker must be active; an all-False mask yields NaNs or
    zeros (e.g. the softmax of an all ``-inf`` row), matching the host
    path's empty-slice garbage rather than silently inventing weights.
    """
    h = h.astype(jnp.float32)
    active = active.astype(bool)
    m = active.astype(jnp.float32)
    if strategy == "boltzmann":
        # normalize over the ACTIVE energies, then softmax with inactive
        # logits at -inf == softmax over the compacted active subset.
        hn = h / jnp.maximum((m * h).sum(), 1e-30)
        logits = jnp.where(active, -a_tilde * hn, -jnp.inf)
        return jax.nn.softmax(logits)
    if strategy == "inverse":
        inv = m / jnp.maximum(h, 1e-30)
        return inv / jnp.maximum(inv.sum(), 1e-30)
    if strategy == "equal":
        return m / jnp.maximum(m.sum(), 1.0)
    if strategy == "best":
        # argmin over active energies; ties break to the first active worker,
        # matching jnp.argmin over the compacted subset. An all-False mask
        # yields NaNs (0/0) like the other strategies, not a silent one-hot
        # on argmin-of-all-inf (worker 0).
        oh = jax.nn.one_hot(jnp.argmin(jnp.where(active, h, jnp.inf)),
                            h.shape[0], dtype=jnp.float32) * m
        return oh / oh.sum()
    raise ValueError(f"unknown weighting strategy {strategy!r}")


def compute_theta(h: jax.Array, strategy: str = "boltzmann",
                  a_tilde: float = 1.0) -> jax.Array:
    if strategy == "boltzmann":
        return boltzmann_weights(h, a_tilde)
    if strategy == "inverse":
        return inverse_weights(h)
    if strategy == "equal":
        return equal_weights(h.shape[0])
    if strategy == "best":
        return best_weights(h)
    raise ValueError(f"unknown weighting strategy {strategy!r}")


def theta_entropy(theta: jax.Array) -> jax.Array:
    """Diagnostic: entropy of the weight distribution (log p = equal)."""
    t = jnp.maximum(theta, 1e-30)
    return -(t * jnp.log(t)).sum()


def omega(theta: jax.Array) -> jax.Array:
    """omega = sum_i theta_i^2 (Lemma 2) — controls the aggregate variance."""
    return jnp.sum(jnp.square(theta))
