"""Sample-order search (paper Sec. 3.4, Alg. 2 ``Judge``/``OrderGen``).

WASGD+ uses the parallel workers to search sample-order space: at each
communication the workers' loss energies are z-scored (``Judge``); a worker
whose score is <= -1 (better than ~84% of workers under normality) *keeps*
its permutation seed for the next epoch segment, everyone else reshuffles
(``OrderGen``). Device side this is a handful of scalars; the permutation
bookkeeping is host-side pipeline state.
"""
from __future__ import annotations

import threading
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp


def judge_scores(h: jax.Array) -> jax.Array:
    """Alg. 2 Function 3: z-score of each worker's loss energy."""
    h = h.astype(jnp.float32)
    ave = h.mean()
    stdv = jnp.sqrt(jnp.maximum(
        jnp.sum(jnp.square(h - ave)) / jnp.maximum(h.shape[0] - 1, 1), 1e-30))
    return (h - ave) / stdv


def permutation(seed: int, length: int) -> np.ndarray:
    """Deterministic sample order from a seed (host-side pipeline)."""
    return np.random.default_rng(int(seed)).permutation(length)


class OrderState:
    """Per-(worker, segment) permutation seeds + accumulated scores (Alg. 1)."""

    def __init__(self, n_workers: int, n_segments: int, base_seed: int = 0,
                 keep_score: float = -1.0):
        rng = np.random.default_rng(base_seed)
        self.seeds = rng.integers(0, 2**31 - 1, size=(n_segments, n_workers))
        self.scores = np.zeros((n_segments, n_workers), np.float64)
        self.keep_score = float(keep_score)
        self._rng = rng
        # record_scores runs on the trainer thread while end_segment may run
        # on the round prefetcher's staging thread (data/pipeline.py) — the
        # lock keeps a decision's read-keep-mask-then-reset atomic against a
        # concurrent score accumulation.
        self._lock = threading.Lock()

    def order_for(self, segment: int, worker: int, length: int) -> np.ndarray:
        return permutation(self.seeds[segment, worker], length)

    def record_scores(self, segment: int, scores: np.ndarray):
        """Accumulate communication-time Judge scores for this segment."""
        with self._lock:
            self.scores[segment] += np.asarray(scores)

    def end_segment(self, segment: int):
        """Alg. 2 OrderGen: keep seeds whose total score <= keep_score."""
        with self._lock:
            keep = self.scores[segment] <= self.keep_score
            n = (~keep).sum()
            if n:
                self.seeds[segment, ~keep] = self._rng.integers(
                    0, 2**31 - 1, size=n)
            self.scores[segment] = 0.0
        return keep

    def resize(self, new_p: int):
        """Membership resize: worker ``i`` keeps its seed column for
        ``i < min(old_p, new_p)`` (the slot contract — a surviving worker's
        permutation, and thus its epoch traversal position, is unaffected by
        others joining or leaving); newcomers draw fresh seeds and start
        their Judge score at 0."""
        if int(new_p) < 1:
            raise ValueError(f"resize needs new_p >= 1, got {new_p}")
        new_p = int(new_p)
        with self._lock:
            old_p = self.seeds.shape[1]
            if new_p <= old_p:
                self.seeds = self.seeds[:, :new_p]
                self.scores = self.scores[:, :new_p]
            else:
                n_seg = self.seeds.shape[0]
                fresh = self._rng.integers(0, 2**31 - 1,
                                           size=(n_seg, new_p - old_p))
                self.seeds = np.concatenate([self.seeds, fresh], axis=1)
                self.scores = np.concatenate(
                    [self.scores, np.zeros((n_seg, new_p - old_p))], axis=1)


def grouped_order(labels: np.ndarray, delta: int, seed: int = 0) -> np.ndarray:
    """Build a sample order with runs of ``delta`` same-label samples
    (the paper's Sec. 5.1 order-effect experiment)."""
    rng = np.random.default_rng(seed)
    by_label = {}
    for idx, lab in enumerate(labels):
        by_label.setdefault(int(lab), []).append(idx)
    for v in by_label.values():
        rng.shuffle(v)
    runs = []
    pools = {k: list(v) for k, v in by_label.items()}
    while any(pools.values()):
        keys = [k for k, v in pools.items() if v]
        k = keys[rng.integers(len(keys))]
        take = min(delta, len(pools[k]))
        runs.extend(pools[k][:take])
        pools[k] = pools[k][take:]
    return np.asarray(runs)
