"""Explicit shard_map collectives for the WASGD communication step — the
*phase* primitives behind the mesh schedules of the two-axis aggregation API.

The pjit path (core/aggregate.py) lets XLA derive the worker-axis
all-reduce from ``tensordot(theta, x)``. This module places the same Eq. 10
reduction as explicit ``jax.lax`` collectives under ``shard_map``, one
function per collective phase so schedules (core/backends.py) can sequence
them — and interleave independent compute between them (the ``overlap=``
hook runs between ``reduce_scatter_phase`` and ``all_gather_phase``):

    all_reduce_m_phase   per shard: m = psum(theta_local * payload_local)
    reduce_scatter_phase per shard: slice = psum_scatter(theta-reduced local
                                    partial), payload pinned to a wire dtype
    all_gather_phase     per shard: m = all_gather(slice)

Each phase returns the *aggregate* (or its slices); the worker-local FMA
``(1-beta) x + beta m`` and the Alg. 4 late-join mask are applied by the
schedule's ``finalize`` outside the shard_map regions — pointwise, so the
numbers are identical to the old fused formulation.

``aggregate_leaf_shard_map`` / ``aggregate_leaf_rs_ag`` /
``weighted_aggregate_shard_map`` remain as the fused-entry compatibility
surface, now thin compositions of the phase functions above;
tests/test_dryrun_small.py checks the shard_map path on an 8-device
placeholder mesh.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.aggregate import _axes_is_leaf, fma_late_join, is_worker_leaf


def _worker_axes_in(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _collective_axis(waxes: Tuple[str, ...]):
    return waxes[-1] if len(waxes) == 1 else waxes


def mesh_worker_shards(mesh: Mesh) -> int:
    """Number of shards the worker dim is split over (p in the rs_ag slices)."""
    p = 1
    for a in _worker_axes_in(mesh):
        p *= mesh.shape[a]
    return p


# ---------------------------------------------------------------------------
# Phase primitives
# ---------------------------------------------------------------------------

def all_reduce_m_phase(payload: jax.Array, theta: jax.Array, mesh: Mesh,
                       reduce_dtype=jnp.float32) -> jax.Array:
    """One-phase psum schedule: (w, ...) payload -> replicated f32 aggregate
    ``m = sum_j theta_j payload_j`` of shape ``payload.shape[1:]``.

    The theta-weighted contraction runs in ``reduce_dtype`` (bf16 halves the
    ring bytes; int payloads are widened first by the caller's codec).
    """
    waxes = _worker_axes_in(mesh)
    ndim = payload.ndim
    spec = P(waxes, *([None] * (ndim - 1)))
    out_spec = P(*([None] * (ndim - 1)))

    @functools.partial(shard_map, mesh=mesh, in_specs=(spec, P(waxes)),
                       out_specs=out_spec)
    def run(p_local, t_local):
        contrib = t_local.astype(reduce_dtype).reshape(
            t_local.shape + (1,) * (ndim - 1)) * p_local.astype(reduce_dtype)
        return jax.lax.psum(contrib.sum(axis=0), waxes).astype(jnp.float32)

    return run(payload, theta)


def reduce_scatter_phase(payload: jax.Array, theta: jax.Array, mesh: Mesh,
                         wire_dtype=jnp.float32) -> jax.Array:
    """rs_ag phase 1: (w, n_pad) payload -> (n_pad,) theta-reduced aggregate,
    scattered 1/p-per-shard over the worker mesh axes.

    When the worker dim holds more copies than mesh shards (w/p > 1) the
    local copies are theta-reduced BEFORE the scatter; concatenating them
    into the scatter dim would hand each shard a chunk of the wrong copy.
    The scattered partial rides the ring in ``wire_dtype`` (psum_scatter
    operates on that operand — XLA cannot re-associate the cast away).
    """
    waxes = _worker_axes_in(mesh)
    ax = _collective_axis(waxes)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(waxes, None), P(waxes)),
                       out_specs=P(waxes))
    def run(p_local, t_local):
        contrib = (t_local.astype(jnp.float32)[:, None]
                   * p_local.astype(jnp.float32)).sum(axis=0) \
            .astype(wire_dtype)
        return jax.lax.psum_scatter(contrib, ax, scatter_dimension=0,
                                    tiled=True)

    return run(payload, theta)


def all_gather_phase(m_scat: jax.Array, mesh: Mesh) -> jax.Array:
    """rs_ag phase 2: scattered (n_pad,) slices -> replicated f32 aggregate.

    RS + AG together move the same ring bytes as one all-reduce; splitting
    them here is what lets the schedule place independent compute (the
    ``overlap=`` thunk) between the two collectives.
    """
    waxes = _worker_axes_in(mesh)
    ax = _collective_axis(waxes)

    # check_rep=False: a tiled all_gather over the full worker axes IS
    # replicated along them, but shard_map's rep checker only infers
    # replication through psum.
    @functools.partial(shard_map, mesh=mesh, in_specs=P(waxes),
                       out_specs=P(None), check_rep=False)
    def run(m_local):
        return jax.lax.all_gather(m_local, ax,
                                  tiled=True).astype(jnp.float32)

    return run(m_scat)


def flatten_pad(x: jax.Array, p: int) -> Tuple[jax.Array, int]:
    """(w, ...) leaf -> ((w, n_pad), n): flattened trailing dims, padded so
    the rs_ag scatter divides evenly over ``p`` shards."""
    n = 1
    for s in x.shape[1:]:
        n *= s
    flat = x.reshape(x.shape[0], n)
    pad = (-n) % p
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, n


# ---------------------------------------------------------------------------
# Fused-entry compatibility surface (compositions of the phases above)
# ---------------------------------------------------------------------------

def aggregate_leaf_shard_map(x: jax.Array, theta: jax.Array,
                             beta: float, mesh: Mesh,
                             active: jax.Array = None) -> jax.Array:
    """x: (w, ...) sharded over the worker mesh axes; theta: (w,).

    ``active`` (optional ``(w,)`` bool, may be a tracer) is the Alg. 4
    late-join mask: inactive workers adopt the aggregate m instead of the
    FMA. ``None`` (the synchronous path) places no mask in the program.
    """
    m = all_reduce_m_phase(x, theta, mesh)
    return fma_late_join(x, m, beta, active)


def aggregate_leaf_rs_ag(x: jax.Array, theta: jax.Array, beta: float,
                         mesh: Mesh, comm_dtype=jnp.float32,
                         active: jax.Array = None) -> jax.Array:
    """Reduce-scatter + local FMA + all-gather schedule of Eq. 10.

    Same ring bytes as one all-reduce, but (a) the payload dtype is pinned
    to ``comm_dtype`` (see EXPERIMENTS §Perf H1 Iter 2) and (b) the two
    collective phases are separate programs that neighboring compute can
    overlap with. The f32 default matches the registry's
    ``AggregationContext`` default so both entry points agree.
    """
    orig_shape = x.shape
    flat, n = flatten_pad(x, mesh_worker_shards(mesh))
    m = all_gather_phase(
        reduce_scatter_phase(flat, theta, mesh, wire_dtype=comm_dtype), mesh)
    out = fma_late_join(flat, m, beta, active)
    return out[:, :n].reshape(orig_shape)


def weighted_aggregate_shard_map(params: Dict, axes: Dict, theta: jax.Array,
                                 beta: float, mesh: Mesh,
                                 schedule: str = "all_reduce",
                                 comm_dtype=jnp.float32) -> Dict:
    """schedule: "all_reduce" (psum) or "rs_ag" (reduce-scatter + FMA +
    all-gather with the ring payload pinned to ``comm_dtype``)."""
    if schedule == "all_reduce":
        leaf = aggregate_leaf_shard_map
    else:
        leaf = functools.partial(aggregate_leaf_rs_ag, comm_dtype=comm_dtype)

    def visit(x, ax):
        if is_worker_leaf(ax):
            return leaf(x, theta, beta, mesh)
        return x

    return jax.tree.map(visit, params, axes, is_leaf=_axes_is_leaf)
