"""Explicit shard_map formulation of the WASGD communication step.

The pjit path (core/aggregate.py) lets XLA derive the worker-axis
all-reduce from `tensordot(theta, x)`. This module expresses the same
Eq. 10 update with explicit ``jax.lax`` collectives under ``shard_map`` —
the form you reach for when scheduling matters (e.g. to interleave the
per-leaf reduces with the next round's first forward, or to stage
pod-local/cross-pod hops by hand):

    per shard:  m = psum(theta_local * x_local, axis=("pod", "data"))
                out = (1 - beta) * x_local + beta * m

Both paths are numerically identical; tests/test_dryrun_small.py checks the
shard_map path on an 8-device placeholder mesh.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.aggregate import _axes_is_leaf, is_worker_leaf


def _worker_axes_in(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def aggregate_leaf_shard_map(x: jax.Array, theta: jax.Array,
                             beta: float, mesh: Mesh,
                             active: jax.Array = None) -> jax.Array:
    """x: (w, ...) sharded over the worker mesh axes; theta: (w,).

    ``active`` (optional ``(w,)`` bool, may be a tracer) is the Alg. 4
    late-join mask: inactive workers adopt the aggregate m instead of the
    FMA (core/async_device.py). ``None`` (the synchronous backends) places
    no mask in the program at all.
    """
    waxes = _worker_axes_in(mesh)
    ndim = x.ndim
    spec = P(waxes, *([None] * (ndim - 1)))
    in_specs = (spec, P(waxes)) + ((P(waxes),) if active is not None else ())

    @functools.partial(
        shard_map, mesh=mesh, in_specs=in_specs, out_specs=spec)
    def run(x_local, theta_local, *active_local):
        # x_local: (w/|waxes|, ...) = (1, ...) when fully sharded
        contrib = theta_local.reshape(
            theta_local.shape + (1,) * (ndim - 1)) * x_local.astype(jnp.float32)
        m = jax.lax.psum(contrib.sum(axis=0, keepdims=True), waxes)
        out = (1.0 - beta) * x_local.astype(jnp.float32) + beta * m
        if active_local:
            mask = active_local[0].reshape(
                active_local[0].shape + (1,) * (ndim - 1))
            out = jnp.where(mask, out, jnp.broadcast_to(m, out.shape))
        return out.astype(x_local.dtype)

    args = (x, theta) if active is None else (x, theta, active)
    return run(*args)


def aggregate_leaf_rs_ag(x: jax.Array, theta: jax.Array, beta: float,
                         mesh: Mesh, comm_dtype=jnp.float32,
                         active: jax.Array = None) -> jax.Array:
    """Reduce-scatter + local FMA + all-gather schedule of Eq. 10.

    ``active`` is the optional Alg. 4 late-join mask, as in
    ``aggregate_leaf_shard_map``.

    Same ring bytes as one all-reduce, but (a) the payload dtype is pinned
    (psum_scatter operates on the ``comm_dtype`` operand — pass bf16 to get
    the halved-ring-bytes optimization XLA re-associates away under pjit,
    see EXPERIMENTS §Perf H1 Iter 2), and (b) the two phases can overlap
    with neighboring compute on real hardware. Each worker shard reduces a
    1/p slice of the flattened leaf, applies the FMA on its slice, and
    gathers the result.

    The f32 default matches the registry's ``AggregationContext`` default
    (core/backends.py) so both entry points agree; bf16 is an explicit
    opt-in via ``WASGDConfig.comm_dtype="bfloat16"``.
    """
    waxes = _worker_axes_in(mesh)
    p = 1
    for a in waxes:
        p *= mesh.shape[a]
    orig_shape = x.shape
    n = 1
    for s in x.shape[1:]:
        n *= s
    pad = (-n) % p
    flat = x.reshape(x.shape[0], n)
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    spec = P(waxes, None)

    ax = waxes[-1] if len(waxes) == 1 else waxes
    in_specs = (spec, P(waxes)) + ((P(waxes),) if active is not None else ())

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=spec)
    def run(x_local, theta_local, *active_local):
        # x_local: (w/p, n_pad) — this shard's worker copies. When the worker
        # dim holds more copies than mesh shards (w/p > 1) the local copies
        # must be theta-reduced BEFORE the scatter; concatenating them into
        # the scatter dim would hand each shard a chunk of the wrong copy.
        contrib = (theta_local.astype(jnp.float32)[:, None]
                   * x_local.astype(jnp.float32)).sum(axis=0) \
            .astype(comm_dtype)                    # (n_pad,) local partial
        # reduce-scatter: each shard ends with a 1/p slice of sum_j theta_j x_j
        m_slice = jax.lax.psum_scatter(contrib, ax,
                                       scatter_dimension=0, tiled=True)
        # all-gather the aggregate slices back (RS+AG == all-reduce bytes,
        # with the ring payload pinned to comm_dtype)
        m = jax.lax.all_gather(m_slice, ax, tiled=True).astype(jnp.float32)
        # the (1-beta) x_i term is worker-LOCAL, so the FMA runs after the
        # gather — the aggregate broadcasts over the local copies.
        out = (1.0 - beta) * x_local.astype(jnp.float32) + beta * m[None]
        if active_local:
            out = jnp.where(active_local[0][:, None], out,
                            jnp.broadcast_to(m[None], out.shape))
        return out.astype(x_local.dtype)

    args = (flat, theta) if active is None else (flat, theta, active)
    out = run(*args)
    if pad:
        out = out[:, :n]
    return out.reshape(orig_shape)


def weighted_aggregate_shard_map(params: Dict, axes: Dict, theta: jax.Array,
                                 beta: float, mesh: Mesh,
                                 schedule: str = "all_reduce",
                                 comm_dtype=jnp.float32) -> Dict:
    """schedule: "all_reduce" (psum) or "rs_ag" (reduce-scatter + FMA +
    all-gather with the ring payload pinned to ``comm_dtype``)."""
    if schedule == "all_reduce":
        leaf = aggregate_leaf_shard_map
    else:
        leaf = functools.partial(aggregate_leaf_rs_ag, comm_dtype=comm_dtype)

    def visit(x, ax):
        if is_worker_leaf(ax):
            return leaf(x, theta, beta, mesh)
        return x

    return jax.tree.map(visit, params, axes, is_leaf=_axes_is_leaf)
