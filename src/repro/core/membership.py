"""Elastic worker membership: the ``WorkerSet`` lifecycle.

WASGD's decentralized weighting (Eq. 10) has no center variable — unlike
EASGD's elastic link to a master, nothing in the math requires fixed
membership, and Alg. 4 already drops stragglers per round. This module
grows that into true elasticity: the worker count ``p`` is a
**round-boundary-mutable property** of a ``WorkerSet``, and a
``resize(new_p)`` event re-shards every per-worker structure in the
system:

* the worker-stacked parameter tree and its mirrored optimizer state
  (``core/aggregate.resize_worker_leaves`` — survivors bitwise-preserved,
  newcomers adopt the aggregate, the Alg. 4 late-join state);
* the worker-assessment policy state (``WeightPolicy.expand_state`` —
  EMA/time/anneal state survives membership changes, newcomers re-init
  from the aggregate);
* the Alg. 4 activity mask (``core/async_device.resize_active_mask`` —
  newcomers join active, a shrink can never empty the active set);
* the per-worker loss-energy accumulator (newcomers start at 0 — it
  resets every round anyway).

The slot contract everywhere: worker ``i`` keeps slot ``i`` for
``i < min(old_p, new_p)``; a shrink kills the tail slots, a grow appends
newcomers at the tail. That keeps every resize a slice-or-concat — no
permutation bookkeeping — and makes "kill worker j" expressible as a
shrink after rotating j to the tail, which the chaos schedule does not
need: which slot dies is irrelevant to convergence, only how many live.

``MembershipSchedule`` scripts the events for a run
(``Trainer.run(membership_schedule=)``), and ``make_chaos_schedule``
generates a seeded kill/revive walk for chaos testing. Checkpoints
(``checkpoint/io.py``) record ``p`` in their manifest; a restore under a
different ``p`` routes through this module's resize machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import aggregate as agg
from repro.core.aggregate import _axes_is_leaf, resize_worker_leaves


# ---------------------------------------------------------------------------
# The WorkerSet lifecycle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One recorded membership change: ``old_p -> new_p`` at ``round``."""
    round: Optional[int]
    old_p: int
    new_p: int


class WorkerSet:
    """Live worker membership: ``p`` as a mutable-at-round-boundary value.

    The set only changes through ``resize`` — every change is validated
    (``p >= 1``), bumps the ``generation`` counter (so downstream caches
    keyed on membership can invalidate), and lands in the event ``log``.
    """

    def __init__(self, p: int):
        if int(p) < 1:
            raise ValueError(f"a WorkerSet needs p >= 1, got {p}")
        self._p = int(p)
        self.generation = 0
        self.log: List[MembershipEvent] = []

    @property
    def p(self) -> int:
        return self._p

    def resize(self, new_p: int, round: Optional[int] = None
               ) -> MembershipEvent:
        """Commit a membership change at a round boundary."""
        new_p = int(new_p)
        if new_p < 1:
            raise ValueError(f"resize needs new_p >= 1, got {new_p}")
        event = MembershipEvent(round, self._p, new_p)
        if new_p != self._p:
            self._p = new_p
            self.generation += 1
        self.log.append(event)
        return event

    def __repr__(self):
        return f"WorkerSet(p={self._p}, generation={self.generation})"


# ---------------------------------------------------------------------------
# Membership schedules (scripted events + the chaos generator)
# ---------------------------------------------------------------------------

class MembershipSchedule:
    """Round-indexed worker counts: ``events[r] = p`` takes effect at the
    START of round ``r`` (a round boundary — mid-round membership is
    exactly what the round abstraction exists to exclude). ``p_of(r)`` is
    the worker count round ``r`` runs with: the latest event at or before
    ``r``, else ``p0``.
    """

    def __init__(self, p0: int, events: Optional[Dict[int, int]] = None):
        if int(p0) < 1:
            raise ValueError(f"MembershipSchedule needs p0 >= 1, got {p0}")
        self.p0 = int(p0)
        events = dict(events or {})
        for r, p in events.items():
            if int(r) < 0:
                raise ValueError(f"membership event at negative round {r}")
            if int(p) < 1:
                raise ValueError(
                    f"membership event at round {r} asks for p={p}; every "
                    f"round needs >= 1 worker")
        self.events = {int(r): int(p) for r, p in events.items()}
        self._boundaries = sorted(self.events)

    def p_of(self, r: int) -> int:
        p = self.p0
        for b in self._boundaries:
            if b > r:
                break
            p = self.events[b]
        return p

    def max_p(self, n_rounds: int) -> int:
        return max([self.p0] + [p for r, p in self.events.items()
                                if r < n_rounds])

    def __repr__(self):
        ev = ", ".join(f"{r}->{p}" for r, p in sorted(self.events.items()))
        return f"MembershipSchedule(p0={self.p0}, {{{ev}}})"


def make_chaos_schedule(p0: int, rounds: int, seed: int = 0,
                        event_prob: float = 0.4, min_p: int = 1,
                        max_p: Optional[int] = None) -> MembershipSchedule:
    """A seeded kill/revive walk over the worker count.

    Each round boundary flips a coin (``event_prob``); on an event the
    worker count takes a +-1 or +-2 step, clamped to ``[min_p, max_p]``
    (``max_p`` defaults to ``2 * p0``) and biased back toward ``p0`` so
    long runs oscillate around the nominal fleet size instead of drifting.
    """
    if max_p is None:
        max_p = 2 * p0
    if not (1 <= min_p <= p0 <= max_p):
        raise ValueError(
            f"need 1 <= min_p <= p0 <= max_p, got {min_p}/{p0}/{max_p}")
    rng = np.random.default_rng(seed)
    events: Dict[int, int] = {}
    p = p0
    for r in range(1, rounds):
        if rng.random() >= event_prob:
            continue
        step = int(rng.integers(1, 3))
        direction = -1 if p > p0 else (1 if p < p0 else
                                       (1 if rng.random() < 0.5 else -1))
        new_p = int(np.clip(p + direction * step, min_p, max_p))
        if new_p != p:
            events[r] = new_p
            p = new_p
    return MembershipSchedule(p0, events)


# ---------------------------------------------------------------------------
# Re-sharding the per-worker state across a resize
# ---------------------------------------------------------------------------

def resize_comm_state(comm_state: Any, new_p: int, policy=None) -> Any:
    """Re-shard a wasgd/wasgd+ ``comm_state`` across a membership resize.

    Handles the three shapes the wasgd rules produce (train/step.py
    ``init_comm_state``): ``()`` (stateless sync), a bare ``(p,)`` bool
    activity mask (stateless on_device), and the ``{"active", "policy"}``
    dict (stateful on_device). A bare stateful-policy state (stateful
    sync) routes through ``policy.expand_state``. Baseline rules' comm
    state (EASGD's center, MWU's log-weights) is tied to their own
    fixed-membership math and is rejected.
    """
    from repro.core.async_device import resize_active_mask

    if isinstance(comm_state, tuple) and not comm_state:
        return ()
    if isinstance(comm_state, dict) and set(comm_state) == {"active",
                                                            "policy"}:
        pstate = comm_state["policy"]
        if policy is not None:
            pstate = policy.expand_state(pstate, new_p)
        return {"active": resize_active_mask(comm_state["active"], new_p),
                "policy": pstate}
    is_mask = (hasattr(comm_state, "dtype")
               and jnp.asarray(comm_state).dtype == jnp.bool_
               and jnp.asarray(comm_state).ndim == 1)
    if is_mask:
        return resize_active_mask(comm_state, new_p)
    if policy is not None and isinstance(comm_state, dict):
        return policy.expand_state(comm_state, new_p)
    raise ValueError(
        "membership resize supports the wasgd/wasgd+ comm_state shapes "
        "((), activity mask, policy state, {'active', 'policy'}); rules "
        "with a center/master variable (easgd, mwu) have no elastic "
        f"re-shard (got {type(comm_state).__name__})")


def _params_structure(axes: Dict):
    return jax.tree_util.tree_structure(axes, is_leaf=_axes_is_leaf)


def _resize_params_like(tree: Any, axes: Dict, new_p: int) -> Any:
    """Worker-axis resize of a params-structured tree: worker leaves are
    sliced/grown (newcomers = survivor mean), shared leaves pass through."""
    def visit(x, ax):
        if not agg.is_worker_leaf(ax):
            return x
        old_p = x.shape[0]
        if new_p <= old_p:
            return x[:new_p]
        fill = jnp.broadcast_to(
            x.astype(jnp.float32).mean(axis=0)[None],
            (new_p - old_p,) + x.shape[1:]).astype(x.dtype)
        return jnp.concatenate([x, fill], axis=0)

    return jax.tree.map(visit, tree, axes, is_leaf=_axes_is_leaf)


def resize_opt_state(opt_state: Any, axes: Dict, new_p: int) -> Any:
    """Re-shard optimizer state across a membership resize.

    Optimizer state in this substrate is element-wise over the params
    (optim/optimizers.py), so it either IS params-structured (momentum
    buffers, ``_tree_zeros``), is empty (plain SGD), or is a container
    (NamedTuple/tuple) whose fields are each params-structured or scalar
    (AdamW's ``(mu, nu, count)``). Worker leaves resize with survivor-mean
    newcomer rows — a joiner inherits the fleet's aggregate momentum/
    moments rather than restarting cold; scalars (step counts) are fleet
    state and pass through.
    """
    target = _params_structure(axes)

    def visit(sub):
        if isinstance(sub, tuple) and not sub:
            return sub
        if jax.tree_util.tree_structure(sub) == target:
            return _resize_params_like(sub, axes, new_p)
        if hasattr(sub, "_fields"):                    # NamedTuple
            return type(sub)(*(visit(getattr(sub, f)) for f in sub._fields))
        if isinstance(sub, (tuple, list)):
            return type(sub)(visit(v) for v in sub)
        if hasattr(sub, "ndim") and jnp.asarray(sub).ndim == 0:
            return sub
        raise ValueError(
            f"don't know how to re-shard optimizer state of type "
            f"{type(sub).__name__} across a membership resize; expected "
            f"(), a params-structured tree, or a container of those")

    return visit(opt_state)


def resize_train_state(state, axes: Dict, new_p: int, policy=None,
                       theta: Optional[jax.Array] = None,
                       comm_state: Any = "__resize__"):
    """Re-shard a full ``TrainState`` across a membership resize.

    Params resize through ``core/aggregate.resize_worker_leaves`` (newcomers
    adopt the aggregate — optionally the ``theta``-weighted one), the
    optimizer state mirrors them, the energy accumulator grows with zeros
    (it resets every round), and the comm state routes through
    ``resize_comm_state`` (pass a pre-resized ``comm_state`` to override,
    e.g. when the Trainer threads it through ``init_comm_state(prev=)``).
    The round counter ``step`` is fleet state and carries over.
    """
    old_energy = state.energy
    old_p = old_energy.shape[0]
    if new_p <= old_p:
        energy = old_energy[:new_p]
    else:
        energy = jnp.concatenate(
            [old_energy, jnp.zeros((new_p - old_p,), old_energy.dtype)])
    if isinstance(comm_state, str) and comm_state == "__resize__":
        comm_state = resize_comm_state(state.comm_state, new_p,
                                       policy=policy)
    return state._replace(
        params=resize_worker_leaves(state.params, axes, new_p, theta=theta),
        opt_state=resize_opt_state(state.opt_state, axes, new_p),
        energy=energy,
        comm_state=comm_state,
    )
