"""Loss-energy estimation (paper Sec. 3.3, Eq. 26 + Alg. 2 ``RecordIndex``).

The weight of a worker is computed from losses *already produced during
backprop* — no extra forward passes. ``record_mask`` marks which of the tau
in-round steps contribute: the last ``m/c`` steps of each of the ``c``
round segments (Alg. 2 Function 1), i.e. recording is spread over the round
("same time" recording) to avoid a stale single-point estimate while staying
free.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def record_indices(tau: int, m: int, c: int) -> np.ndarray:
    """Alg. 2 Function 1: indices ((i+1)*tau/c - j - 1) for j < m/c, i < c."""
    c = max(1, min(c, tau))
    per_chunk = max(1, min(m // c if m >= c else 1, tau // c))
    out = set()
    for i in range(c):
        end = (i + 1) * tau // c
        for j in range(per_chunk):
            idx = end - j - 1
            if 0 <= idx < tau:
                out.add(idx)
    return np.asarray(sorted(out), dtype=np.int32)


def record_mask(tau: int, m: int, c: int) -> jnp.ndarray:
    mask = np.zeros((tau,), bool)
    mask[record_indices(tau, m, c)] = True
    return jnp.asarray(mask)


def estimation_error(theta: jax.Array, theta_true: jax.Array) -> jax.Array:
    """Eq. 27: sum_i |theta_i - theta_true_i|, in [0, 2]."""
    return jnp.abs(theta - theta_true).sum()
