"""Payload codecs — the *encoding* axis of the two-axis aggregation API.

The Eq. 10 aggregate ``m = sum_j theta_j x_j`` is one computation, but the
bytes that ride the worker-axis collective are a free choice. A
``PayloadCodec`` owns exactly that choice, per worker-stacked leaf:

    payload, aux = codec.encode(x, ctx)      # what rides the wire
    m_hat        = <schedule reduces theta-weighted payload>
    m            = codec.decode_reduced(m_hat, aux)   # back to f32

The *schedule* (``core/backends.py``) decides where the collectives go; the
codec decides what they carry. ``WASGDConfig.backend = "schedule:codec"``
composes the two (e.g. ``"rs_ag:int8"``, ``"hierarchical:bf16"``).

Registered codecs
=================

``f32``    Identity payload. The reference the parity grid compares against.
``bf16``   bfloat16 payload: the weighted reduce runs in bf16, halving ring
           bytes. This is what ``ctx.comm_dtype="bfloat16"`` used to select;
           specs without an explicit codec still derive it from there.
``int8``   Symmetric per-leaf int8 quantization (scale = max|x|/127, riding
           in ``aux``), decoded after the reduce — the old ``quantized``
           backend, now composable with any schedule (the pod-local hop of
           ``hierarchical:int8`` carries int8, the cross-pod hop f32).
``int4``   int4-range stochastic rounding (scale = max|x|/7, unbiased
           ``floor(x/scale + u)`` with u ~ U[0,1)). ~8x fewer operand bytes;
           noise is zero-mean so the Eq. 10 contraction averages it away.

Error contract
==============

``codec.error_bound(x, theta, beta)`` returns a per-element bound on
``|out - out_f32|`` for one Eq. 10 application — the documented tolerance
the composition-grid test (``tests/test_composition_grid.py``) holds every
``schedule:codec`` pair to:

* ``f32``  — float noise only.
* ``bf16`` — operand + accumulation rounding, linear-in-w worst case.
* ``int8`` — deterministic rounding: per-element quantization error is at
  most ``scale/2``, so the aggregate errs by at most ``beta * scale/2``.
* ``int4`` — stochastic rounding: per-element error strictly below one step
  ``scale``, so the aggregate errs by less than ``beta * scale``.

Quantizing codecs (``int8``/``int4``) mark ``quantizing=True``: schedules
that cast a locally-reduced *partial* onto the wire (``rs_ag``) encode the
operand instead and let the partial ride in ``reduce_dtype`` — partial sums
of integer payloads are fractional, so re-quantizing them per-hop would
compound error silently.

Codecs also feed the fused ``pallas_wagg`` kernel (``kernels/wagg``)
directly: the ``(payload, aux)`` pair rides into the kernel as-is — wire
tiles are decoded IN VMEM in the same pass as the Eq. 10 FMA, with the
per-leaf scalar ``aux`` (the int8/int4 scale) folded into theta by the ops
wrapper, so ``decode_reduced`` never runs as a separate XLA program on
that path. Both paths are equivalent up to float reassociation:
``sum_j (theta_j * scale) q_j == scale * sum_j theta_j q_j``.

Adding a codec
==============

    from repro.core.codecs import register_codec

    @register_codec
    class MyCodec:
        name = "fp8ish"
        ...

It becomes selectable in every ``"schedule:fp8ish"`` spec and is picked up
by the composition-grid parity test automatically.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp


@runtime_checkable
class PayloadCodec(Protocol):
    """Encoding of the worker-axis collective payload for one leaf."""

    name: str
    wire_dtype: Any          # dtype of the encoded payload on the wire
    reduce_dtype: Any        # dtype the theta-weighted reduce runs in
    quantizing: bool         # True: encode/decode are not a plain dtype cast

    def encode(self, x: jax.Array, ctx=None) -> Tuple[jax.Array, Any]:
        """leaf -> (payload, aux). ``aux`` carries decode state (scales)."""
        ...

    def decode_reduced(self, m: jax.Array, aux) -> jax.Array:
        """Reduced payload -> f32 aggregate m."""
        ...

    def error_bound(self, x: jax.Array, theta: jax.Array, beta) -> jax.Array:
        """Per-element bound on |out - out_f32| for one Eq. 10 step."""
        ...


class _DtypeCodec:
    """Pure dtype-cast codec (f32 / bf16): payload = x.astype(dtype)."""

    quantizing = False

    def __init__(self, name: str, dtype):
        self.name = name
        self.wire_dtype = dtype
        self.reduce_dtype = dtype

    def encode(self, x, ctx=None):
        return x.astype(self.wire_dtype), None

    def decode_reduced(self, m, aux):
        return m.astype(jnp.float32)

    def error_bound(self, x, theta, beta):
        if self.wire_dtype == jnp.float32:
            return jnp.float32(1e-5)
        # operand rounding (2^-9 relative each) + bf16 accumulation over the
        # worker axis: linear-in-w worst case, plus float noise.
        w = theta.shape[0]
        return (beta * (w + 4) * 2.0 ** -8
                * jnp.max(jnp.abs(x)).astype(jnp.float32) + 1e-5)

    def __repr__(self):
        return f"PayloadCodec({self.name!r})"


class _Int8Codec:
    """Symmetric per-leaf int8: q = round(x/scale), scale = max|x|/127."""

    name = "int8"
    wire_dtype = jnp.int8
    reduce_dtype = jnp.float32
    quantizing = True

    def encode(self, x, ctx=None):
        scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return q.astype(jnp.int8), scale

    def decode_reduced(self, m, aux):
        return m.astype(jnp.float32) * aux

    def error_bound(self, x, theta, beta):
        scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
        # deterministic rounding: per-element error <= scale/2; the aggregate
        # is a theta-convex combination, so the bound survives the reduce.
        return (beta * scale / 2).astype(jnp.float32) + 1e-5

    def __repr__(self):
        return f"PayloadCodec({self.name!r})"


class _Int4StochasticCodec:
    """int4-range payload with unbiased stochastic rounding.

    q = clip(floor(x/scale + u), -7, 7) with u ~ U[0,1) — E[q] = x/scale, so
    quantization noise is zero-mean and the theta-weighted aggregate averages
    it away instead of accumulating bias round over round. The uniform draw
    comes from ``ctx.key`` when the caller threads one; either way the leaf
    CONTENT is mixed into the key (an xor-fold of the payload bits), so the
    noise pattern changes whenever the parameters do — fresh pseudo-noise
    every training round without any key plumbing through the jitted round
    — and ``ctx.leaf_index`` (the leaf's position in the flattened tree,
    set per-leaf by ``ComposedBackend.aggregate``) is folded in on top, so
    IDENTICAL-content leaves (zero-inits, tied embeddings) still draw
    distinct noise instead of correlating their quantization error across
    the tree. Encoding is deterministic per (key, leaf value, leaf index),
    which is what the parity tests want.
    """

    name = "int4"
    wire_dtype = jnp.int8            # int4-valued, carried in an int8 array
    reduce_dtype = jnp.float32
    quantizing = True

    def encode(self, x, ctx=None):
        scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 7.0
        key = getattr(ctx, "key", None) if ctx is not None else None
        if key is None:
            key = jax.random.key(0x144)
        # mix the payload bits into the key: the draw decorrelates round
        # over round as the parameters change (a frozen key would repeat
        # the identical noise pattern every round, turning the zero-mean
        # error into correlated drift) and differs across same-shaped
        # leaves.
        bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32),
                                            jnp.uint32)
        # wraparound u32 sum, not xor-reduce: modular addition is an equally
        # cheap content hash but partitions as a tree reduction, so the
        # encode compiles under GSPMD on every backend (XLA CPU cannot
        # partition a bitwise_xor reduce across a sharded leaf).
        seed = jnp.sum(bits.ravel(), dtype=jnp.uint32)
        key = jax.random.fold_in(jax.random.fold_in(key, x.size), seed)
        # (size, content-xor) alone collide for equal-content leaves —
        # zero-inits and tied embeddings would draw the SAME noise and bias
        # the aggregate; the per-leaf tree position breaks the tie.
        leaf_index = getattr(ctx, "leaf_index", None) if ctx is not None \
            else None
        if leaf_index is not None:
            key = jax.random.fold_in(key, leaf_index)
        u = jax.random.uniform(key, x.shape, jnp.float32)
        q = jnp.clip(jnp.floor(x.astype(jnp.float32) / scale + u), -7, 7)
        return q.astype(jnp.int8), scale

    def decode_reduced(self, m, aux):
        return m.astype(jnp.float32) * aux

    def error_bound(self, x, theta, beta):
        scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 7.0
        # stochastic rounding: |q*scale - x| < scale strictly (one step).
        return (beta * scale).astype(jnp.float32) + 1e-5

    def __repr__(self):
        return f"PayloadCodec({self.name!r})"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_CODECS: Dict[str, PayloadCodec] = {}


def register_codec(codec: PayloadCodec, *, overwrite: bool = False):
    """Register a codec instance (or class — it is instantiated) by name."""
    obj = codec() if isinstance(codec, type) else codec
    if obj.name in _CODECS and not overwrite:
        raise ValueError(f"payload codec {obj.name!r} already registered; "
                         f"pass overwrite=True to replace")
    _CODECS[obj.name] = obj
    return codec


def get_codec(name: str) -> PayloadCodec:
    if name not in _CODECS:
        raise KeyError(f"unknown payload codec {name!r}; "
                       f"known: {sorted(_CODECS)}")
    return _CODECS[name]


def available_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_CODECS))


def codec_for_dtype(dtype) -> PayloadCodec:
    """ctx.comm_dtype -> codec, for specs that leave the codec axis open
    (the legacy aliases: ``einsum``/``hierarchical``/``rs_ag`` keep honoring
    ``WASGDConfig.comm_dtype`` exactly as before)."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16):
        return get_codec("bf16")
    return get_codec("f32")


register_codec(_DtypeCodec("f32", jnp.float32))
register_codec(_DtypeCodec("bf16", jnp.bfloat16))
register_codec(_Int8Codec())
register_codec(_Int4StochasticCodec())


__all__ = ["PayloadCodec", "available_codecs", "codec_for_dtype",
           "get_codec", "register_codec"]
