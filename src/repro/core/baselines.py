"""Benchmark baselines from the paper (Sec. 5.2.2), as communication rules
over a worker-stacked parameter tree. All share the WASGD round structure
(local steps, then a communication) so comparisons isolate the aggregation
rule itself:

* ``spsgd``  — SimuParallelSGD [Zinkevich et al. 2010]: equal-weight average.
* ``easgd``  — Elastic Averaging SGD [Zhang et al. 2015]: center variable
               x~ with moving rate alpha (Eqs. 3-4).
* ``omwu``   — Original Multiplicative Weight Update [Dwork & Roth]: weights
               updated multiplicatively from FULL-dataset loss; workers adopt
               the highest-weight worker's parameters.
* ``mmwu``   — Modified MWU: same rule but with the paper's free m-sample
               loss estimator (the paper's own modification).
* sequential SGD is the p=1 degenerate case of any rule.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregate as agg
from repro.core.weights import equal_weights


# -- SimuParallelSGD -------------------------------------------------------------

def spsgd_communicate(params: Dict, axes: Dict) -> Dict:
    p = None

    def first_w(x, ax):
        nonlocal p
        if agg.is_worker_leaf(ax) and p is None:
            p = x.shape[0]
        return x

    jax.tree.map(first_w, params, axes)
    theta = equal_weights(p)
    return agg.weighted_aggregate(params, axes, theta, beta=1.0)


# -- EASGD -----------------------------------------------------------------------

class EASGDState(NamedTuple):
    center: Dict                 # x~ — same structure as params minus worker dim


def easgd_init(params: Dict, axes: Dict) -> EASGDState:
    center = jax.tree.map(
        lambda x, ax: x[0] if agg.is_worker_leaf(ax) else x, params, axes)
    return EASGDState(center)


def easgd_communicate(params: Dict, axes: Dict, state: EASGDState,
                      alpha: float) -> Tuple[Dict, EASGDState]:
    """Eq. 3 elastic pull + Eq. 4 center update (communication part only)."""
    def upd(x, ax, c):
        if not agg.is_worker_leaf(ax):
            return x, c
        p = x.shape[0]
        delta = alpha * (x.astype(jnp.float32) - c.astype(jnp.float32)[None])
        new_x = (x.astype(jnp.float32) - delta).astype(x.dtype)
        new_c = (c.astype(jnp.float32) + delta.sum(0)).astype(c.dtype)
        return new_x, new_c

    flat_p, treedef = jax.tree.flatten(params)
    flat_a = treedef.flatten_up_to(axes)
    flat_c = treedef.flatten_up_to(state.center)
    new_p, new_c = zip(*[upd(x, ax, c)
                         for x, ax, c in zip(flat_p, flat_a, flat_c)])
    return (jax.tree.unflatten(treedef, new_p),
            EASGDState(jax.tree.unflatten(treedef, new_c)))


# -- Multiplicative Weight Update ---------------------------------------------------

class MWUState(NamedTuple):
    log_w: jax.Array             # (p,) log multiplicative weights


def mwu_init(p: int) -> MWUState:
    return MWUState(jnp.zeros((p,), jnp.float32))


def mwu_communicate(params: Dict, axes: Dict, state: MWUState, h: jax.Array,
                    eps: float = 0.5) -> Tuple[Dict, MWUState]:
    """w_i <- w_i * exp(-eps * h'_i); all workers adopt the argmax worker.

    OMWU computes ``h`` over the full training set (its cost is the point of
    the paper's comparison); MMWU passes the free m-sample estimate instead —
    the communication rule is identical.
    """
    hp = h.astype(jnp.float32) / jnp.maximum(h.sum(), 1e-30)
    log_w = state.log_w - eps * hp
    theta = jax.nn.one_hot(jnp.argmax(log_w), h.shape[0], dtype=jnp.float32)
    new_params = agg.weighted_aggregate(params, axes, theta, beta=1.0)
    return new_params, MWUState(log_w)
