from repro.core.aggregate import (
    aggregate_leaf,
    fma_late_join,
    map_worker_leaves,
    replicate_workers,
    strip_worker_axis,
    take_worker,
    weighted_aggregate,
    worker_in_axes,
)
from repro.core.backends import (
    AggregationContext,
    aggregate_from_config,
    aggregate_with,
    available_backends,
    available_codecs,
    available_schedules,
    available_specs,
    backend_name_from_config,
    canonical_spec,
    context_from_config,
    get_backend,
    get_codec,
    register_backend,
    register_codec,
    register_schedule,
    resolve_spec,
    select_auto_spec,
)
from repro.core.async_device import (
    ASYNC_BACKENDS,
    async_backend_name,
    build_async_round,
    run_parallel_sgd_on_device,
    weighted_aggregate_async,
)
from repro.core.async_sim import StragglerSchedule, make_schedule
from repro.core.energy import estimation_error, record_indices, record_mask
from repro.core.order import OrderState, grouped_order, judge_scores
from repro.core.wasgd import CommResult, communicate
from repro.core.weights import (
    best_weights,
    boltzmann_weights,
    compute_theta,
    equal_weights,
    inverse_weights,
    masked_compute_theta,
    normalize_energy,
    omega,
    theta_entropy,
)

__all__ = [
    "aggregate_leaf", "fma_late_join", "map_worker_leaves",
    "replicate_workers",
    "strip_worker_axis", "take_worker", "weighted_aggregate",
    "worker_in_axes", "AggregationContext", "aggregate_from_config",
    "aggregate_with",
    "available_backends", "available_codecs", "available_schedules",
    "available_specs", "backend_name_from_config", "canonical_spec",
    "context_from_config",
    "get_backend", "get_codec", "register_backend", "register_codec",
    "register_schedule", "resolve_spec", "select_auto_spec",
    "ASYNC_BACKENDS", "async_backend_name", "build_async_round",
    "run_parallel_sgd_on_device", "weighted_aggregate_async",
    "StragglerSchedule", "make_schedule",
    "estimation_error", "record_indices", "record_mask",
    "OrderState", "grouped_order", "judge_scores", "CommResult",
    "communicate", "best_weights", "boltzmann_weights", "compute_theta",
    "equal_weights", "inverse_weights", "masked_compute_theta",
    "normalize_energy", "omega", "theta_entropy",
]
