"""On-device asynchronous WASGD+ (paper Alg. 4) through the backend registry.

``core/async_sim.py`` reproduces Alg. 4's *scheduling semantics* as a
host-side numpy event simulation; this module runs the same p-of-(p+b)
round as ONE jitted program on the worker mesh axis. Each worker's activity
is a traced ``(w,)`` boolean mask:

    local tau steps -> loss energies -> masked Boltzmann theta
        (``weights.masked_compute_theta``: stragglers' theta is exactly 0)
    -> Eq. 10 aggregate over the ACTIVE workers, placed as explicit
       collectives under ``shard_map`` (all-reduce or rs_ag schedule)
    -> straggler late-join: inactive workers adopt the aggregate
       m = sum_j theta_j x_j when they arrive (Alg. 4 line 20).

Because the stragglers' theta is zero they contribute nothing to the psum,
so exclusion needs no gather/compaction — the whole round stays SPMD and
the mask can change every round without recompilation.

The registry names:

``async_einsum``     meshless reference (pjit tensordot + late-join) — the
                     in-registry twin of the host simulation's update.
``async_shard_map``  masked psum + late-join in one ``shard_map`` program.
``async_rs_ag``      reduce-scatter + local FMA + all-gather with the ring
                     payload pinned to ``ctx.comm_dtype``, + late-join.

The activity mask rides in ``AggregationContext.active`` (``None`` means
everyone is active, which degenerates to the synchronous backends). The host
simulation stays the semantic oracle: ``tests/test_async_device.py`` injects
the same ``StragglerSchedule`` into both paths and requires leaf-for-leaf
parity across all weight strategies and both mesh schedules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import backends
from repro.core import shardmap_agg as smagg
from repro.core.aggregate import _axes_is_leaf, is_worker_leaf
from repro.core.async_sim import (AsyncResult, StepTimeModel,
                                  StragglerSchedule, make_schedule)
from repro.core.weights import masked_compute_theta

ASYNC_BACKENDS = ("async_einsum", "async_shard_map", "async_rs_ag")

# sync backend -> its Alg. 4 (masked + late-join) counterpart
_ASYNC_OF = {"einsum": "async_einsum", "shard_map": "async_shard_map",
             "rs_ag": "async_rs_ag"}


def async_backend_name(name: str) -> str:
    """Map a (possibly synchronous) backend name to its async counterpart."""
    if name in ASYNC_BACKENDS:
        return name
    if name in _ASYNC_OF:
        return _ASYNC_OF[name]
    raise ValueError(
        f"aggregation backend {name!r} has no async (Alg. 4) counterpart; "
        f"use one of {sorted(_ASYNC_OF)} or {sorted(ASYNC_BACKENDS)}")


# ---------------------------------------------------------------------------
# Masked Eq. 10 + late-join leaves
# ---------------------------------------------------------------------------

def _resolve_active(theta: jax.Array, active: Optional[jax.Array]):
    if active is None:
        return jnp.ones(theta.shape, bool)
    return active.astype(bool)


def aggregate_leaf_async_einsum(x: jax.Array, theta: jax.Array,
                                active: jax.Array, beta,
                                comm_dtype=jnp.float32) -> jax.Array:
    """Meshless reference: pjit tensordot aggregate + late-join ``where`` —
    the same update the host event simulation applies per round."""
    xf = x.astype(jnp.float32)
    theta = theta.astype(jnp.float32)
    m = jnp.tensordot(theta.astype(comm_dtype), xf.astype(comm_dtype),
                      axes=1).astype(jnp.float32)
    fma = (1.0 - beta) * xf + beta * m[None]
    mask = active.reshape((-1,) + (1,) * (x.ndim - 1))
    out = jnp.where(mask, fma, jnp.broadcast_to(m[None], fma.shape))
    return out.astype(x.dtype)


def weighted_aggregate_async(params: Dict, axes: Dict, theta: jax.Array,
                             active: Optional[jax.Array], beta,
                             mesh=None, schedule: str = "all_reduce",
                             comm_dtype=jnp.float32) -> Dict:
    """Apply the masked Eq. 10 + late-join to all worker leaves.

    ``schedule``: "einsum" (meshless), "all_reduce" (masked psum under
    shard_map) or "rs_ag" (reduce-scatter + FMA + all-gather). The mesh
    schedules are the SAME collective leaves as the synchronous
    ``shard_map``/``rs_ag`` backends (core/shardmap_agg.py) with the
    late-join mask passed through — stragglers carry theta == 0, so the
    collectives already exclude them, and inactive workers adopt the
    aggregate m (analytically equal to sum_j theta_j [(1-beta)x_j + beta*m]).
    """
    active = _resolve_active(theta, active)
    if schedule == "einsum":
        leaf = functools.partial(aggregate_leaf_async_einsum,
                                 comm_dtype=comm_dtype)
    elif schedule == "all_reduce":
        leaf = lambda x, t, act, b: smagg.aggregate_leaf_shard_map(
            x, t, b, mesh, active=act)
    elif schedule == "rs_ag":
        leaf = lambda x, t, act, b: smagg.aggregate_leaf_rs_ag(
            x, t, b, mesh, comm_dtype=comm_dtype, active=act)
    else:
        raise ValueError(f"unknown async schedule {schedule!r}")

    def visit(x, ax):
        if is_worker_leaf(ax):
            return leaf(x, theta, active, beta)
        return x

    return jax.tree.map(visit, params, axes, is_leaf=_axes_is_leaf)


# ---------------------------------------------------------------------------
# Registry entries
# ---------------------------------------------------------------------------

@backends.register_backend("async_einsum")
def _async_einsum(params, axes, theta, beta, ctx):
    return weighted_aggregate_async(params, axes, theta, ctx.active, beta,
                                    schedule="einsum",
                                    comm_dtype=ctx.comm_dtype)


@backends.register_backend("async_shard_map", needs_mesh=True)
def _async_shard_map(params, axes, theta, beta, ctx):
    return weighted_aggregate_async(params, axes, theta, ctx.active, beta,
                                    mesh=ctx.mesh, schedule="all_reduce")


@backends.register_backend("async_rs_ag", needs_mesh=True)
def _async_rs_ag(params, axes, theta, beta, ctx):
    return weighted_aggregate_async(params, axes, theta, ctx.active, beta,
                                    mesh=ctx.mesh, schedule="rs_ag",
                                    comm_dtype=ctx.comm_dtype)


# ---------------------------------------------------------------------------
# One compiled Alg. 4 round + the driver loop
# ---------------------------------------------------------------------------

def build_async_round(grad_fn: Callable, axes: Dict, *, lr: float,
                      beta: float = 0.9, a_tilde: float = 1.0,
                      strategy: str = "boltzmann",
                      backend: str = "async_shard_map",
                      ctx: Optional[backends.AggregationContext] = None,
                      jit: bool = True) -> Callable:
    """Build ``round_fn(params, batch, active) -> (params, losses, theta)``.

    One jitted program per p-of-(p+b) round: the local steps, the masked
    Boltzmann theta, the Eq. 10 aggregate, and the straggler late-join all
    trace together — ``active`` is a ``(w,)`` bool input, so a new straggler
    set per round costs no recompilation.

    ``grad_fn(params_stacked, batch) -> (losses (w,), grads_stacked)`` —
    the same contract as ``async_sim.run_parallel_sgd``.
    """
    ctx = backends.DEFAULT_CONTEXT if ctx is None else ctx
    name = async_backend_name(backend)
    backend_obj = backends.get_backend(name)
    if getattr(backend_obj, "needs_mesh", False) and ctx.mesh is None:
        raise ValueError(
            f"async aggregation backend {name!r} places explicit "
            f"collectives and needs ctx.mesh (AggregationContext(mesh=...))")
    w_axes = jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes,
                          is_leaf=_axes_is_leaf)

    def round_fn(params, batch, active):
        losses, grads = grad_fn(params, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        theta = masked_compute_theta(losses, active, a_tilde, strategy)
        params = backend_obj.aggregate(
            params, w_axes, theta, beta,
            ctx=dataclasses.replace(ctx, active=active))
        return params, losses, theta

    return jax.jit(round_fn, donate_argnums=(0,)) if jit else round_fn


def run_parallel_sgd_on_device(grad_fn: Callable, params0: Dict, axes: Dict,
                               batches, *, n_workers: int, backups: int,
                               tau: int, rounds: int, lr: float,
                               time_model: Optional[StepTimeModel] = None,
                               schedule: Optional[StragglerSchedule] = None,
                               a_tilde: float = 1.0, beta: float = 0.9,
                               strategy: str = "boltzmann",
                               synchronous: bool = False,
                               backend: str = "async_shard_map",
                               ctx: Optional[backends.AggregationContext]
                               = None) -> AsyncResult:
    """On-device drop-in for ``async_sim.run_parallel_sgd``.

    Same scheduling semantics (inject the same ``schedule`` for parity),
    but every round executes as one jitted SPMD program through the
    ``async_*`` backend family. ``AsyncResult.params`` is the final
    worker-stacked parameter tree the parity harness compares leaf-for-leaf
    against the host simulation's.
    """
    if schedule is None:
        if time_model is None:
            raise ValueError("pass either time_model= or schedule=")
        schedule = make_schedule(time_model, rounds=rounds, tau=tau,
                                 n_workers=n_workers, backups=backups,
                                 synchronous=synchronous)
    w = n_workers + backups
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (w,) + x.shape), params0)
    round_fn = build_async_round(grad_fn, axes, lr=lr, beta=beta,
                                 a_tilde=a_tilde, strategy=strategy,
                                 backend=backend, ctx=ctx)

    losses_hist = []
    for r in range(rounds):
        batch = next(batches)                      # (w, tau*b_local, ...)
        active = jnp.asarray(schedule.active[r])
        params, losses, _ = round_fn(params, batch, active)
        losses_np = np.asarray(losses)
        losses_hist.append(float(losses_np[schedule.active[r]].mean()))

    wall = float(schedule.round_wall[:rounds].sum())
    dropped = int((~schedule.active[:rounds]).sum())
    return AsyncResult(np.asarray(losses_hist), wall, dropped, params)
