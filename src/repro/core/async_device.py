"""On-device asynchronous WASGD+ (paper Alg. 4) through the backend registry.

``core/async_sim.py`` reproduces Alg. 4's *scheduling semantics* as a
host-side numpy event simulation; this module runs the same p-of-(p+b)
round as ONE jitted program on the worker mesh axis. Each worker's activity
is a traced ``(w,)`` boolean mask:

    local tau steps -> loss energies -> masked Boltzmann theta
        (``weights.masked_compute_theta``: stragglers' theta is exactly 0)
    -> Eq. 10 aggregate over the ACTIVE workers, through any composed
       ``schedule:codec`` spec of the two-axis API (core/backends.py)
    -> straggler late-join: inactive workers adopt the aggregate
       m = sum_j theta_j x_j when they arrive (Alg. 4 line 20).

Because the stragglers' theta is zero they contribute nothing to the
reduce, so exclusion needs no gather/compaction — the whole round stays
SPMD and the mask can change every round without recompilation.

Under the two-axis API the async family is NOT a separate set of backends
anymore: every composed spec applies the late-join mask in its ``finalize``
when ``ctx.active`` is set (``None`` = all-active, degenerating to the
synchronous update). The legacy names stay as registry aliases —

``async_einsum``     -> ``einsum``        (meshless reference; the
                                          in-registry twin of the host sim)
``async_shard_map``  -> ``shard_map:f32`` (masked psum under shard_map)
``async_rs_ag``      -> ``rs_ag``         (masked reduce-scatter + FMA +
                                          all-gather, ring payload from the
                                          codec / ``ctx.comm_dtype``)

— and ``async_backend_name`` now maps ANY resolvable spec to its Alg. 4
form, so the async regime composes with the payload axis
(``"hierarchical:int8"`` under a straggler mask is a valid round). Only
``pallas_wagg`` has no masked path. The host simulation stays the semantic
oracle: ``tests/test_async_device.py`` injects the same
``StragglerSchedule`` into both paths and requires leaf-for-leaf parity.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import backends
from repro.core.aggregate import _axes_is_leaf
from repro.core.async_sim import (AsyncResult, StepTimeModel,
                                  StragglerSchedule, make_schedule)
from repro.core.weights import masked_compute_theta

ASYNC_BACKENDS = ("async_einsum", "async_shard_map", "async_rs_ag")

# legacy sync backend -> its Alg. 4 (masked + late-join) alias
_ASYNC_OF = {"einsum": "async_einsum", "shard_map": "async_shard_map",
             "rs_ag": "async_rs_ag"}


def async_backend_name(name: str) -> str:
    """Map a (possibly synchronous) backend name/spec to its Alg. 4 form.

    Legacy names keep their ``async_*`` aliases; any other resolvable
    ``schedule[:codec]`` spec is already mask-capable (the composed
    ``finalize`` applies the late-join whenever ``ctx.active`` is set), so
    it maps to its own canonical spec — e.g. ``"quantized"`` ->
    ``"einsum:int8"``, ``"hierarchical:int8"`` -> itself. ``pallas_wagg``
    is the one schedule with no masked path.
    """
    if name in ASYNC_BACKENDS:
        return name
    if name in _ASYNC_OF:
        return _ASYNC_OF[name]
    try:
        sched, codec = backends.resolve_spec(name)
    except KeyError:
        raise ValueError(
            f"aggregation backend {name!r} has no async (Alg. 4) "
            f"counterpart; use a composed 'schedule:codec' spec, one of "
            f"{sorted(_ASYNC_OF)}, or {sorted(ASYNC_BACKENDS)}")
    if not getattr(backends._SCHEDULES[sched], "supports_mask", True):
        raise ValueError(
            f"aggregation schedule {sched!r} has no async (Alg. 4) "
            f"counterpart (no masked/late-join path); use the "
            f"einsum/shard_map/rs_ag schedules")
    return backends.canonical_spec(name)


def validate_active_rounds(active: np.ndarray, rounds: Optional[int] = None):
    """Reject straggler schedules containing an all-False round.

    ``masked_compute_theta`` documents that an all-False mask yields NaNs
    (the softmax of an all ``-inf`` row) rather than silently inventing
    weights, and the driver's per-round loss (the mean over the active
    workers) is the mean of an empty slice — NaN again. Both poison the
    entire downstream loss history, so a schedule with an empty round is a
    config error caught loudly HERE, at injection time, not a numerical
    curiosity discovered rounds later. Used by
    ``run_parallel_sgd_on_device`` and ``Trainer.run(straggler_schedule=)``.
    """
    active = np.asarray(active, bool)
    if rounds is not None:
        active = active[:rounds]
    empty = np.flatnonzero(~active.any(axis=-1))
    if empty.size:
        raise ValueError(
            f"straggler schedule has no active worker in round(s) "
            f"{empty.tolist()}: an all-straggler round has no Alg. 4 "
            f"aggregate to late-join (masked theta would be NaN and the "
            f"round loss the mean of an empty slice); every round needs "
            f">= 1 active worker")


# ---------------------------------------------------------------------------
# Masked Eq. 10 + late-join over a tree (compat entry point)
# ---------------------------------------------------------------------------

def _resolve_active(theta: jax.Array, active: Optional[jax.Array]):
    if active is None:
        return jnp.ones(theta.shape, bool)
    return active.astype(bool)


# schedule keyword of the pre-two-axis API -> composed backend name
_SCHEDULE_NAMES = {"einsum": "einsum", "all_reduce": "shard_map:f32",
                   "rs_ag": "rs_ag"}


def weighted_aggregate_async(params: Dict, axes: Dict, theta: jax.Array,
                             active: Optional[jax.Array], beta,
                             mesh=None, schedule: str = "all_reduce",
                             comm_dtype=jnp.float32) -> Dict:
    """Apply the masked Eq. 10 + late-join to all worker leaves.

    ``schedule``: "einsum" (meshless), "all_reduce" (masked psum under
    shard_map) or "rs_ag" (reduce-scatter + FMA + all-gather). Thin compat
    wrapper over the composed backends — the collectives are the SAME
    leaves as the synchronous path with the late-join mask riding
    ``ctx.active``: stragglers carry theta == 0, so the reduce already
    excludes them, and inactive workers adopt the aggregate m (analytically
    equal to sum_j theta_j [(1-beta)x_j + beta*m]).
    """
    if schedule not in _SCHEDULE_NAMES:
        raise ValueError(f"unknown async schedule {schedule!r}; "
                         f"known: {sorted(_SCHEDULE_NAMES)}")
    ctx = backends.AggregationContext(
        mesh=mesh, comm_dtype=comm_dtype,
        active=_resolve_active(theta, active))
    return backends.aggregate_with(_SCHEDULE_NAMES[schedule], params, axes,
                                   theta, beta, ctx=ctx)


# ---------------------------------------------------------------------------
# One compiled Alg. 4 round + the driver loop
# ---------------------------------------------------------------------------

def build_async_round(grad_fn: Callable, axes: Dict, *, lr: float,
                      beta: float = 0.9, a_tilde: float = 1.0,
                      strategy: str = "boltzmann",
                      backend: str = "async_shard_map",
                      ctx: Optional[backends.AggregationContext] = None,
                      jit: bool = True) -> Callable:
    """Build ``round_fn(params, batch, active) -> (params, losses, theta)``.

    One jitted program per p-of-(p+b) round: the local steps, the masked
    Boltzmann theta, the Eq. 10 aggregate, and the straggler late-join all
    trace together — ``active`` is a ``(w,)`` bool input, so a new straggler
    set per round costs no recompilation. ``backend`` accepts any composed
    ``schedule:codec`` spec (or a legacy ``async_*`` alias).

    ``grad_fn(params_stacked, batch) -> (losses (w,), grads_stacked)`` —
    the same contract as ``async_sim.run_parallel_sgd``.
    """
    ctx = backends.DEFAULT_CONTEXT if ctx is None else ctx
    name = async_backend_name(backend)
    backend_obj = backends.get_backend(name)
    if getattr(backend_obj, "needs_mesh", False) and ctx.mesh is None:
        raise ValueError(
            f"async aggregation backend {name!r} places explicit "
            f"collectives and needs ctx.mesh (AggregationContext(mesh=...))")
    w_axes = jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes,
                          is_leaf=_axes_is_leaf)

    def round_fn(params, batch, active):
        losses, grads = grad_fn(params, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        theta = masked_compute_theta(losses, active, a_tilde, strategy)
        params = backend_obj.aggregate(
            params, w_axes, theta, beta,
            ctx=dataclasses.replace(ctx, active=active))
        return params, losses, theta

    return jax.jit(round_fn, donate_argnums=(0,)) if jit else round_fn


def run_parallel_sgd_on_device(grad_fn: Callable, params0: Dict, axes: Dict,
                               batches, *, n_workers: int, backups: int,
                               tau: int, rounds: int, lr: float,
                               time_model: Optional[StepTimeModel] = None,
                               schedule: Optional[StragglerSchedule] = None,
                               a_tilde: float = 1.0, beta: float = 0.9,
                               strategy: str = "boltzmann",
                               synchronous: bool = False,
                               backend: str = "async_shard_map",
                               ctx: Optional[backends.AggregationContext]
                               = None) -> AsyncResult:
    """On-device drop-in for ``async_sim.run_parallel_sgd``.

    Same scheduling semantics (inject the same ``schedule`` for parity),
    but every round executes as one jitted SPMD program through a composed
    aggregation spec. ``AsyncResult.params`` is the final worker-stacked
    parameter tree the parity harness compares leaf-for-leaf against the
    host simulation's.
    """
    if schedule is None:
        if time_model is None:
            raise ValueError("pass either time_model= or schedule=")
        schedule = make_schedule(time_model, rounds=rounds, tau=tau,
                                 n_workers=n_workers, backups=backups,
                                 synchronous=synchronous)
    validate_active_rounds(schedule.active, rounds=rounds)
    w = n_workers + backups
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (w,) + x.shape), params0)
    round_fn = build_async_round(grad_fn, axes, lr=lr, beta=beta,
                                 a_tilde=a_tilde, strategy=strategy,
                                 backend=backend, ctx=ctx)

    losses_hist = []
    for r in range(rounds):
        batch = next(batches)                      # (w, tau*b_local, ...)
        active = jnp.asarray(schedule.active[r])
        params, losses, _ = round_fn(params, batch, active)
        losses_np = np.asarray(losses)
        losses_hist.append(float(losses_np[schedule.active[r]].mean()))

    wall = float(schedule.round_wall[:rounds].sum())
    dropped = int((~schedule.active[:rounds]).sum())
    return AsyncResult(np.asarray(losses_hist), wall, dropped, params)
