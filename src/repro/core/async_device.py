"""On-device asynchronous WASGD+ (paper Alg. 4) through the backend registry.

``core/async_sim.py`` reproduces Alg. 4's *scheduling semantics* as a
host-side numpy event simulation; this module runs the same p-of-(p+b)
round as ONE jitted program on the worker mesh axis. Each worker's activity
is a traced ``(w,)`` boolean mask:

    local tau steps -> loss energies -> masked Boltzmann theta
        (``weights.masked_compute_theta``: stragglers' theta is exactly 0)
    -> Eq. 10 aggregate over the ACTIVE workers, through any composed
       ``schedule:codec`` spec of the two-axis API (core/backends.py)
    -> straggler late-join: inactive workers adopt the aggregate
       m = sum_j theta_j x_j when they arrive (Alg. 4 line 20).

Because the stragglers' theta is zero they contribute nothing to the
reduce, so exclusion needs no gather/compaction — the whole round stays
SPMD and the mask can change every round without recompilation.

Under the two-axis API the async family is NOT a separate set of backends
anymore: every composed spec applies the late-join mask in its ``finalize``
when ``ctx.active`` is set (``None`` = all-active, degenerating to the
synchronous update). The legacy names stay as registry aliases —

``async_einsum``     -> ``einsum``        (meshless reference; the
                                          in-registry twin of the host sim)
``async_shard_map``  -> ``shard_map:f32`` (masked psum under shard_map)
``async_rs_ag``      -> ``rs_ag``         (masked reduce-scatter + FMA +
                                          all-gather, ring payload from the
                                          codec / ``ctx.comm_dtype``)

— and ``async_backend_name`` now maps ANY resolvable spec to its Alg. 4
form, so the async regime composes with the payload axis
(``"hierarchical:int8"`` under a straggler mask is a valid round). Since
the v2 fused kernel that includes ``pallas_wagg``: the activity mask is
applied inside the kernel's VMEM pass, so the on-device round can select
``pallas_wagg:<codec>`` like any other spec. The host simulation stays the
semantic oracle: ``tests/test_async_device.py`` injects the same
``StragglerSchedule`` into both paths and requires leaf-for-leaf parity.

Worker assessment comes from the policy axis (core/weights.py): the
drivers take ``policy=`` spec strings / ``WeightPolicy`` objects (legacy
``strategy``/``a_tilde`` stay as bitwise aliases), with stateful policy
state threading across rounds. ``run_parallel_sgd_on_device(
measure_times=True)`` additionally derives the Alg. 4 activity mask from
MEASURED per-device round times — no ``StepTimeModel`` or precomputed
schedule — and feeds the measurements to time-consuming policy stages
(``time_aware``) via ``observe_times``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import backends
from repro.core import weights as weights_mod
from repro.core.aggregate import _axes_is_leaf
from repro.core.async_sim import (AsyncResult, StepTimeModel,
                                  StragglerSchedule, make_schedule)
from repro.core.weights import masked_compute_theta

ASYNC_BACKENDS = ("async_einsum", "async_shard_map", "async_rs_ag")

# legacy sync backend -> its Alg. 4 (masked + late-join) alias
_ASYNC_OF = {"einsum": "async_einsum", "shard_map": "async_shard_map",
             "rs_ag": "async_rs_ag"}


def async_backend_name(name: str) -> str:
    """Map a (possibly synchronous) backend name/spec to its Alg. 4 form.

    Legacy names keep their ``async_*`` aliases; any other resolvable
    ``schedule[:codec]`` spec is already mask-capable (the composed
    ``finalize`` applies the late-join whenever ``ctx.active`` is set), so
    it maps to its own canonical spec — e.g. ``"quantized"`` ->
    ``"einsum:int8"``, ``"hierarchical:int8"`` -> itself, ``"pallas_wagg"``
    -> ``"pallas_wagg:f32"`` (the v2 fused kernel applies the mask in its
    VMEM pass). Schedules registered with ``supports_mask=False`` still
    raise — there is no Alg. 4 round without a late-join path.
    """
    if name in ASYNC_BACKENDS:
        return name
    if name in _ASYNC_OF:
        return _ASYNC_OF[name]
    try:
        sched, codec = backends.resolve_spec(name)
    except KeyError:
        raise ValueError(
            f"aggregation backend {name!r} has no async (Alg. 4) "
            f"counterpart; use a composed 'schedule:codec' spec, one of "
            f"{sorted(_ASYNC_OF)}, or {sorted(ASYNC_BACKENDS)}")
    if not getattr(backends._SCHEDULES[sched], "supports_mask", True):
        raise ValueError(
            f"aggregation schedule {sched!r} has no async (Alg. 4) "
            f"counterpart (no masked/late-join path); use the "
            f"einsum/shard_map/rs_ag schedules")
    return backends.canonical_spec(name)


def validate_active_rounds(active: np.ndarray, rounds: Optional[int] = None):
    """Reject straggler schedules containing an all-False round.

    ``masked_compute_theta`` documents that an all-False mask yields NaNs
    (the softmax of an all ``-inf`` row) rather than silently inventing
    weights, and the driver's per-round loss (the mean over the active
    workers) is the mean of an empty slice — NaN again. Both poison the
    entire downstream loss history, so a schedule with an empty round is a
    config error caught loudly HERE, at injection time, not a numerical
    curiosity discovered rounds later. Used by
    ``run_parallel_sgd_on_device`` and ``Trainer.run(straggler_schedule=)``.
    """
    active = np.asarray(active, bool)
    if rounds is not None:
        active = active[:rounds]
    empty = np.flatnonzero(~active.any(axis=-1))
    if empty.size:
        raise ValueError(
            f"straggler schedule has no active worker in round(s) "
            f"{empty.tolist()}: an all-straggler round has no Alg. 4 "
            f"aggregate to late-join (masked theta would be NaN and the "
            f"round loss the mean of an empty slice); every round needs "
            f">= 1 active worker")


def resize_active_mask(active, new_p: int) -> jnp.ndarray:
    """Rebuild the Alg. 4 activity mask after a membership resize
    (core/membership.py): worker ``i`` keeps slot ``i`` for
    ``i < min(old_p, new_p)`` — a straggler that was excluded stays
    excluded — a shrink drops the tail slots, and newcomers join ACTIVE
    (they hold the aggregate, the freshest state in the fleet). A shrink
    that would leave no active worker is the same config error as an
    all-straggler round and raises ``no_active_error`` at the resize, not
    as NaNs rounds later.
    """
    if new_p < 1:
        raise ValueError(f"resize needs new_p >= 1, got {new_p}")
    active = jnp.asarray(active).astype(bool)
    old_p = active.shape[0]
    if new_p <= old_p:
        out = active[:new_p]
        weights_mod._reject_concrete_all_false(out)
        return out
    return jnp.concatenate([active, jnp.ones((new_p - old_p,), bool)])


# ---------------------------------------------------------------------------
# Masked Eq. 10 + late-join over a tree (compat entry point)
# ---------------------------------------------------------------------------

def _resolve_active(theta: jax.Array, active: Optional[jax.Array]):
    if active is None:
        return jnp.ones(theta.shape, bool)
    return active.astype(bool)


# schedule keyword of the pre-two-axis API -> composed backend name
_SCHEDULE_NAMES = {"einsum": "einsum", "all_reduce": "shard_map:f32",
                   "rs_ag": "rs_ag"}


def weighted_aggregate_async(params: Dict, axes: Dict, theta: jax.Array,
                             active: Optional[jax.Array], beta,
                             mesh=None, schedule: str = "all_reduce",
                             comm_dtype=jnp.float32) -> Dict:
    """Apply the masked Eq. 10 + late-join to all worker leaves.

    ``schedule``: "einsum" (meshless), "all_reduce" (masked psum under
    shard_map) or "rs_ag" (reduce-scatter + FMA + all-gather). Thin compat
    wrapper over the composed backends — the collectives are the SAME
    leaves as the synchronous path with the late-join mask riding
    ``ctx.active``: stragglers carry theta == 0, so the reduce already
    excludes them, and inactive workers adopt the aggregate m (analytically
    equal to sum_j theta_j [(1-beta)x_j + beta*m]).
    """
    if schedule not in _SCHEDULE_NAMES:
        raise ValueError(f"unknown async schedule {schedule!r}; "
                         f"known: {sorted(_SCHEDULE_NAMES)}")
    ctx = backends.AggregationContext(
        mesh=mesh, comm_dtype=comm_dtype,
        active=_resolve_active(theta, active))
    return backends.aggregate_with(_SCHEDULE_NAMES[schedule], params, axes,
                                   theta, beta, ctx=ctx)


# ---------------------------------------------------------------------------
# One compiled Alg. 4 round + the driver loop
# ---------------------------------------------------------------------------

def _resolve_backend(backend: str, ctx):
    name = async_backend_name(backend)
    backend_obj = backends.get_backend(name)
    if getattr(backend_obj, "needs_mesh", False) and ctx.mesh is None:
        raise ValueError(
            f"async aggregation backend {name!r} places explicit "
            f"collectives and needs ctx.mesh (AggregationContext(mesh=...))")
    return backend_obj


def _resolve_policy(policy, strategy: str, a_tilde: float):
    """``policy`` spec/object wins; ``None`` aliases the legacy knobs to
    their (stateless, bitwise-identical) kernel policy. The legacy arg is
    kernel-checked first — ``strategy="ema"`` must keep raising the
    unknown-strategy error, not silently build a stateful pipeline."""
    if policy is None:
        weights_mod.validate_config_spec(strategy)
        return weights_mod.parse_policy(strategy, default_a=a_tilde)
    return weights_mod.as_policy(policy, default_a=a_tilde)


def build_async_round(grad_fn: Callable, axes: Dict, *, lr: float,
                      beta: float = 0.9, a_tilde: float = 1.0,
                      strategy: str = "boltzmann",
                      policy=None,
                      backend: str = "async_shard_map",
                      ctx: Optional[backends.AggregationContext] = None,
                      jit: bool = True) -> Callable:
    """Build one jitted p-of-(p+b) round.

    Stateless policy (the default ``strategy``/``a_tilde`` aliases):
    ``round_fn(params, batch, active) -> (params, losses, theta)``.
    Stateful policy (``policy="ema(0.9)|..."``): the policy state threads
    through the round —
    ``round_fn(params, batch, active, pstate)
        -> (params, losses, theta, pstate)``
    (``round_fn.stateful`` tells the caller which signature it got).

    The local steps, the masked policy theta, the Eq. 10 aggregate, and the
    straggler late-join all trace together — ``active`` is a ``(w,)`` bool
    input, so a new straggler set per round costs no recompilation.
    ``backend`` accepts any composed ``schedule:codec`` spec (or a legacy
    ``async_*`` alias).

    ``grad_fn(params_stacked, batch) -> (losses (w,), grads_stacked)`` —
    the same contract as ``async_sim.run_parallel_sgd``.
    """
    ctx = backends.DEFAULT_CONTEXT if ctx is None else ctx
    backend_obj = _resolve_backend(backend, ctx)
    pol = _resolve_policy(policy, strategy, a_tilde)
    w_axes = jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes,
                          is_leaf=_axes_is_leaf)

    def _advance(params, batch, active, pstate):
        losses, grads = grad_fn(params, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        theta, pstate = pol(losses, active, pstate)
        params = backend_obj.aggregate(
            params, w_axes, theta, beta,
            ctx=dataclasses.replace(ctx, active=active))
        return params, losses, theta, pstate

    if pol.stateful:
        def round_fn(params, batch, active, pstate):
            return _advance(params, batch, active, pstate)
    else:
        def round_fn(params, batch, active):
            return _advance(params, batch, active, ())[:3]

    if jit:
        round_fn = jax.jit(round_fn, donate_argnums=(0,))
    round_fn.stateful = pol.stateful
    return round_fn


def build_split_async_round(grad_fn: Callable, axes: Dict, *, lr: float,
                            beta: float = 0.9,
                            policy="boltzmann",
                            backend: str = "async_einsum",
                            ctx: Optional[backends.AggregationContext]
                            = None,
                            jit: bool = True) -> Tuple[Callable, Callable]:
    """The round split at the host's measurement point (measured-time mode).

    ``measure_times=True`` needs the host in the loop BETWEEN the local
    steps and the aggregation — the activity mask of Alg. 4 line 16 (the
    first p arrivals) is derived from each worker's measured completion of
    its local steps, so the fused single-program round of
    ``build_async_round`` is split into two jitted programs:

        ``local_fn(params, batch) -> (params, losses)``
            tau local steps, no collectives — per-device completion of
            THIS program is what ``measure_round_times`` observes;
        ``agg_fn(params, losses, active, pstate)
            -> (params, theta, pstate)``
            masked policy theta + Eq. 10 aggregate + straggler late-join.
    """
    ctx = backends.DEFAULT_CONTEXT if ctx is None else ctx
    backend_obj = _resolve_backend(backend, ctx)
    pol = weights_mod.as_policy(policy)
    w_axes = jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes,
                          is_leaf=_axes_is_leaf)

    def local_fn(params, batch):
        losses, grads = grad_fn(params, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return params, losses

    def agg_fn(params, losses, active, pstate):
        theta, pstate = pol(losses, active, pstate)
        params = backend_obj.aggregate(
            params, w_axes, theta, beta,
            ctx=dataclasses.replace(ctx, active=active))
        return params, theta, pstate

    if jit:
        local_fn = jax.jit(local_fn, donate_argnums=(0,))
        agg_fn = jax.jit(agg_fn, donate_argnums=(0,))
    return local_fn, agg_fn


def measure_round_times(x: jax.Array, w: int) -> np.ndarray:
    """Measured per-device completion times of a worker-stacked output.

    Blocks each addressable shard of ``x`` (device order) and records the
    host clock as its data arrives: on a real mesh, a device's shards
    become ready when THAT device finishes its program, so the recorded
    instants are per-device arrival upper-bounds (monotone in block order —
    a shard blocked later can only report later). Workers sharing a device
    (w/p > 1 copies, or a single host device) share its time; downstream
    tie-breaks are by worker index, matching the stable first-p-arrivals
    rule. This is the measured signal that replaces the host
    ``StepTimeModel``.
    """
    t0 = time.perf_counter()
    times = np.full((w,), np.nan)
    shards = sorted(x.addressable_shards, key=lambda s: s.device.id)
    for sh in shards:
        jax.block_until_ready(sh.data)
        dt = time.perf_counter() - t0
        idx = sh.index[0] if sh.index else slice(None)
        times[idx] = dt
    if np.isnan(times).any():              # non-addressable rows (multi-host)
        times = np.where(np.isnan(times), np.nanmax(times), times)
    return times


def run_parallel_sgd_on_device(grad_fn: Callable, params0: Dict, axes: Dict,
                               batches, *, n_workers: int, backups: int,
                               tau: int, rounds: int, lr: float,
                               time_model: Optional[StepTimeModel] = None,
                               schedule: Optional[StragglerSchedule] = None,
                               measure_times: bool = False,
                               a_tilde: float = 1.0, beta: float = 0.9,
                               strategy: str = "boltzmann",
                               policy=None,
                               synchronous: bool = False,
                               backend: str = "async_shard_map",
                               ctx: Optional[backends.AggregationContext]
                               = None) -> AsyncResult:
    """On-device drop-in for ``async_sim.run_parallel_sgd``.

    Same scheduling semantics (inject the same ``schedule`` for parity),
    but every round executes as one jitted SPMD program through a composed
    aggregation spec. ``AsyncResult.params`` is the final worker-stacked
    parameter tree the parity harness compares leaf-for-leaf against the
    host simulation's.

    ``policy`` (spec string or ``WeightPolicy``) selects the worker-
    assessment policy; stateful policy state threads across the jitted
    rounds. ``None`` keeps the legacy ``strategy``/``a_tilde`` kernels.

    ``measure_times=True`` drives Alg. 4 line 16 from MEASURED per-device
    round times instead of any host-side model: no ``time_model`` or
    ``schedule`` is needed. The round splits at the measurement point
    (``build_split_async_round``) — after each round's local steps the
    host records every device's completion (``measure_round_times``), the
    first ``n_workers`` arrivals form the aggregation set, and the measured
    times are fed to the policy (``observe_times`` — the ``time_aware``
    stage weights workers by real speed). ``AsyncResult.round_times`` holds
    the measurements; ``wall`` is the sum of the per-round gate times
    (the p-th measured arrival).
    """
    w = n_workers + backups
    pol = _resolve_policy(policy, strategy, a_tilde)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (w,) + x.shape), params0)

    if measure_times:
        if schedule is not None or time_model is not None:
            raise ValueError(
                "measure_times=True derives the activity schedule from "
                "measured per-device round times; don't pass time_model= "
                "or schedule= as well")
        local_fn, agg_fn = build_split_async_round(
            grad_fn, axes, lr=lr, beta=beta, policy=pol, backend=backend,
            ctx=ctx)
        pstate = pol.init_state(w)
        losses_hist, times_hist = [], []
        wall = 0.0
        dropped = 0
        for r in range(rounds):
            batch = next(batches)                  # (w, tau*b_local, ...)
            params, losses = local_fn(params, batch)
            times = measure_round_times(losses, w)
            order = np.argsort(times, kind="stable")
            active = np.zeros((w,), bool)
            active[order[:n_workers]] = True       # first p arrivals
            wall += float(times[order[n_workers - 1]])
            dropped += int(backups)
            pstate = pol.observe_times(pstate, jnp.asarray(times))
            params, _, pstate = agg_fn(params, losses, jnp.asarray(active),
                                       pstate)
            losses_hist.append(float(np.asarray(losses)[active].mean()))
            times_hist.append(times)
        return AsyncResult(np.asarray(losses_hist), wall, dropped, params,
                           np.asarray(times_hist))

    if schedule is None:
        if time_model is None:
            raise ValueError("pass either time_model= or schedule= "
                             "(or measure_times=True)")
        schedule = make_schedule(time_model, rounds=rounds, tau=tau,
                                 n_workers=n_workers, backups=backups,
                                 synchronous=synchronous)
    validate_active_rounds(schedule.active, rounds=rounds)
    round_fn = build_async_round(grad_fn, axes, lr=lr, beta=beta,
                                 a_tilde=a_tilde, strategy=strategy,
                                 policy=pol, backend=backend, ctx=ctx)
    pstate = pol.init_state(w)

    losses_hist = []
    for r in range(rounds):
        batch = next(batches)                      # (w, tau*b_local, ...)
        active = jnp.asarray(schedule.active[r])
        if round_fn.stateful:
            params, losses, _, pstate = round_fn(params, batch, active,
                                                 pstate)
        else:
            params, losses, _ = round_fn(params, batch, active)
        losses_np = np.asarray(losses)
        losses_hist.append(float(losses_np[schedule.active[r]].mean()))

    wall = float(schedule.round_wall[:rounds].sum())
    dropped = int((~schedule.active[:rounds]).sum())
    return AsyncResult(np.asarray(losses_hist), wall, dropped, params)
