"""Asynchronous WASGD+ (paper Alg. 4) as an event-driven simulation.

On a TPU pod SPMD is lockstep, so the async/backup-worker variant has no
native execution analogue (DESIGN.md §2) — but its *scheduling semantics*
can be simulated exactly: p + b workers with heterogeneous step-time
distributions; at each communication point a worker aggregates as soon as
the FIRST p round-results are available (Alg. 4 line 16), so the b slowest
workers of the round are excluded from that aggregation and adopt it late.

The simulation advances real parameters (any loss_fn) while tracking
simulated wall-clock, which reproduces the paper's Sec. 3.5 decision rule:
high step-time variance + small tau => async wins; low variance => sync.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import aggregate as agg
from repro.core import backends
from repro.core.weights import compute_theta


def masked_theta(losses: np.ndarray, active: np.ndarray,
                 a_tilde: float = 1.0, strategy: str = "boltzmann"
                 ) -> np.ndarray:
    """θ over the p active workers of a p-of-p+b round; 0 for stragglers.

    Inactive workers must be masked out *before* ``normalize_energy`` runs
    inside ``compute_theta``: a large sentinel energy (the old ``1e30``
    approach) dominates the normalizing sum, collapses the active workers'
    normalized energies toward 0, and degenerates the Boltzmann weights to
    near-equal regardless of loss.
    """
    losses = np.asarray(losses)
    active = np.asarray(active, bool)
    theta_active = np.asarray(compute_theta(
        jnp.asarray(losses[active], jnp.float32), strategy, a_tilde))
    theta = np.zeros(losses.shape[0], np.float32)
    theta[active] = theta_active
    return theta / theta.sum()


class StepTimeModel:
    """Per-worker step-time sampler: lognormal base + straggler spikes."""

    def __init__(self, n_workers: int, mean: float = 1.0, sigma: float = 0.1,
                 straggle_p: float = 0.0, straggle_mult: float = 10.0,
                 seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.n = n_workers
        self.mean, self.sigma = mean, sigma
        self.straggle_p, self.straggle_mult = straggle_p, straggle_mult

    def round_times(self, tau: int) -> np.ndarray:
        """Simulated wall-time for each worker to finish tau local steps."""
        t = self.rng.lognormal(np.log(self.mean), self.sigma,
                               size=(self.n, tau))
        spikes = self.rng.random((self.n, tau)) < self.straggle_p
        t = np.where(spikes, t * self.straggle_mult, t)
        return t.sum(axis=1)


class AsyncResult(NamedTuple):
    losses: np.ndarray          # per-round mean loss (over active workers)
    wall: float                 # simulated wall-clock
    dropped_rounds: int         # total straggler exclusions


def run_parallel_sgd(loss_fn: Callable, grad_fn: Callable, params0: Dict,
                     axes: Dict, batches, *, n_workers: int, backups: int,
                     tau: int, rounds: int, lr: float,
                     time_model: StepTimeModel, a_tilde: float = 1.0,
                     beta: float = 0.9, synchronous: bool = False,
                     backend: str = "einsum",
                     ctx: Optional[backends.AggregationContext] = None
                     ) -> AsyncResult:
    """Alg. 4 if ``synchronous=False`` (p of p+b fastest aggregate), Alg. 1
    if True (barrier over all workers; backups just add capacity).

    ``grad_fn(params_stacked, batch) -> (losses (w,), grads_stacked)``.
    ``backend`` names the aggregation backend (core/backends.py) applying
    Eq. 10 over the active workers; ``ctx`` carries its mesh/comm_dtype/
    n_pods knobs (defaults suit the meshless ``einsum`` family).
    """
    ctx = backends.DEFAULT_CONTEXT if ctx is None else ctx
    w = n_workers + backups
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (w,) + x.shape), params0)
    w_axes = jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes,
                          is_leaf=agg._axes_is_leaf)

    wall = 0.0
    dropped = 0
    losses_hist = []
    for r in range(rounds):
        batch = next(batches)                      # (w, tau*b_local, ...)
        losses, grads = grad_fn(params, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)

        t = time_model.round_times(tau)
        if synchronous:
            wall += float(t.max())
            active = np.ones(w, bool)
        else:
            order = np.argsort(t)
            active = np.zeros(w, bool)
            active[order[:n_workers]] = True       # first p arrivals
            wall += float(t[order[n_workers - 1]]) # p-th arrival gates
            dropped += int((~active).sum())

        theta = masked_theta(np.asarray(losses), active, a_tilde)
        new_params = backends.aggregate_with(
            backend, params, w_axes, jnp.asarray(theta, jnp.float32), beta,
            ctx=ctx)
        # stragglers adopt the aggregate fully when they arrive (late join)
        params = jax.tree.map(
            lambda new, old: jnp.where(
                jnp.asarray(active).reshape((-1,) + (1,) * (old.ndim - 1)),
                new, jnp.tensordot(jnp.asarray(theta, jnp.float32),
                                   new.astype(jnp.float32), axes=1)[None]
                .astype(old.dtype)),
            new_params, params)
        losses_hist.append(float(np.mean(np.asarray(losses)[active])))
    return AsyncResult(np.asarray(losses_hist), wall, dropped)
