"""Asynchronous WASGD+ (paper Alg. 4) as an event-driven simulation.

On a TPU pod SPMD is lockstep, so the async/backup-worker variant has no
native execution analogue (DESIGN.md §2) — but its *scheduling semantics*
can be simulated exactly: p + b workers with heterogeneous step-time
distributions; at each communication point a worker aggregates as soon as
the FIRST p round-results are available (Alg. 4 line 16), so the b slowest
workers of the round are excluded from that aggregation and adopt it late.

The simulation advances real parameters (any loss_fn) while tracking
simulated wall-clock, which reproduces the paper's Sec. 3.5 decision rule:
high step-time variance + small tau => async wins; low variance => sync.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import aggregate as agg
from repro.core import backends
from repro.core import weights as weights_mod
from repro.core.weights import compute_theta, no_active_error


def masked_theta(losses: np.ndarray, active: np.ndarray,
                 a_tilde: float = 1.0, strategy: str = "boltzmann"
                 ) -> np.ndarray:
    """θ over the p active workers of a p-of-p+b round; 0 for stragglers.

    Inactive workers must be masked out *before* ``normalize_energy`` runs
    inside ``compute_theta``: a large sentinel energy (the old ``1e30``
    approach) dominates the normalizing sum, collapses the active workers'
    normalized energies toward 0, and degenerates the Boltzmann weights to
    near-equal regardless of loss.

    An all-False mask is rejected with the SAME error the traced device
    path (``weights.masked_compute_theta``) raises on concrete masks —
    host and device fail identically instead of the host returning the
    empty-slice garbage it used to.
    """
    losses = np.asarray(losses)
    active = np.asarray(active, bool)
    if active.size and not active.any():
        raise no_active_error()
    theta_active = np.asarray(compute_theta(
        jnp.asarray(losses[active], jnp.float32), strategy, a_tilde))
    theta = np.zeros(losses.shape[0], np.float32)
    theta[active] = theta_active
    return theta / theta.sum()


class StepTimeModel:
    """Per-worker step-time sampler: lognormal base + straggler spikes."""

    def __init__(self, n_workers: int, mean: float = 1.0, sigma: float = 0.1,
                 straggle_p: float = 0.0, straggle_mult: float = 10.0,
                 seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.n = n_workers
        self.mean, self.sigma = mean, sigma
        self.straggle_p, self.straggle_mult = straggle_p, straggle_mult

    def round_times(self, tau: int) -> np.ndarray:
        """Simulated wall-time for each worker to finish tau local steps."""
        t = self.rng.lognormal(np.log(self.mean), self.sigma,
                               size=(self.n, tau))
        spikes = self.rng.random((self.n, tau)) < self.straggle_p
        t = np.where(spikes, t * self.straggle_mult, t)
        return t.sum(axis=1)


class StragglerSchedule(NamedTuple):
    """Precomputed p-of-(p+b) activity schedule — the host-side scheduling
    semantics of Alg. 4, separated from parameter advancement so the SAME
    schedule can be injected into both this event simulation and the
    on-device path (core/async_device.py) for leaf-for-leaf parity tests."""
    active: np.ndarray          # (rounds, w) bool — round-r aggregation set
    round_wall: np.ndarray      # (rounds,) simulated gate time per round


def make_schedule(time_model: StepTimeModel, *, rounds: int, tau: int,
                  n_workers: int, backups: int = 0,
                  synchronous: bool = False) -> StragglerSchedule:
    """Sample the per-round activity sets from the step-time model.

    Async (Alg. 4 line 16): the first ``n_workers`` arrivals of each round
    form the aggregation set, and the p-th arrival gates the round's wall
    time. Synchronous (Alg. 1): everyone is active, the slowest gates.
    """
    w = n_workers + backups
    active = np.ones((rounds, w), bool)
    round_wall = np.zeros(rounds)
    for r in range(rounds):
        t = time_model.round_times(tau)
        if synchronous:
            round_wall[r] = t.max()
        else:
            order = np.argsort(t)
            active[r] = False
            active[r, order[:n_workers]] = True    # first p arrivals
            round_wall[r] = t[order[n_workers - 1]]
    return StragglerSchedule(active, round_wall)


class AsyncResult(NamedTuple):
    losses: np.ndarray          # per-round mean loss (over active workers)
    wall: float                 # simulated (or measured) wall-clock
    dropped_rounds: int         # total straggler exclusions
    params: Optional[Dict] = None   # final worker-stacked parameter tree
                                    # (leaf-for-leaf parity vs async_device)
    round_times: Optional[np.ndarray] = None
                                # (rounds, w) MEASURED per-device round
                                # times (async_device measure_times=True;
                                # None when a host schedule drove the run)


def run_parallel_sgd(loss_fn: Callable, grad_fn: Callable, params0: Dict,
                     axes: Dict, batches, *, n_workers: int, backups: int,
                     tau: int, rounds: int, lr: float,
                     time_model: Optional[StepTimeModel] = None,
                     a_tilde: float = 1.0,
                     beta: float = 0.9, synchronous: bool = False,
                     strategy: str = "boltzmann",
                     policy=None,
                     backend: str = "einsum",
                     schedule: Optional[StragglerSchedule] = None,
                     ctx: Optional[backends.AggregationContext] = None
                     ) -> AsyncResult:
    """Alg. 4 if ``synchronous=False`` (p of p+b fastest aggregate), Alg. 1
    if True (barrier over all workers; backups just add capacity).

    ``grad_fn(params_stacked, batch) -> (losses (w,), grads_stacked)``.
    ``backend`` names the aggregation backend (core/backends.py) applying
    Eq. 10 over the active workers; ``ctx`` carries its mesh/comm_dtype/
    n_pods knobs (defaults suit the meshless ``einsum`` family).

    ``policy`` (a spec string or ``WeightPolicy``) selects the worker-
    assessment policy; it overrides ``strategy``/``a_tilde`` and may be
    stateful (the state threads across the simulated rounds), so this
    event simulation stays the parity oracle for policy-driven on-device
    runs too. ``None`` keeps the legacy ``masked_theta`` path bit-for-bit.

    ``schedule`` overrides ``time_model``: a precomputed activity schedule
    (``make_schedule``), so parity tests can inject the exact same straggler
    pattern here and into ``async_device.run_parallel_sgd_on_device``.
    """
    ctx = backends.DEFAULT_CONTEXT if ctx is None else ctx
    if schedule is None:
        if time_model is None:
            raise ValueError("pass either time_model= or schedule=")
        schedule = make_schedule(time_model, rounds=rounds, tau=tau,
                                 n_workers=n_workers, backups=backups,
                                 synchronous=synchronous)
    w = n_workers + backups
    pol = (None if policy is None
           else weights_mod.as_policy(policy, default_a=a_tilde))
    pstate = pol.init_state(w) if pol is not None else None
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (w,) + x.shape), params0)
    w_axes = jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes,
                          is_leaf=agg._axes_is_leaf)

    wall = 0.0
    dropped = 0
    losses_hist = []
    for r in range(rounds):
        batch = next(batches)                      # (w, tau*b_local, ...)
        losses, grads = grad_fn(params, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)

        active = schedule.active[r]
        wall += float(schedule.round_wall[r])
        dropped += int((~active).sum())

        if pol is None:
            theta = masked_theta(np.asarray(losses), active, a_tilde,
                                 strategy)
        else:
            theta_j, pstate = pol(jnp.asarray(losses),
                                  jnp.asarray(active), pstate)
            theta = np.asarray(theta_j, np.float32)
        new_params = backends.aggregate_with(
            backend, params, w_axes, jnp.asarray(theta, jnp.float32), beta,
            ctx=ctx)
        # stragglers adopt the aggregate fully when they arrive (late join)
        params = jax.tree.map(
            lambda new, old: jnp.where(
                jnp.asarray(active).reshape((-1,) + (1,) * (old.ndim - 1)),
                new, jnp.tensordot(jnp.asarray(theta, jnp.float32),
                                   new.astype(jnp.float32), axes=1)[None]
                .astype(old.dtype)),
            new_params, params)
        losses_hist.append(float(np.mean(np.asarray(losses)[active])))
    return AsyncResult(np.asarray(losses_hist), wall, dropped, params)
