"""WASGD+ round orchestration: the communication step of Alg. 1.

``communicate`` consumes the per-worker loss energies accumulated during the
round (core/energy.py), computes θ with the configured weight-evaluating
function (core/weights.py), applies the weighted aggregation (Eq. 10) to the
parameter tree through the backend selected by ``wcfg.backend``
(core/backends.py), and returns the Judge z-scores for the order search.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import WASGDConfig
from repro.core import backends
from repro.core.order import judge_scores
from repro.core.weights import omega, policy_from_config, theta_entropy


class CommResult(NamedTuple):
    params: Dict
    theta: jax.Array            # (p,)
    scores: jax.Array           # (p,) Judge z-scores
    metrics: Dict


def communicate(params: Dict, axes: Dict, h: jax.Array, wcfg: WASGDConfig,
                leaf_fn=None, mesh=None, policy_state=None) -> CommResult:
    """One communication (lines 12-19 of Alg. 1), SPMD formulation.

    ``h``: (p,) loss energies. The paper's send/wait/arrange steps are
    subsumed by SPMD: ``h`` is already globally consistent (tiny all-gather)
    and the weighted sum lowers to one all-reduce over the worker axis.

    theta comes from the worker-assessment policy the config selects
    (``wcfg.policy`` spec or the legacy ``strategy``/``a_tilde`` aliases —
    core/weights.py). ``communicate`` is the stateless compat entry point:
    a stateful policy starts from a fresh state unless the caller threads
    ``policy_state=`` through; either way the advanced state rides out in
    ``metrics["policy_state"]`` (the train-step rules thread it through
    ``comm_state`` instead).

    The aggregation spec comes from ``wcfg.backend`` — a two-axis
    ``"schedule:codec"`` composition, a legacy alias, or ``"auto"``
    (measurement-driven selection per parameter tree) — or is composed from
    the legacy ``quantize_comm``/``hierarchical``/``sharded_aggregate``
    booleans, with ``comm_dtype``/``n_pods``/``mesh`` riding in the backend
    context (core/backends.py) — every config knob reaches the computation.
    ``leaf_fn`` remains as a legacy escape hatch that bypasses the registry.
    """
    pol = policy_from_config(wcfg)
    theta, policy_state = pol(h, None, policy_state)
    new_params = backends.aggregate_from_config(wcfg, params, axes, theta,
                                                mesh=mesh, leaf_fn=leaf_fn)
    scores = judge_scores(h)
    metrics = {
        "theta_entropy": theta_entropy(theta),
        "omega": omega(theta),
        "h_mean": h.mean(),
        "h_min": h.min(),
        "policy_state": policy_state,
    }
    return CommResult(new_params, theta, scores, metrics)
