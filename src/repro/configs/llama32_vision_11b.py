"""llama-3.2-vision-11b — VLM backbone with interleaved cross-attention
image layers [hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder + projector are a STUB per the assignment: the
framework consumes precomputed patch embeddings of shape
``(batch, n_media_tokens, d_model)`` supplied by ``input_specs``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,            # 8 cross-attn layers in 40
    n_media_tokens=1600,           # one tile of 1601-1 patch embeddings (stub)
    source="[hf:meta-llama/Llama-3.2-11B-Vision]",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        cross_attn_every=2,
        n_media_tokens=16,
        remat=False,
        source=CONFIG.source,
    )
