"""mamba2-370m — attention-free SSM with state-space duality (SSD)
[arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=64),
    attn_every=0,                  # pure SSM — no attention layers
    tie_embeddings=True,
    source="[arXiv:2405.21060] Transformers are SSMs (Mamba-2)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=512,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=32, chunk_size=16),
        attn_every=0,
        tie_embeddings=True,
        remat=False,
        source=CONFIG.source,
    )
