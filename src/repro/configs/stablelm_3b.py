"""stablelm-3b — dense decoder [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    source="[hf:stabilityai/stablelm-2-1_6b] (3B family member)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=216,
        vocab_size=512,
        remat=False,
        source=CONFIG.source,
    )
