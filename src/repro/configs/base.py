"""Configuration dataclasses and the architecture registry.

Every assigned architecture gets one module in ``repro.configs`` exporting a
``CONFIG`` (full-size, dry-run only) and a ``smoke_config()`` (reduced, runs
on CPU). ``repro.configs.registry`` maps ``--arch`` ids to those modules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) mixer configuration."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 64
    conv_width: int = 4
    dt_min: float = 1e-3
    dt_max: float = 1e-1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                         # dense FFN hidden (0 for pure-SSM / pure-MoE)
    vocab_size: int
    head_dim: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6

    # Attention pattern ------------------------------------------------------
    attn_window: Optional[int] = None   # sliding-window size; None = full
    global_attn_every: int = 0          # >0: layer idx % every == every-1 is global
    cross_attn_every: int = 0           # >0 (vlm): cross-attn at idx % every == every-1
    n_media_tokens: int = 0             # vlm: patch tokens per example (stub frontend)

    # Audio ------------------------------------------------------------------
    n_codebooks: int = 0                # musicgen: parallel EnCodec streams

    # MoE --------------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    moe_every: int = 1                  # MoE FFN at layer idx % moe_every == moe_every-1
    expert_sharding: str = "ep_data"    # "ep_data": single expert-parallel copy
                                        #   sharded over the worker axis (all-to-all
                                        #   dispatch; required for arctic-class MoE)
                                        # "worker": full per-worker expert copies —
                                        #   experts join the weighted aggregation,
                                        #   zero dispatch traffic (§Perf, olmoe)

    # SSM / hybrid -----------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0                 # hybrid: attention at idx % attn_every == attn_every-1
                                        # (0 with ssm set => pure SSM, no attention)

    # Numerics ---------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    sharded_ce: bool = False            # §Perf: vocab-sharded cross-entropy
                                        # (one-hot contraction + logsumexp, no
                                        # gather over the sharded vocab dim)
    tie_embeddings: bool = False
    remat: bool = True                  # activation checkpointing per block
    logits_softcap: float = 0.0
    unroll_attn_scan: bool = False      # dry-run: unroll flash KV scan so HLO
                                        # cost analysis sees every block
    windowed_qblock: bool = False       # §Perf: q-blocked sliding-window path
                                        # that skips out-of-window kv blocks

    # Citation (provenance of the numbers above) -----------------------------
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded so TP sharding over the model axis divides evenly."""
        return int(math.ceil(self.vocab_size / 256) * 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_is_attn(self, idx: int) -> bool:
        """Hybrid/SSM schedule: which mixer does layer ``idx`` use."""
        if self.ssm is None:
            return True
        if self.attn_every <= 0:
            return False                    # pure SSM
        return idx % self.attn_every == self.attn_every - 1

    def layer_is_ssm(self, idx: int) -> bool:
        return self.ssm is not None and not self.layer_is_attn(idx)

    def layer_is_moe(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return idx % self.moe_every == self.moe_every - 1

    def layer_is_global_attn(self, idx: int) -> bool:
        """gemma3-style local:global interleave; True = full-context attention."""
        if self.attn_window is None:
            return True
        if self.global_attn_every <= 0:
            return False
        return idx % self.global_attn_every == self.global_attn_every - 1

    def layer_is_cross_attn(self, idx: int) -> bool:
        if self.cross_attn_every <= 0:
            return False
        return idx % self.cross_attn_every == self.cross_attn_every - 1

    def window_for_layer(self, idx: int) -> Optional[int]:
        if self.attn_window is not None and not self.layer_is_global_attn(idx):
            return self.attn_window
        return None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.padded_vocab * d                       # embed
        if not self.tie_embeddings:
            heads = max(1, self.n_codebooks)
            n += heads * self.padded_vocab * d           # lm head(s)
        for i in range(self.n_layers):
            if self.layer_is_attn(i):
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                n += 2 * d                               # norms
                if self.layer_is_cross_attn(i):
                    n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                    n += d
            if self.layer_is_ssm(i):
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                n += d * (2 * di + 2 * s.d_state + nh)        # in_proj: z,x,B,C,dt
                n += (di + 2 * s.d_state) * (s.conv_width + 1)  # depthwise conv + bias
                n += 3 * nh + di                              # A_log, D, dt_bias, norm
                n += di * d + d                               # out proj + final norm
            if self.layer_is_moe(i):
                m = self.moe
                n += d * m.n_experts                          # router
                n += m.n_experts * 3 * d * m.d_ff_expert      # gated experts
                if m.dense_residual and self.d_ff > 0:
                    n += 3 * d * self.d_ff
                n += d
            elif self.d_ff > 0 and not self.layer_is_ssm(i):
                n += 3 * d * self.d_ff + d                    # gated dense FFN
        n += d                                                # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive_per_moe_layer = (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        return self.param_count() - n_moe_layers * inactive_per_moe_layer


# ---------------------------------------------------------------------------
# WASGD / training / input-shape configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WASGDConfig:
    """The paper's knobs (Alg. 1)."""
    beta: float = 0.9                 # acceptance of the aggregate (Eq. 10)
    a_tilde: float = 1.0              # Boltzmann temperature^{-1} (Eq. 13); T = 1/a
    tau: int = 4                      # local steps per communication round
    strategy: str = "boltzmann"       # boltzmann | inverse (WASGD v1) | equal | best
    policy: str = ""                  # worker-assessment policy spec
                                      # (core/weights.py): a composed
                                      # "stage|stage|..." pipeline — e.g.
                                      # "boltzmann(a=8)|anneal(cosine)",
                                      # "ema(0.9)|time_aware",
                                      # "trimmed(1)|boltzmann" — of energy
                                      # transforms (ema, time_aware), mask
                                      # refinements (topk, trimmed), one
                                      # kernel (boltzmann/inverse/equal/
                                      # best) and an anneal modifier.
                                      # "" resolves the legacy knobs
                                      # (strategy / a_tilde / a_schedule)
                                      # as aliases, bitwise-identically.
    m_estimate: int = 100             # loss-energy sample budget (Eq. 21/26)
    record_chunks: int = 4            # c in Alg. 2 RecordIndex
    order_search: bool = True         # WASGD+ sample-order search (Judge/OrderGen)
    order_keep_score: float = -1.0    # keep order if z-score <= this (Alg. 2)
    a_schedule: str = "constant"      # beyond-paper: "anneal" raises a_tilde
    anneal_rate: float = 0.05         #   per round: T cools, explore->exploit
    quantize_comm: bool = False       # beyond-paper: int8 aggregation payload
    comm_dtype: str = "float32"       # beyond-paper: bf16 halves ring bytes
    hierarchical: bool = False        # beyond-paper: pod-local then cross-pod 2-hop
    n_pods: int = 1                   # pod count for the hierarchical 2-hop
    sharded_aggregate: bool = False   # beyond-paper: reduce-scatter + local axpy + all-gather
    backend: str = ""                 # two-axis aggregation spec
                                      # (core/backends.py): a composed
                                      # "<schedule>:<codec>" string —
                                      # schedules einsum | hierarchical |
                                      # shard_map | rs_ag | pallas_wagg,
                                      # codecs f32 | bf16 | int8 | int4 —
                                      # e.g. "rs_ag:int8"; a bare schedule
                                      # (codec derived from comm_dtype); a
                                      # legacy alias (quantized,
                                      # async_shard_map, ...); or "auto"
                                      # (select_auto_spec: pick per worker-
                                      # leaf bytes + mesh from recorded
                                      # kernel_bench measurements).
                                      # "" composes it from the legacy
                                      # booleans above
                                      # (backend_name_from_config).
    async_mode: str = "host_sim"      # Alg. 4 execution: "host_sim" keeps the
                                      # p-of-(p+b) regime in the numpy event
                                      # simulation (core/async_sim.py);
                                      # "on_device" runs the masked round as
                                      # one jitted program on the worker mesh
                                      # axis (core/async_device.py) — the
                                      # round's activity mask rides in
                                      # TrainState.comm_state (alongside the
                                      # policy state when the policy is
                                      # stateful).

    def __post_init__(self):
        # Validate the worker-assessment knobs at CONSTRUCTION: an unknown
        # strategy or unparsable policy spec used to fail deep inside
        # tracing; it now fails here, listing the registered policy names.
        # Late import: core.weights is repro-import-free, so the cycle
        # configs -> core -> wasgd -> configs resolves (WASGDConfig is
        # already defined by the time any config is constructed).
        from repro.core.weights import validate_config_spec
        validate_config_spec(self.strategy, self.policy)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    momentum: float = 0.0
    weight_decay: float = 0.0
    optimizer: str = "sgd"            # sgd | momentum | adamw
    global_batch: int = 256
    seq_len: int = 4096
    wasgd: WASGDConfig = WASGDConfig()
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode
    window_override: Optional[int] = None   # sub-quadratic override for dense archs


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode", window_override=8192),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


def dtype_of(name: str):
    return jnp.dtype(name)
