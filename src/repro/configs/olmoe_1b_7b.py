"""olmoe-1b-7b — 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,                         # every FFN is MoE
    vocab_size=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    moe_every=1,
    source="[arXiv:2409.02060] OLMoE: Open Mixture-of-Experts Language Models",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=0,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
        moe_every=1,
        remat=False,
        source=CONFIG.source,
    )
