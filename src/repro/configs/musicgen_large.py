"""musicgen-large — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].

The EnCodec conv codec (audio frontend) is a STUB per the assignment: the
model consumes the 4 parallel codebook token streams directly
(``tokens: (batch, seq, n_codebooks) int32``) with summed codebook
embeddings and 4 parallel output heads (the "delay pattern" interleave is a
data-layout concern handled in the pipeline).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    source="[arXiv:2306.05284] Simple and Controllable Music Generation",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke",
        family="audio",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        n_codebooks=4,
        remat=False,
        source=CONFIG.source,
    )
