"""Architecture registry: maps ``--arch`` ids to config modules."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

# arch-id -> module path (all ten assigned architectures)
_ARCH_MODULES: Dict[str, str] = {
    "yi-6b": "repro.configs.yi_6b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "musicgen-large": "repro.configs.musicgen_large",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "jamba-v0.1-52b": "repro.configs.jamba_52b",
    "gemma3-1b": "repro.configs.gemma3_1b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()
