"""arctic-480b — 128-expert top-2 MoE with a dense residual MLP
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,                      # dense residual MLP hidden
    vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True),
    moe_every=1,
    source="[hf:Snowflake/snowflake-arctic-base]",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=96,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, dense_residual=True),
        moe_every=1,
        remat=False,
        source=CONFIG.source,
    )
