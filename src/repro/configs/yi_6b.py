"""yi-6b — dense llama-arch GQA decoder [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    source="[arXiv:2403.04652] Yi: Open Foundation Models by 01.AI",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=1,
        head_dim=32,
        d_ff=344,
        vocab_size=512,
        remat=False,
        source=CONFIG.source,
    )
