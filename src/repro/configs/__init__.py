from repro.configs.base import (
    INPUT_SHAPES,
    SHAPES_BY_NAME,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    TrainConfig,
    WASGDConfig,
)
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config

__all__ = [
    "INPUT_SHAPES",
    "SHAPES_BY_NAME",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "TrainConfig",
    "WASGDConfig",
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
]
