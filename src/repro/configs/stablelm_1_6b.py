"""stablelm-1.6b — dense decoder [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    source="[hf:stabilityai/stablelm-2-1_6b]",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=176,
        vocab_size=512,
        remat=False,
        source=CONFIG.source,
    )
