"""gemma3-1b — dense decoder with 5:1 local:global sliding-window attention
and a 262k vocab [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    attn_window=512,               # local layers: 512-token sliding window
    global_attn_every=6,           # 5 local : 1 global
    tie_embeddings=True,
    logits_softcap=30.0,
    source="[hf:google/gemma-3-1b-pt]",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        attn_window=16,
        global_attn_every=2,
        tie_embeddings=True,
        logits_softcap=30.0,
        remat=False,
        source=CONFIG.source,
    )
