"""jamba-v0.1-52b — hybrid Mamba+attention (1:7 attn:mamba interleave) with
16-expert top-2 MoE every other layer [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    moe_every=2,                   # MoE every other layer, dense FFN otherwise
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, chunk_size=64),
    attn_every=8,                  # 1 attention layer per 8 (1:7 interleave)
    source="[arXiv:2403.19887] Jamba: A Hybrid Transformer-Mamba Language Model",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        n_layers=4,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256),
        moe_every=2,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=32, chunk_size=16),
        attn_every=2,
        remat=False,
        source=CONFIG.source,
    )
