"""Order-managed data pipeline (paper Alg. 1 lines 4-7 + OrderGen) and the
round prefetcher that feeds the pipelined train step.

Each worker traverses the full dataset in its own permutation order; the
epoch is split into ``n_segments`` order segments whose seeds survive or get
reshuffled based on Judge scores (core/order.OrderState). Batches are
assembled worker-major with leading dim ``tau * p * b_local`` to match the
train-step reshape contract.

``RoundPrefetcher`` stages rounds on a background thread (double-buffered by
default) so the host-side index/gather/reshape work for round ``r+1`` — and
the slice of its first worker-major microbatch, which the pipelined train
step feeds into the aggregation schedule's overlap seam — happens while
round ``r`` runs on the devices.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.order import OrderState


class OrderedDataset:
    def __init__(self, data: Dict[str, np.ndarray], n_workers: int, tau: int,
                 b_local: int, n_segments: int = 1,
                 order_state: Optional[OrderState] = None, seed: int = 0,
                 boundary_delay: int = 0):
        self.data = data
        self.n = len(next(iter(data.values())))
        self.p = n_workers
        self.tau = tau
        self.b_local = b_local
        self.n_segments = n_segments
        self.order = order_state or OrderState(n_workers, n_segments, seed)
        self.per_round = tau * b_local           # samples per worker per round
        self.seg_len = self.n // n_segments
        self.rounds_per_segment = max(1, self.seg_len // self.per_round)
        self.rounds_per_epoch = self.rounds_per_segment * n_segments
        # Rounds to wait after a segment boundary before committing that
        # segment's OrderGen keep-or-reshuffle decision. 0 = decide the
        # moment the traversal leaves the segment (Alg. 2 semantics). Under
        # the round prefetcher the generator runs ahead of training by up to
        # ``RoundPrefetcher.run_ahead()`` rounds (depth + 2, NOT just the
        # depth), so a delay >= that keeps every round's Judge scores
        # recorded before the decision fires. A deferred decision never
        # fires mid-traversal of its own segment (see ``batches``).
        self.boundary_delay = int(boundary_delay)

    def segment_of_round(self, r: int) -> int:
        return (r // self.rounds_per_segment) % self.n_segments

    def resize(self, new_p: int):
        """Membership resize at a round boundary: the per-worker index rows
        in ``batches`` follow ``self.p``, and the OrderState's seed columns
        follow the slot contract (survivors keep their permutation, newcomers
        draw fresh seeds — ``OrderState.resize``). Restart ``batches`` at the
        resume round afterwards; a generator already in flight keeps the old
        worker count."""
        if int(new_p) < 1:
            raise ValueError(f"resize needs new_p >= 1, got {new_p}")
        self.p = int(new_p)
        self.order.resize(self.p)

    def batches(self, start_round: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite iterator over rounds; at EACH segment boundary the
        segment just left is ended (``OrderState.end_segment``), so
        OrderGen's keep-or-reshuffle decision (paper Alg. 2) fires per
        segment mid-epoch — not once per epoch for all segments at once,
        which left every segment's decision reading stale epoch-end scores.

        A ``boundary_delay``-deferred decision whose due round lands inside
        a NEW traversal of the same segment (n_segments=1, or a delay >=
        rounds_per_segment) is held until that traversal's next boundary:
        ``order_for`` re-derives the permutation from the seed every round,
        so reshuffling mid-traversal would switch the sample order under an
        epoch in progress (some samples seen twice, others skipped).

        ``start_round`` resumes the round counter mid-traversal — the
        elastic Trainer rebuilds this generator at each membership resize
        (and a checkpoint resume) so the new generator picks up at the
        round the old one stopped, with the new ``self.p``.
        """
        r = int(start_round)
        pending = []                     # (fire_at_round, segment) FIFO
        while True:
            seg = self.segment_of_round(r)
            within = r % self.rounds_per_segment
            if within == 0 and r > 0:
                pending.append((r + self.boundary_delay,
                                self.segment_of_round(r - 1)))
            while pending and pending[0][0] <= r:
                if pending[0][1] == seg and within != 0:
                    break                # never reshuffle mid-traversal
                self.order.end_segment(pending.pop(0)[1])
            # per-worker sample indices for this round
            idx = np.empty((self.p, self.per_round), np.int64)
            for w in range(self.p):
                perm = self.order.order_for(seg, w, self.seg_len)
                start = (within * self.per_round) % max(
                    1, self.seg_len - self.per_round + 1)
                sel = perm[start:start + self.per_round]
                if len(sel) < self.per_round:   # wrap
                    sel = np.concatenate([sel, perm[: self.per_round - len(sel)]])
                idx[w] = seg * self.seg_len + sel
            flat = idx.reshape(-1)               # worker-major: (p * tau * b_local)
            batch = {k: v[flat] for k, v in self.data.items()}
            yield batch
            r += 1


# ---------------------------------------------------------------------------
# Round prefetch: the host side of the pipelined train step
# ---------------------------------------------------------------------------

def first_microbatch(batch: Dict, n_workers: int, tau: int) -> Dict:
    """Slice the first worker-major microbatch out of a round batch.

    Every leaf has leading dim ``B = tau * p * b_local`` laid out
    worker-major (the ``train/step.py`` reshape contract); the result has
    leading dims ``(p, b_local)`` and is leaf-for-leaf the ``t = 0`` slice
    the train step's ``reshape_batch`` produces — the pipelined round's
    parity guarantee rests on this equality (tests/test_pipeline.py).
    """
    import jax

    def f(x):
        b = x.shape[0]
        if b % (tau * n_workers):
            raise ValueError(
                f"batch dim {b} not divisible by tau*p = {tau}*{n_workers}")
        bl = b // (tau * n_workers)
        return np.asarray(x).reshape(n_workers, tau, bl, *x.shape[1:])[:, 0]

    return jax.tree.map(f, batch)


class _PrefetchError:
    def __init__(self, exc: BaseException):
        self.exc = exc


_END = object()


class RoundPrefetcher:
    """Double-buffered round staging for the pipelined train step.

    Wraps a round-batch iterator and yields ``(batch_r, first_{r+1})``
    tuples, where ``first_{r+1}`` is round ``r+1``'s first worker-major
    microbatch (``first_microbatch``). A daemon thread pulls rounds ahead
    (``depth`` staged rounds in flight), so the host-side index/gather/
    reshape/transfer staging of the NEXT round overlaps the in-flight
    device step instead of sitting on the critical path between rounds.

    On a finite iterator the final tuple reuses the last round's own first
    microbatch (there is no round ``r+1`` to stage); the pipelined step's
    seam output for that round is simply never consumed.

    NOTE: the upstream generator runs ahead of training by up to
    ``run_ahead()`` = depth + 2 rounds (``depth`` staged items in the
    queue, plus one blocked in the producer's ``put``, plus one held as the
    consumer's pair lookahead), so generator side effects (OrderedDataset's
    per-segment OrderGen decision) fire that much early; pass
    ``OrderedDataset(boundary_delay=RoundPrefetcher.run_ahead(depth))`` to
    re-align the decision with the recorded Judge scores.
    """

    DEFAULT_DEPTH = 2

    @classmethod
    def run_ahead(cls, depth: Optional[int] = None) -> int:
        """Worst-case rounds the upstream generator leads training by."""
        return (cls.DEFAULT_DEPTH if depth is None else depth) + 2

    def __init__(self, batches: Iterator[Dict], n_workers: int, tau: int,
                 depth: int = DEFAULT_DEPTH, to_device: bool = True):
        self.n_workers = n_workers
        self.tau = tau
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._stop = False
        self._cur: Optional[Tuple] = None
        self._done = False
        self._to_device = to_device
        self._batches = iter(batches)
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="round-prefetch")
        self._thread.start()

    def _stage(self, batch: Dict) -> Tuple[Dict, Dict]:
        first = first_microbatch(batch, self.n_workers, self.tau)
        if self._to_device:
            import jax
            batch = jax.device_put(batch)
            first = jax.device_put(first)
        return batch, first

    def _put(self, item) -> bool:
        while not self._stop:
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                pass
        return False

    def _worker(self):
        try:
            for batch in self._batches:
                if self._stop or not self._put(self._stage(batch)):
                    return
            self._put(_END)
        except BaseException as e:                 # propagate to the consumer
            self._put(_PrefetchError(e))

    def _get(self):
        item = self._q.get()
        if isinstance(item, _PrefetchError):
            self._done = True
            raise item.exc
        return item

    def __iter__(self):
        return self

    def __next__(self) -> Tuple[Dict, Dict]:
        if self._done:
            raise StopIteration
        if self._cur is None:
            head = self._get()
            if head is _END:
                self._done = True
                raise StopIteration
            self._cur = head
        nxt = self._get()
        batch, first = self._cur
        if nxt is _END:
            self._done = True
            return batch, first                    # reuse own first microbatch
        self._cur = nxt
        return batch, nxt[1]

    def close(self):
        """Stop the staging thread and drain the buffer (safe to call
        multiple times; the Trainer calls it when a pipelined run ends)."""
        self._stop = True
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=1.0)

    def resize(self, n_workers: int, batches: Optional[Iterator[Dict]] = None):
        """Membership resize: tear down the staging thread (everything it
        buffered was laid out for the old worker count — worker-major
        reshapes are not reinterpretable across ``p``), then restart staging
        against ``batches`` (a fresh upstream generator built for the new
        membership, e.g. ``OrderedDataset.batches(start_round=r)`` after
        ``OrderedDataset.resize``; defaults to reusing the current upstream,
        which is only correct if that iterator itself now yields new-``p``
        rounds). The consumer's pair lookahead resets too, so the next
        ``__next__`` yields the first new-membership round."""
        if int(n_workers) < 1:
            raise ValueError(f"resize needs n_workers >= 1, got {n_workers}")
        self.close()
        self.n_workers = int(n_workers)
        if batches is not None:
            self._batches = iter(batches)
        self._q = queue.Queue(maxsize=self._q.maxsize)
        self._stop = False
        self._cur = None
        self._done = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="round-prefetch")
        self._thread.start()
