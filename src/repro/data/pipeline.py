"""Order-managed data pipeline (paper Alg. 1 lines 4-7 + OrderGen).

Each worker traverses the full dataset in its own permutation order; the
epoch is split into ``n_segments`` order segments whose seeds survive or get
reshuffled based on Judge scores (core/order.OrderState). Batches are
assembled worker-major with leading dim ``tau * p * b_local`` to match the
train-step reshape contract.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.order import OrderState


class OrderedDataset:
    def __init__(self, data: Dict[str, np.ndarray], n_workers: int, tau: int,
                 b_local: int, n_segments: int = 1,
                 order_state: Optional[OrderState] = None, seed: int = 0):
        self.data = data
        self.n = len(next(iter(data.values())))
        self.p = n_workers
        self.tau = tau
        self.b_local = b_local
        self.n_segments = n_segments
        self.order = order_state or OrderState(n_workers, n_segments, seed)
        self.per_round = tau * b_local           # samples per worker per round
        self.seg_len = self.n // n_segments
        self.rounds_per_segment = max(1, self.seg_len // self.per_round)
        self.rounds_per_epoch = self.rounds_per_segment * n_segments

    def segment_of_round(self, r: int) -> int:
        return (r // self.rounds_per_segment) % self.n_segments

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite iterator over rounds; reshuffles per OrderGen at segment
        boundaries."""
        r = 0
        while True:
            seg = self.segment_of_round(r)
            within = r % self.rounds_per_segment
            if within == 0 and r > 0 and seg == 0:
                for s in range(self.n_segments):
                    self.order.end_segment(s)
            # per-worker sample indices for this round
            idx = np.empty((self.p, self.per_round), np.int64)
            for w in range(self.p):
                perm = self.order.order_for(seg, w, self.seg_len)
                start = (within * self.per_round) % max(
                    1, self.seg_len - self.per_round + 1)
                sel = perm[start:start + self.per_round]
                if len(sel) < self.per_round:   # wrap
                    sel = np.concatenate([sel, perm[: self.per_round - len(sel)]])
                idx[w] = seg * self.seg_len + sel
            flat = idx.reshape(-1)               # worker-major: (p * tau * b_local)
            batch = {k: v[flat] for k, v in self.data.items()}
            yield batch
            r += 1
