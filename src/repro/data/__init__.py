from repro.data.pipeline import (OrderedDataset, RoundPrefetcher,
                                 first_microbatch)
from repro.data.synthetic import (
    lm_batch,
    make_classification,
    make_images,
    make_tokens,
)

__all__ = ["OrderedDataset", "RoundPrefetcher", "first_microbatch",
           "lm_batch", "make_classification", "make_images", "make_tokens"]
