from repro.data.pipeline import OrderedDataset
from repro.data.synthetic import (
    lm_batch,
    make_classification,
    make_images,
    make_tokens,
)

__all__ = ["OrderedDataset", "lm_batch", "make_classification",
           "make_images", "make_tokens"]
