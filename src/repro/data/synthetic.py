"""Offline synthetic datasets (no network access in this container).

* ``make_classification`` — teacher-MLP labelled gaussian features; stands in
  for MNIST/Fashion-MNIST in the paper-repro benchmarks.
* ``make_images``        — 28x28 class-templated images + noise for the CNN.
* ``make_tokens``        — token streams with a learnable bigram structure
  (noisy random permutation map) for LM training examples/tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def make_classification(seed: int, n: int, d: int = 64, n_classes: int = 10,
                        noise: float = 0.1) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, d)).astype(np.float32)
    y = rng.integers(0, n_classes, size=n)
    x = centers[y] + noise * rng.normal(size=(n, d)).astype(np.float32)
    # nonlinear warp so the problem isn't linearly trivial
    w = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)
    x = np.tanh(x @ w) + noise * rng.normal(size=(n, d)).astype(np.float32)
    return x.astype(np.float32), y.astype(np.int32)


def make_images(seed: int, n: int, n_classes: int = 10, size: int = 28,
                noise: float = 0.3) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(n_classes, size, size, 1)).astype(np.float32)
    # low-pass the templates so classes have spatial structure
    for _ in range(2):
        templates = (templates
                     + np.roll(templates, 1, 1) + np.roll(templates, -1, 1)
                     + np.roll(templates, 1, 2) + np.roll(templates, -1, 2)) / 5
    y = rng.integers(0, n_classes, size=n)
    x = templates[y] + noise * rng.normal(size=(n, size, size, 1))
    return x.astype(np.float32), y.astype(np.int32)


def make_tokens(seed: int, n_seq: int, seq_len: int, vocab: int,
                p_follow: float = 0.8) -> np.ndarray:
    """Noisy-permutation bigram language: t+1 = perm[t] w.p. p_follow."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(vocab)
    toks = np.empty((n_seq, seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=n_seq)
    for t in range(seq_len):
        follow = rng.random(n_seq) < p_follow
        rand = rng.integers(0, vocab, size=n_seq)
        toks[:, t + 1] = np.where(follow, perm[toks[:, t]], rand)
    return toks


def lm_batch(seed: int, batch: int, seq_len: int, vocab: int,
             n_codebooks: int = 0, media_tokens: int = 0, d_model: int = 0
             ) -> Dict[str, np.ndarray]:
    """One LM training batch (tokens/labels [+ media embeddings stub])."""
    rng = np.random.default_rng(seed)
    if n_codebooks > 0:
        toks = rng.integers(0, vocab, size=(batch, seq_len + 1, n_codebooks),
                            dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    else:
        toks = make_tokens(seed, batch, seq_len, vocab)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if media_tokens > 0:
        out["media"] = rng.normal(
            size=(batch, media_tokens, d_model)).astype(np.float32)
    return out
