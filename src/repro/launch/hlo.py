"""HLO text analysis: collective-traffic accounting for the roofline.

``collective_bytes`` parses a (stable)HLO/optimized-HLO dump and sums operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (including async ``-start`` forms; ``-done`` halves are
skipped so nothing is double counted).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"\b(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([^\s=]+)\s*=\s*(.*)$")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_type_map(text: str) -> Dict[str, str]:
    out = {}
    for line in text.splitlines():
        m = _NAME_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # result type = everything up to the opcode token; taking the prefix
        # before the first '(' that follows an opcode word is fragile, so we
        # just keep the full rest — _shape_bytes only counts dtype[dims]
        # patterns, and the *first* ones on the line are the result type(s).
        # For operand-size lookups only the first type matters rarely; we
        # store the prefix up to the last '=' free segment.
        out[name] = rest
    return out


def _paren_span(line: str, start: int) -> Tuple[int, int]:
    depth = 0
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return start, i
    return start, len(line) - 1


_GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\](?:T\(([0-9,]+)\))?")


def _group_stride(line: str) -> int:
    """First-two-element stride of the first replica group (-1 unknown).

    stride 1  => groups are contiguous device runs  => "model" (TP) axis;
    stride >1 => strided groups                     => worker ("data"/"pod")
    axis, under the production mesh layout (model minor).
    """
    m = _GROUPS_LITERAL_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        return ids[1] - ids[0] if len(ids) > 1 else 0
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s, n, perm = m.groups()
        if perm is None or perm == "0,1":
            return 1              # groups are consecutive rows of iota
        return int(m.group(1)) if perm == "1,0" else -1
    return -1


def classify_axis(stride: int) -> str:
    if stride == 1:
        return "model"
    if stride > 1:
        return "worker"
    return "unknown"


def collective_bytes(text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind. Returns {kind: bytes, total:}."""
    # map of instruction name -> result-type bytes (first shapes on the line)
    result_bytes: Dict[str, int] = {}
    for line in text.splitlines():
        m = _NAME_RE.match(line)
        if m:
            name, rest = m.groups()
            # only count shapes before the opcode's '(' — cut at first '('
            cut = rest.find("(")
            head = rest if cut < 0 else rest[:cut]
            if not _SHAPE_RE.search(head):
                head = rest  # tuple results start with '(' — keep everything
                cut2 = rest.find(")")
                head = rest[:cut2 + 1] if cut2 > 0 else rest
            result_bytes[name] = _shape_bytes(head)

    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    by_axis = {"model": 0, "worker": 0, "unknown": 0}
    for line in text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(1)
        op_start = line.find("(", m.start())
        a, b = _paren_span(line, op_start)
        inner = line[a + 1:b]
        nbytes = _shape_bytes(inner)              # inline operand shapes
        if nbytes == 0:                           # resolve operand names
            for name in _OPERAND_NAME_RE.findall(inner):
                nbytes += result_bytes.get(name, 0)
        out[kind] += nbytes
        counts[kind] += 1
        by_axis[classify_axis(_group_stride(line))] += nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    out["by_axis"] = by_axis
    return out


def normalize_cost_analysis(cost) -> Dict:
    """``compiled.cost_analysis()`` returns a dict on current jaxlib and a
    one-element list of dicts on older releases; normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}
