"""Render the dry-run JSONL artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report results/dryrun_single.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(paths: List[str]) -> Dict:
    recs = {}
    for path in paths:
        for line in open(path):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | status | compile | temp/chip | args/chip | collectives (AR/AG/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if not r["ok"]:
            rows.append(f"| {arch} | {shape} | {mesh} | FAIL: {r['error'][:40]} | | | | |")
            continue
        cb = r["collective_bytes"]
        cc = r["collective_counts"]
        coll = "/".join(str(cc[k]) for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        mem = r.get("memory") or {}
        rows.append(
            f"| {arch} | {shape} | {mesh} | OK | {r['t_compile_s']:.0f}s "
            f"| {fmt_bytes(mem.get('temp_bytes'))} "
            f"| {fmt_bytes(mem.get('argument_bytes'))} "
            f"| {coll} ({fmt_bytes(cb['total'])}) |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | useful FLOPs | worker-coll | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if not r["ok"] or mesh != "16x16":
            continue
        rf = r["roofline"]
        ax = r.get("collective_by_axis", {})
        lever = _lever(r)
        rows.append(
            f"| {arch} | {shape} | {rf['compute_s']*1e3:.2f} "
            f"| {rf['memory_s']*1e3:.2f} | {rf['collective_s']*1e3:.3f} "
            f"| **{rf['dominant'].replace('_s','')}** "
            f"| {r['useful_flops_frac']:.2f} "
            f"| {fmt_bytes(ax.get('worker', 0) + ax.get('unknown', 0))} "
            f"| {lever} |")
    return "\n".join(rows)


def _lever(r) -> str:
    rf = r["roofline"]
    dom = rf["dominant"]
    if dom == "collective_s":
        return "raise tau (worker-coll amortizes 1/tau) or quantize payload"
    if dom == "compute_s":
        if r["useful_flops_frac"] < 0.5:
            return "cut replicated/wasted compute (head sharding, windowed-block skip)"
        return "near roofline; overlap collectives"
    if r["useful_flops_frac"] < 0.3:
        return "bytes & flops both inflated by replication — reshard"
    return "fuse elementwise chains (XLA:TPU/flash kernel), cut f32 temps"


def main():
    paths = sys.argv[1:] or ["results/dryrun_single.jsonl"]
    recs = load(paths)
    ok = sum(r["ok"] for r in recs.values())
    print(f"## Dry-run matrix ({ok}/{len(recs)} OK)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, per compiled step)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
