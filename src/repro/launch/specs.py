"""ShapeDtypeStruct stand-ins for every (architecture x input-shape) workload.

``input_specs`` returns everything the dry-run needs to lower one compiled
step — abstract state/batch trees, matching logical-axes trees, the step
callable, and the rules table — without allocating a single device byte.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (InputShape, ModelConfig, TrainConfig,
                                WASGDConfig)
from repro.models import abstract_params, cache_axes, decode_step, init_cache, prefill
from repro.parallel.sharding import SERVE_LONG_RULES, SERVE_RULES, TRAIN_RULES
from repro.train.lm import abstract_lm_state, lm_batch_specs, make_lm_loss
from repro.train.step import build_train_step


def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long-context sub-quadratic override (DESIGN.md §4.2):
    pure full-attention architectures run ``long_500k`` only under an
    explicit sliding-window variant."""
    if (shape.window_override and cfg.ssm is None and cfg.attn_window is None
            and shape.kind == "decode"):
        return dataclasses.replace(cfg, attn_window=shape.window_override,
                                   global_attn_every=0)
    return cfg


class Workload(NamedTuple):
    fn: Any                     # callable to jit
    arg_shapes: tuple           # ShapeDtypeStruct pytrees (positional)
    arg_axes: tuple             # logical-axes pytrees (same structure)
    rules: Dict                 # logical-axis -> mesh-axis table
    cfg: ModelConfig            # effective model config
    meta: Dict


def _abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len, jnp.bfloat16))
    return shapes, cache_axes(cfg)


def input_specs(cfg: ModelConfig, shape: InputShape, n_workers: int,
                tcfg: Optional[TrainConfig] = None,
                for_dryrun: bool = True,
                train_rules: Optional[Dict] = None) -> Workload:
    cfg = effective_config(cfg, shape)
    if for_dryrun:
        # unroll the flash-attention KV scan so HLO cost analysis (which
        # counts while bodies once) sees every block's FLOPs
        cfg = dataclasses.replace(cfg, unroll_attn_scan=True)
    tcfg = tcfg or TrainConfig()

    if shape.kind == "train":
        state_shapes, state_axes, optimizer = abstract_lm_state(
            cfg, tcfg, n_workers)
        batch_shapes, batch_axes = lm_batch_specs(
            cfg, shape.global_batch, shape.seq_len)
        step = build_train_step(make_lm_loss(cfg), optimizer,
                                state_axes.params, tcfg.wasgd, n_workers)
        rules = TRAIN_RULES if train_rules is None else train_rules
        return Workload(step, (state_shapes, batch_shapes),
                        (state_axes, batch_axes), rules, cfg,
                        {"kind": "train", "tau": tcfg.wasgd.tau,
                         "workers": n_workers})

    params_shapes, params_axes = abstract_params(cfg)
    rules = SERVE_LONG_RULES if shape.global_batch == 1 else SERVE_RULES

    if shape.kind == "prefill":
        cache_shapes, cax = _abstract_cache(cfg, shape.global_batch,
                                            shape.seq_len)
        if cfg.n_codebooks > 0:
            tok = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.n_codebooks), jnp.int32)
            tok_axes = ("batch", "seq", None)
        else:
            tok = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32)
            tok_axes = ("batch", "seq")
        args = [params_shapes, tok, cache_shapes]
        axes = [params_axes, tok_axes, cax]
        if cfg.n_media_tokens > 0:
            args.append(jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_media_tokens, cfg.d_model),
                jnp.bfloat16))
            axes.append(("batch", "media", None))
        fn = functools.partial(prefill, cfg)
        return Workload(fn, tuple(args), tuple(axes), rules, cfg,
                        {"kind": "prefill"})

    # decode: one new token against a seq_len-deep cache
    cache_shapes, cax = _abstract_cache(cfg, shape.global_batch,
                                        shape.seq_len)
    if cfg.n_codebooks > 0:
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.n_codebooks),
                                   jnp.int32)
        tok_axes = ("batch", None, None)
    else:
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_axes = ("batch", None)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    args = [params_shapes, tok, cache_shapes, index]
    axes = [params_axes, tok_axes, cax, ()]
    if cfg.n_media_tokens > 0:
        args.append(jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_media_tokens, cfg.d_model),
            jnp.bfloat16))
        axes.append(("batch", "media", None))
    fn = functools.partial(decode_step, cfg)
    return Workload(fn, tuple(args), tuple(axes), rules, cfg,
                    {"kind": "decode"})
