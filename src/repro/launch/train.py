"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
        --workers 4 --rounds 20

On a real TPU pod this builds the production mesh and shards the worker-
stacked state per parallel/sharding.py; on CPU (this container) it runs the
reduced config on the host device with the same code path — the mesh only
changes the `in_shardings`, never the program.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import (TrainConfig, WASGDConfig, get_config,
                           get_smoke_config)
from repro.data import OrderedDataset, RoundPrefetcher, make_tokens
from repro.models import init_params
from repro.train import Trainer
from repro.train.lm import make_lm_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.9)
    ap.add_argument("--a-tilde", type=float, default=1.0)
    ap.add_argument("--strategy", default="boltzmann",
                    choices=["boltzmann", "inverse", "equal", "best"])
    ap.add_argument("--policy", default="",
                    help="worker-assessment policy spec (core/weights.py), "
                         "e.g. 'boltzmann(a=8)|anneal(cosine)', "
                         "'ema(0.9)|time_aware', 'trimmed(1)|boltzmann'; "
                         "empty resolves --strategy/--a-tilde as aliases")
    ap.add_argument("--rule", default="wasgd",
                    choices=["wasgd", "spsgd", "easgd", "omwu", "mmwu", "seq"])
    ap.add_argument("--lr", type=float, default=0.03)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--b-local", type=int, default=2)
    ap.add_argument("--ckpt", default=None,
                    help="write a final params-only flat checkpoint here")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for periodic full-state sharded "
                         "checkpoints (checkpoint-dir/round_N); saved "
                         "asynchronously every --checkpoint-every rounds")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="rounds between sharded checkpoints "
                         "(0 = disabled; requires --checkpoint-dir)")
    ap.add_argument("--resume", default=None,
                    help="resume from a sharded checkpoint (a "
                         "checkpoint-dir/round_N path); a checkpoint saved "
                         "under a different --workers count is resized "
                         "into this run's membership on restore")
    ap.add_argument("--chaos", type=int, default=0, metavar="SEED",
                    help="run under a seeded elastic membership chaos "
                         "schedule (core/membership.make_chaos_schedule; "
                         "0 = fixed membership)")
    ap.add_argument("--transfer-guard", default=None,
                    choices=["log", "disallow", "log_explicit",
                             "disallow_explicit"],
                    help="debug: run each jitted round under "
                         "jax.transfer_guard at this level — catches "
                         "implicit device<->host transfers inside the "
                         "step (batches are staged explicitly first)")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="record structured telemetry to this JSONL file "
                         "(repro.obs.JsonlSink): per-round RoundTrace "
                         "phase breakdowns + WorkerAssessment, plus "
                         "membership/checkpoint events; summarize with "
                         "tools/obs_report.py")
    ap.add_argument("--pipeline", default=None,
                    choices=["parity", "speculative"],
                    help="software-pipeline the round (train/step.py): "
                         "prefetch round r+1 and feed its first microbatch "
                         "into the aggregation schedule's phase-gap seam; "
                         "'parity' is bitwise-identical to unpipelined, "
                         "'speculative' also runs the next Judge forward on "
                         "pre-aggregate params (wasgd/wasgd+ rules only)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_count():,} workers={args.workers}")

    tcfg = TrainConfig(
        learning_rate=args.lr, optimizer="sgd",
        wasgd=WASGDConfig(tau=args.tau, beta=args.beta, a_tilde=args.a_tilde,
                          strategy=args.strategy, policy=args.policy))

    toks = make_tokens(0, 2048, args.seq, cfg.vocab_size)
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.n_codebooks:
        rng = np.random.default_rng(0)
        t = rng.integers(0, cfg.vocab_size,
                         (2048, args.seq + 1, cfg.n_codebooks), dtype=np.int32)
        data = {"tokens": t[:, :-1], "labels": t[:, 1:]}
    if cfg.n_media_tokens:
        data["media"] = np.random.default_rng(1).normal(
            size=(2048, cfg.n_media_tokens, cfg.d_model)).astype(np.float32)

    ds = OrderedDataset(data, args.workers, args.tau, args.b_local,
                        n_segments=2,
                        boundary_delay=RoundPrefetcher.run_ahead()
                        if args.pipeline else 0)
    params, axes = init_params(cfg, jax.random.key(0))
    trainer = Trainer(make_lm_loss(cfg), params, axes, tcfg, args.workers,
                      rule=args.rule, pipeline=args.pipeline)
    membership = None
    if args.chaos:
        from repro.core.membership import make_chaos_schedule
        membership = make_chaos_schedule(args.workers, args.rounds,
                                         seed=args.chaos)
        print(f"chaos membership: {membership}")
    if args.checkpoint_every and not args.checkpoint_dir:
        raise SystemExit("--checkpoint-every requires --checkpoint-dir")
    sink = None
    if args.telemetry:
        from repro.obs import JsonlSink
        sink = JsonlSink(args.telemetry)
    try:
        summary = trainer.run(ds, args.rounds,
                              log_every=max(1, args.rounds // 5),
                              checkpoint_every=args.checkpoint_every,
                              checkpoint_path=args.checkpoint_dir,
                              membership_schedule=membership,
                              resume_from=args.resume,
                              transfer_guard=args.transfer_guard,
                              telemetry=sink)
    finally:
        if sink is not None:
            sink.close()
            print(f"telemetry: {sink.n_emitted} events -> {args.telemetry}")
    print(f"done: {summary}")
    if args.ckpt:
        save(args.ckpt, trainer.state.params,
             meta={"arch": cfg.name, "rounds": args.rounds})
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
