import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers and compiles under the production sharding, and extract
the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

The two lines above this docstring MUST stay the first statements in the
module: jax locks the device count at first backend init (see the assignment
brief), and only the dry-run is allowed to see 512 placeholder devices.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, TrainConfig, get_config
from repro.launch.hlo import collective_bytes, normalize_cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.parallel.sharding import num_workers, tree_shardings

# -- TPU v5e hardware model (per chip) --------------------------------------------
PEAK_FLOPS = 197e12           # bf16
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link


def model_flops(cfg, shape, tau: int = 4) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens/step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: 1 token/seq


def run_one(arch: str, shape_name: str, multi_pod: bool,
            tcfg: Optional[TrainConfig] = None, verbose: bool = True,
            unroll: bool = True, cfg_overrides: Optional[Dict] = None,
            variant: str = "baseline", dp_workers: bool = False) -> Dict:
    import dataclasses
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    workers = num_workers(mesh)
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    tcfg = tcfg or TrainConfig()

    train_rules = None
    if dp_workers:
        # §Perf: small-model layout — every chip is a WASGD worker (worker
        # axis spans the WHOLE mesh incl. "model"); no tensor parallelism.
        from repro.parallel.sharding import TRAIN_RULES as _TR
        train_rules = {**_TR, "worker": ("pod", "data", "model"),
                       "heads": None, "kv_heads": None, "ffn": None,
                       "vocab": None, "expert_ffn": None, "experts": None}
        workers = n_chips
    wl = input_specs(cfg, shape, workers, tcfg, for_dryrun=unroll,
                     train_rules=train_rules)
    in_shardings = tuple(
        tree_shardings(mesh, s, a, wl.rules)
        for s, a in zip(wl.arg_shapes, wl.arg_axes))

    t0 = time.time()
    with mesh:
        jitted = jax.jit(wl.fn, in_shardings=in_shardings)
        lowered = jitted.lower(*wl.arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())

    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    mf = model_flops(wl.cfg, shape, tcfg.wasgd.tau)
    coll_worker = coll["by_axis"]["worker"] + coll["by_axis"]["unknown"]
    coll_model = coll["by_axis"]["model"]

    # cost_analysis on the partitioned module reports PER-DEVICE numbers;
    # verify against the analytic model count and normalize to per-chip.
    per_chip_flops = flops
    if flops > mf / 4:                       # looks like whole-program FLOPs
        per_chip_flops = flops / n_chips

    compute_s = per_chip_flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll["total"] / ICI_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    # WASGD amortization: the worker-axis aggregation runs once per tau local
    # steps; TP (model-axis) collectives run every step.
    amortized = {f"collective_s_tau{t}": (coll_worker / t + coll_model) / ICI_BW
                 for t in (1, 10, 100, 1000)}

    rec = {
        "arch": arch,
        "variant": variant,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "workers": workers,
        "chips": n_chips,
        "ok": True,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "hlo_flops_per_chip": per_chip_flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "collective_bytes": {k: v for k, v in coll.items()
                             if k not in ("counts", "by_axis")},
        "collective_counts": coll["counts"],
        "collective_by_axis": coll["by_axis"],
        "collective_amortized": amortized,
        "model_flops": mf,
        "useful_flops_frac": mf / n_chips / max(per_chip_flops, 1.0),
        "roofline": {**terms, "dominant": dominant},
        "memory": {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        } if mem is not None else None,
        "window_override": wl.cfg.attn_window != cfg.attn_window,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {rec['mesh']}] OK "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"compute={compute_s*1e3:.2f}ms mem={memory_s*1e3:.2f}ms "
              f"coll={collective_s*1e3:.2f}ms dominant={dominant} "
              f"useful={rec['useful_flops_frac']:.2f}")
        if mem is not None:
            print(f"   memory_analysis: temp={rec['memory']['temp_bytes']} "
                  f"args={rec['memory']['argument_bytes']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--variant", default="baseline",
                    help="label recorded with each result row")
    ap.add_argument("--sharded-ce", action="store_true")
    ap.add_argument("--windowed-qblock", action="store_true")
    ap.add_argument("--comm-dtype", default="float32")
    ap.add_argument("--backend", default="",
                    help="aggregation spec '<schedule>:<codec>' (e.g. "
                         "'rs_ag:int8'), a legacy alias, or 'auto'; empty "
                         "composes it from the legacy boolean flags "
                         "(core/backends.py)")
    ap.add_argument("--policy", default="",
                    help="worker-assessment policy spec (core/weights.py), "
                         "e.g. 'ema(0.9)|time_aware'; stateful policy state "
                         "rides comm_state into the compiled round")
    ap.add_argument("--expert-sharding", default=None,
                    choices=["ep_data", "worker"])
    ap.add_argument("--dp-workers", action="store_true",
                    help="worker axis spans the whole mesh (no TP)")
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--async-mode", default="host_sim",
                    choices=["host_sim", "on_device"],
                    help="on_device: compile the Alg. 4 masked round (the "
                         "straggler mask is a (w,) bool input riding in "
                         "comm_state) instead of the synchronous Alg. 1 "
                         "round")
    ap.add_argument("--no-unroll", action="store_true",
                    help="skip flash-scan unrolling: faster compiles, HLO "
                         "FLOPs undercount scan bodies (compile-proof runs)")
    ap.add_argument("--tau", type=int, default=1,
                    help="local steps per compiled round; 1 keeps HLO cost "
                         "analysis exact (while bodies are counted once)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = list(SHAPES_BY_NAME) if (args.all or args.shape in (None, "all")) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    from repro.configs.base import WASGDConfig
    tcfg = TrainConfig(wasgd=WASGDConfig(
        tau=args.tau, comm_dtype=args.comm_dtype, backend=args.backend,
        policy=args.policy,
        hierarchical=args.hierarchical, n_pods=2 if args.hierarchical else 1,
        async_mode=args.async_mode))
    cfg_overrides = {}
    if args.sharded_ce:
        cfg_overrides["sharded_ce"] = True
    if args.windowed_qblock:
        cfg_overrides["windowed_qblock"] = True
    if args.expert_sharding:
        cfg_overrides["expert_sharding"] = args.expert_sharding

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_one(arch, shape, mp, tcfg,
                                  unroll=not args.no_unroll,
                                  cfg_overrides=cfg_overrides,
                                  variant=args.variant,
                                  dp_workers=args.dp_workers)
                except Exception as e:           # noqa: BLE001 — report, keep going
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": False, "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[{arch} x {shape} x {rec['mesh']}] FAIL: "
                          f"{rec['error']}")
                results.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")

    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} combinations lowered+compiled")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
