"""Serving engines.

``ContinuousEngine`` is the production path: a continuous-batching engine
over the paged block cache (serve/paged_cache.py) whose decode body is ONE
jitted ``lax.while_loop`` program — sampling, paged cache writes and
per-request done-flags all happen inside the loop, with no host round-trip
per token. Requests are admitted into and evicted from the running batch at
token boundaries (serve/scheduler.py); the loop exits early when a request
finishes while others are queued, so freed slots/blocks are recycled
immediately.

Determinism: the key for the token at absolute position ``p`` of a request
is ``fold_in(fold_in(engine_key, request_seed), p)`` — a pure function of
the request, never of the batch it happened to ride in. Together with the
row-independence of every per-token op (norms, attention, MLP, SSM step,
argmax), a request decodes token-for-token identically whether it runs solo
or is inserted/evicted mid-flight — the greedy-parity guarantee
(tests/test_serve_continuous.py). MoE layers are the exception: capacity
dispatch ranks tokens across the whole batch, so only non-MoE archs get
exact parity.

``ServeEngine`` is the legacy monolithic-cache engine, kept for the archs
the paged path does not cover (cross-attention/media, audio codebooks).

For trained WASGD checkpoints the served copy is worker 0's slice after a
final beta=1 aggregation (all workers coincide — Sec. 4.1's tau-step fixed
point): ``train.evaluate.consensus_params``. ``HotSwapBridge`` wires that
into ``Trainer.run(serve_hook=...)``: each call swaps the fresh consensus
into a live engine without touching in-flight decode state (params are an
argument of the jitted loop, not a constant), and records per-swap
staleness metrics.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, decode_step_paged, init_cache, prefill
from repro.obs import NULL, HotSwap, ServeSample
from repro.serve.paged_cache import PagedCache
from repro.serve.scheduler import Request, Scheduler


class ServeEngine:
    """Legacy engine: monolithic ``(b, max_len, ...)`` cache, Python
    token loop. Covers every arch (incl. media/audio); use
    ``ContinuousEngine`` for throughput serving of text archs."""

    def __init__(self, cfg: ModelConfig, params: Dict, max_len: int = 2048,
                 cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(functools.partial(prefill, cfg))
        self._decode = jax.jit(functools.partial(decode_step, cfg))

    def generate(self, prompt: np.ndarray, n_new: int,
                 media: Optional[np.ndarray] = None,
                 temperature: float = 0.0, seed: int = 0,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """prompt: (b, s) int32 (or (b, s, n_q) audio). Greedy if T == 0.

        With ``eos_id`` set, decoding stops once every row has emitted the
        stop token, and a row's positions after its first stop token are
        padded with it. Checking the stop condition forces a device-to-host
        read of every token — the structural cost of a Python decode loop
        that the ``ContinuousEngine`` while_loop folds into its compiled
        done-flags."""
        b, s = prompt.shape[:2]
        if s + n_new > self.max_len:
            raise ValueError(
                f"prompt ({s}) + n_new ({n_new}) = {s + n_new} tokens "
                f"exceeds the cache budget max_len={self.max_len}")
        cache = init_cache(self.cfg, b, self.max_len, self.cache_dtype)
        logits, cache = self._prefill(self.params, jnp.asarray(prompt), cache,
                                      media)
        key = jax.random.key(seed)
        key, sub = jax.random.split(key)
        out = [self._sample(logits, temperature, sub)]
        done = (np.asarray(out[-1])[:, 0] == eos_id
                if eos_id is not None else None)
        index = s
        for t in range(n_new - 1):
            if done is not None and done.all():
                break
            key, sub = jax.random.split(key)
            tok = out[-1]
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(index), media)
            out.append(self._sample(logits, temperature, sub))
            if done is not None:
                done |= np.asarray(out[-1])[:, 0] == eos_id
            index += 1
        toks = np.concatenate([np.asarray(t) for t in out], axis=1)
        if eos_id is not None:
            hit = toks == eos_id
            past_eos = np.cumsum(hit, axis=1) - hit   # strictly after first
            toks = np.where(past_eos > 0, eos_id, toks)
        return toks

    def _sample(self, logits, temperature, key):
        logits = logits[:, -1:] if logits.shape[1] > 1 else logits
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)


def _sample_rows(logits: jax.Array, temps: jax.Array,
                 keys: jax.Array) -> jax.Array:
    """Per-row sampling: argmax where temp <= 0, categorical otherwise.
    logits (n, 1, V) -> (n,) int32."""
    lg = logits[:, -1].astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temps > 0, temps, 1.0)
    cat = jax.vmap(jax.random.categorical)(keys, lg / safe_t[:, None])
    return jnp.where(temps > 0, cat.astype(jnp.int32), greedy)


class ContinuousEngine:
    """Continuous-batching engine on the paged KV cache.

    ``n_slots`` concurrent requests share per-layer block pools; admission
    reserves each request's whole token budget from the free list (decode
    never allocates) and scatters a batch=1 prefill into its blocks. The
    decode chunk is one jitted ``lax.while_loop``; finished rows keep
    riding the batch (KV writes redirected to the trash block, SSM state
    frozen) until the host recycles their slot at the next chunk boundary.

    ``eos_id``, when set, is a stop token: a row that emits it finishes
    regardless of remaining budget. The check compiles into the loop's
    done-flags — the host never reads a token to test it.
    """

    def __init__(self, cfg: ModelConfig, params: Dict, n_slots: int = 8,
                 max_len: int = 2048, block_size: int = 16,
                 cache_dtype=jnp.bfloat16, chunk: int = 32,
                 full_blocks: Optional[int] = None, seed: int = 0,
                 eos_id: Optional[int] = None, telemetry=None):
        """``telemetry`` (a ``repro.obs`` sink; default ``NullSink`` = off)
        receives one ``ServeSample`` per ``step()``: fenced chunk wall
        time, inter-token latency, TTFT for requests admitted that step,
        block-pool occupancy, queue depth, admission/eviction counts.
        With the default sink the engine adds no fences or host reads."""
        for i in range(cfg.n_layers):
            if cfg.layer_is_cross_attn(i):
                raise NotImplementedError(
                    "ContinuousEngine does not serve cross-attention "
                    "(media) archs — use the legacy ServeEngine")
        if getattr(cfg, "n_codebooks", 0):
            raise NotImplementedError(
                "ContinuousEngine does not serve multi-codebook (audio) "
                "archs — use the legacy ServeEngine")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.cache_dtype = cache_dtype
        self.chunk = chunk
        self.cache = PagedCache(cfg, n_slots, max_len, block_size,
                                dtype=cache_dtype, full_blocks=full_blocks)
        self.scheduler = Scheduler(n_slots)
        self.tokens_generated = 0
        self.n_swaps = 0
        self.eos_id = eos_id
        self.telemetry = telemetry if telemetry is not None else NULL
        self._key = jax.random.key(seed)

        n = n_slots
        self._st: Dict[str, Any] = {
            "last_tok": jnp.zeros((n, 1), jnp.int32),
            "index": jnp.zeros((n,), jnp.int32),
            "remaining": jnp.zeros((n,), jnp.int32),
            "active": jnp.zeros((n,), bool),
            # chunk steps + the admission-time first token for a fresh row
            "out_buf": jnp.zeros((n, chunk + 1), jnp.int32),
            "out_pos": jnp.zeros((n,), jnp.int32),
            "keys": jax.random.split(self._key, n),
            "temps": jnp.zeros((n,), jnp.float32),
        }

        self._prefill = jax.jit(functools.partial(prefill, cfg))
        # prefill scratch caches keyed (batch, prompt bucket): the scratch
        # only has to hold the prompt (write_prefill reads nothing past
        # it), so admission never copies max_len-wide buffers
        self._mono_scratch: Dict[tuple, Dict] = {}

        def chunk_fn(params, pools, tables, st, stop_early, *,
                     max_steps: int):
            entry_active = st["active"]
            keys, temps = st["keys"], st["temps"]
            rows = jnp.arange(entry_active.shape[0])
            out_cap = st["out_buf"].shape[1]
            # loop-invariant: all-greedy batches skip per-step RNG entirely
            any_sampled = jnp.any(temps > 0)

            def cond(c):
                _, _, _, _, act, _, _, t = c
                newly_done = jnp.any(entry_active & ~act)
                return (jnp.any(act) & (t < max_steps)
                        & ~(stop_early & newly_done))

            def body(c):
                pools, lt, idx, rem, act, ob, op, t = c
                logits, pools = decode_step_paged(
                    cfg, params, lt, pools, tables, idx, act,
                    max_len=max_len, block_size=block_size)

                def sampled(lg, i):
                    tok_keys = jax.vmap(jax.random.fold_in)(keys, i + 1)
                    return _sample_rows(lg, temps, tok_keys)

                def greedy(lg, i):
                    return jnp.argmax(lg[:, -1].astype(jnp.float32),
                                      axis=-1).astype(jnp.int32)

                tok = jax.lax.cond(any_sampled, sampled, greedy, logits, idx)
                lt = jnp.where(act[:, None], tok[:, None], lt)
                opc = jnp.minimum(op, out_cap - 1)
                ob = ob.at[rows, opc].set(
                    jnp.where(act, tok, ob[rows, opc]))
                inc = act.astype(jnp.int32)
                idx = idx + inc
                op = op + inc
                rem = rem - inc
                act = act & (rem > 0)
                if eos_id is not None:       # in-loop done-flag, no host read
                    act = act & (tok != eos_id)
                return (pools, lt, idx, rem, act, ob, op, t + 1)

            c0 = (pools, st["last_tok"], st["index"], st["remaining"],
                  st["active"], st["out_buf"], st["out_pos"], jnp.int32(0))
            pools, lt, idx, rem, act, ob, op, t = jax.lax.while_loop(
                cond, body, c0)
            return pools, {**st, "last_tok": lt, "index": idx,
                           "remaining": rem, "active": act, "out_buf": ob,
                           "out_pos": op}, t

        self._chunk = jax.jit(chunk_fn, static_argnames=("max_steps",))

        def admit_state(st, lg, key, slot, seed, n_prompt, n_new, temp):
            """Fold a freshly prefilled request into the batch state: sample
            its first token (keyed by absolute position ``n_prompt``, same
            discipline as the decode loop) and set its slot's rows. One
            jitted call instead of a dozen eager dispatches."""
            base = jax.random.fold_in(key, seed)
            first_key = jax.random.fold_in(base, n_prompt)
            lg = lg.astype(jnp.float32)
            safe_t = jnp.where(temp > 0, temp, 1.0)
            cat = jax.random.categorical(first_key, lg / safe_t)
            tok = jnp.where(temp > 0, cat, jnp.argmax(lg)).astype(jnp.int32)
            st = dict(st)
            st["last_tok"] = st["last_tok"].at[slot, 0].set(tok)
            st["index"] = st["index"].at[slot].set(n_prompt)
            st["remaining"] = st["remaining"].at[slot].set(n_new - 1)
            st["active"] = st["active"].at[slot].set(n_new > 1)
            st["out_buf"] = st["out_buf"].at[slot, 0].set(tok)
            st["out_pos"] = st["out_pos"].at[slot].set(1)
            st["keys"] = st["keys"].at[slot].set(base)
            st["temps"] = st["temps"].at[slot].set(temp)
            return st

        self._admit_state = jax.jit(admit_state)

    # -- request API --------------------------------------------------------

    def submit(self, prompt: np.ndarray, n_new: int,
               temperature: float = 0.0, seed: int = 0) -> int:
        """prompt: (s,) int32. Returns a request id; drive with step()/run().
        The whole token budget is validated here — no silent overflow."""
        prompt = np.asarray(prompt, np.int32)
        s = prompt.shape[-1]
        if n_new < 1:
            raise ValueError(f"n_new must be >= 1, got {n_new}")
        if s + n_new > self.max_len:
            raise ValueError(
                f"prompt ({s}) + n_new ({n_new}) = {s + n_new} tokens "
                f"exceeds the cache budget max_len={self.max_len}")
        need = self.cache.blocks_needed(s + n_new)
        total = self.cache._group_phys.get("full", 0)
        if need > total > 0:
            raise ValueError(
                f"request needs {need} cache blocks but the pool only has "
                f"{total} — raise full_blocks or max_len")
        return self.scheduler.submit(prompt, n_new, temperature, seed)

    def swap_params(self, params: Dict) -> None:
        """Hot-swap served params. They are an *argument* of the jitted
        decode chunk, so this neither recompiles nor perturbs in-flight
        request state — the next chunk simply decodes under the new model."""
        self.params = params
        self.n_swaps += 1

    @property
    def n_running(self) -> int:
        return len(self.scheduler.running)

    # -- drive --------------------------------------------------------------

    def _admit_all(self) -> List[Request]:
        """Admit every waiting request that fits (FIFO, stop at the first
        that doesn't). Admissions sharing a prompt length share one batched
        prefill into a bucketed scratch cache; each request's prefill KV is
        then scattered into its reserved blocks and its first token folded
        into the batch state — it rides ``out_buf[slot, 0]`` and is
        collected with the next chunk, so admission never syncs the host
        (a telemetry sink adds one fence per prefill group, to stamp
        first-token readiness for TTFT). Returns the admitted requests."""
        admitted: List[Request] = []
        while True:
            req = self.scheduler.next_admit()
            if req is None or not self.cache.can_admit(req.total_budget):
                break
            r = self.scheduler.admit()
            self.cache.reserve(r.slot, r.total_budget)
            admitted.append(r)
        by_len: Dict[int, List[Request]] = {}
        for r in admitted:
            by_len.setdefault(len(r.prompt), []).append(r)
        for n_prompt, group in by_len.items():
            k = len(group)
            bucket = min(self.max_len,
                         1 << max(3, (n_prompt - 1).bit_length()))
            if (k, bucket) not in self._mono_scratch:
                self._mono_scratch[(k, bucket)] = init_cache(
                    self.cfg, k, bucket, self.cache_dtype)
            prompts = jnp.asarray(np.stack([r.prompt for r in group]))
            logits, mono = self._prefill(self.params, prompts,
                                         self._mono_scratch[(k, bucket)],
                                         None)
            for i, r in enumerate(group):
                self.cache.write_prefill(r.slot, mono, n_prompt, row=i)
                self._st = self._admit_state(
                    self._st, logits[i, -1], self._key, r.slot, r.seed,
                    n_prompt, r.n_new, jnp.float32(r.temperature))
            if self.telemetry.enabled:
                # first token sampled for every request of this group —
                # fence once, stamp TTFT readiness for the whole group.
                jax.block_until_ready(self._st["out_buf"])
                now = time.perf_counter()
                for r in group:
                    r.t_first = now
        return admitted

    def _collect(self) -> List[Request]:
        st = self._st
        out_pos = np.asarray(st["out_pos"])
        out_buf = np.asarray(st["out_buf"])
        active = np.asarray(st["active"])
        finished: List[Request] = []
        for slot, req in list(self.scheduler.running.items()):
            k = int(out_pos[slot])
            if k:
                req.tokens.extend(int(t) for t in out_buf[slot, :k])
                self.tokens_generated += k
            if not active[slot]:         # budget spent or stop token emitted
                self.cache.release(slot)
                finished.append(self.scheduler.evict(slot))
        st["out_pos"] = jnp.zeros_like(st["out_pos"])
        return finished

    def step(self) -> List[Request]:
        """One scheduling round: admit waiting requests into free slots,
        run one jitted decode chunk, collect tokens and recycle finished
        slots. Returns the requests that finished this round."""
        tele = self.telemetry
        obs_on = tele.enabled
        admitted = self._admit_all()
        if not self.scheduler.running:
            return []
        stop_early = jnp.asarray(bool(self.scheduler.queue))
        # attend only over block-table columns actually backed by reserved
        # blocks (bucketed to a power of two to bound retraces) — the
        # monolithic engine must attend over the whole max_len budget
        tables = self.cache.tables
        full = tables.get("full")
        w = self.cache.used_width()
        if full is not None and w is not None and w < full.shape[1]:
            tables = {**tables, "full": full[:, :w]}
        t0 = time.perf_counter() if obs_on else 0.0
        pools, st, t = self._chunk(self.params, self.cache.pools,
                                   tables, self._st, stop_early,
                                   max_steps=self.chunk)
        self.cache.pools = pools
        self._st = st
        if obs_on:
            jax.block_until_ready(st["out_pos"])
            chunk_s = time.perf_counter() - t0
            steps = int(t)               # host read: telemetry only
        tokens_before = self.tokens_generated
        finished = self._collect()
        if obs_on:
            now = time.perf_counter()
            tokens = self.tokens_generated - tokens_before
            free = self.cache.free_blocks()
            total = self.cache._group_phys.get("full", 0)
            tele.emit(ServeSample(
                chunk_s=chunk_s, steps=steps, tokens=tokens,
                itl_s=chunk_s / max(steps, 1),
                n_running=self.n_running,
                queue_depth=len(self.scheduler.queue),
                admitted=len(admitted), finished=len(finished),
                blocks_free=free, blocks_total=total,
                occupancy=(1.0 - free / total) if total else 0.0,
                ttft_s=[r.t_first - r.t_submit for r in admitted
                        if r.t_first is not None],
                e2e_s=[now - r.t_submit for r in finished]))
        return finished

    def run(self) -> Dict[int, np.ndarray]:
        """Drain queue + running batch; returns {rid: generated tokens}."""
        while not self.scheduler.idle:
            self.step()
        return {rid: np.asarray(r.tokens, np.int32)
                for rid, r in self.scheduler.finished.items()}

    def generate(self, prompts: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Batched convenience wrapper: submit one request per row (row i
        seeded ``seed + i``), drain, return (b, n_new) in submission order."""
        prompts = np.asarray(prompts, np.int32)
        rids = [self.submit(p, n_new, temperature, seed + i)
                for i, p in enumerate(prompts)]
        done = self.run()
        return np.stack([done[r] for r in rids])


class HotSwapBridge:
    """``Trainer.run(serve_hook=...)`` adapter: on each call, extract the
    Sec. 4.1 fixed point (beta=1 equal aggregation, worker 0's slice) and
    hot-swap it into a live engine; in-flight requests keep decoding. Each
    swap appends a staleness record to ``swaps``: rounds since the engine
    last saw fresh params, how many tokens were served under the stale
    copy, the L2 drift the swap closed, and the in-flight request count."""

    def __init__(self, engine, telemetry=None):
        """``telemetry`` defaults to the engine's own sink, so a bridge
        over an instrumented engine emits ``HotSwap`` events without
        extra wiring; pass an explicit sink (or ``repro.obs.NULL``) to
        override."""
        self.engine = engine
        self.telemetry = (telemetry if telemetry is not None
                          else getattr(engine, "telemetry", NULL))
        self.swaps: List[Dict] = []
        self._last_round: Optional[int] = None
        self._tokens_at_swap = engine.tokens_generated

    @staticmethod
    def _drift(old: Dict, new: Dict) -> float:
        sq = jax.tree.map(
            lambda a, b: jnp.sum(
                (a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2),
            old, new)
        return float(jnp.sqrt(sum(jax.tree.leaves(sq))))

    def __call__(self, round_idx: int, params: Dict, axes: Dict) -> Dict:
        from repro.train.evaluate import consensus_params
        new = consensus_params(params, axes)
        rec = {
            "round": int(round_idx),
            "rounds_since_last": (int(round_idx) - self._last_round
                                  if self._last_round is not None else None),
            "tokens_under_prev": self.engine.tokens_generated
            - self._tokens_at_swap,
            "param_drift_l2": self._drift(self.engine.params, new),
            "in_flight": self.engine.n_running,
        }
        self.engine.swap_params(new)
        self._last_round = int(round_idx)
        self._tokens_at_swap = self.engine.tokens_generated
        self.swaps.append(rec)
        if self.telemetry.enabled:
            self.telemetry.emit(HotSwap(**rec))
        return rec
