"""Serving engine: batched prefill + token-by-token decode with per-layer
KV caches (ring buffers for sliding-window layers) and SSM recurrent states.

For trained WASGD checkpoints the served copy is worker 0's slice after a
final beta=1 aggregation (all workers coincide — Sec. 4.1's tau-step fixed
point), extracted with ``core.take_worker``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, max_len: int = 2048,
                 cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(functools.partial(prefill, cfg))
        self._decode = jax.jit(functools.partial(decode_step, cfg))

    def generate(self, prompt: np.ndarray, n_new: int,
                 media: Optional[np.ndarray] = None,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompt: (b, s) int32 (or (b, s, n_q) audio). Greedy if T == 0."""
        b, s = prompt.shape[:2]
        cache = init_cache(self.cfg, b, self.max_len, self.cache_dtype)
        logits, cache = self._prefill(self.params, jnp.asarray(prompt), cache,
                                      media)
        key = jax.random.key(seed)
        out = [self._sample(logits, temperature, key)]
        index = s
        for t in range(n_new - 1):
            key, sub = jax.random.split(key)
            tok = out[-1]
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(index), media)
            out.append(self._sample(logits, temperature, sub))
            index += 1
        return np.concatenate([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits, temperature, key):
        logits = logits[:, -1:] if logits.shape[1] > 1 else logits
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
