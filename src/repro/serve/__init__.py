from repro.serve.engine import ContinuousEngine, HotSwapBridge, ServeEngine
from repro.serve.paged_cache import PagedCache
from repro.serve.scheduler import Request, Scheduler

__all__ = ["ContinuousEngine", "HotSwapBridge", "PagedCache", "Request",
           "Scheduler", "ServeEngine"]
