"""Continuous-batching scheduler: FIFO admission queue, batch-slot
recycling, per-request insertion into and eviction from the running batch
at token boundaries.

The scheduler is pure bookkeeping — it owns no device state. The engine
asks it which request to admit next (``next_admit``), binds a free slot
(``admit``), and returns finished requests to it (``evict``); the paged
cache separately gates admission on block availability.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from time import perf_counter
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (s,) int32
    n_new: int
    temperature: float = 0.0
    seed: int = 0
    tokens: List[int] = field(default_factory=list)   # generated so far
    slot: Optional[int] = None
    # host wall-clock marks (perf_counter domain) for latency telemetry:
    # submission, and first-token readiness (set by the engine at the end
    # of the request's prefill when a telemetry sink is attached).
    t_submit: float = field(default_factory=perf_counter)
    t_first: Optional[float] = None

    @property
    def total_budget(self) -> int:
        return len(self.prompt) + self.n_new

    @property
    def done(self) -> bool:
        """Budget spent. A request can also finish early on a stop token —
        eviction is the authoritative signal, this is a convenience."""
        return len(self.tokens) >= self.n_new


class Scheduler:
    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}      # slot -> request
        self.finished: Dict[int, Request] = {}     # rid -> request
        self._free_slots: List[int] = list(range(n_slots))
        self._next_rid = 0

    def submit(self, prompt: np.ndarray, n_new: int,
               temperature: float = 0.0, seed: int = 0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid,
                                  prompt=np.asarray(prompt, np.int32),
                                  n_new=int(n_new),
                                  temperature=float(temperature),
                                  seed=int(seed)))
        return rid

    # -- admission ----------------------------------------------------------

    def has_free_slot(self) -> bool:
        return bool(self._free_slots)

    def next_admit(self) -> Optional[Request]:
        """Peek the request that would be admitted next (FIFO)."""
        if self.queue and self._free_slots:
            return self.queue[0]
        return None

    def admit(self) -> Request:
        """Bind the head-of-queue request to a free slot."""
        req = self.queue.popleft()
        req.slot = self._free_slots.pop()
        self.running[req.slot] = req
        return req

    # -- completion ---------------------------------------------------------

    def evict(self, slot: int) -> Request:
        """Remove a finished (or cancelled) request and recycle its slot."""
        req = self.running.pop(slot)
        req.slot = None
        self._free_slots.append(slot)
        self.finished[req.rid] = req
        return req

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running
