"""Paged/block KV cache: device-side block pools plus a host-side free-list
allocator and per-request block tables.

Replaces the monolithic ``(b, max_len, kv, hd)`` serving cache. Storage is
a per-layer pool of fixed-size blocks ``(n_pool, block_size, kv, hd)`` whose
last row is the *trash block* (inactive batch rows write there); requests
address the pool through int32 block tables, one table per *layout group*
(see ``models.cache_layout``):

* ``"full"`` group — full-attention layers. Each request reserves
  ``ceil((prompt + n_new) / block_size)`` blocks from a free list at
  admission (so the decode loop never allocates) and releases them at
  eviction; unreserved table entries point at the trash block and are
  masked off by the ``slot <= index`` validity test.
* ``"ring{R}"`` groups — sliding-window layers. The ring keeps every slot
  live, so each batch slot permanently owns its ``R / block_size`` blocks
  and the table is static.

Block ids are shared across all layers of a group: each layer has its own
K/V pool, indexed by the same table. Recycling a slot needs no zeroing —
the validity masks (age for rings, ``slot <= index`` for full layers)
already exclude a previous tenant's stale blocks.

SSM layers carry per-slot recurrent state ``(n_slots, ...)`` rather than
blocks; admission overwrites the row, the engine freezes inactive rows.
"""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ssm as SSM
from repro.models.transformer import PagedKV, cache_layout


class PagedCache:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 block_size: int = 16, dtype=jnp.bfloat16,
                 full_blocks: int | None = None):
        """``full_blocks`` caps the full-group physical pool (default: fully
        provisioned, ``n_slots * ceil(max_len / block_size)``); a smaller
        budget makes admission wait on the free list — real paging."""
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.layout = cache_layout(cfg, max_len, block_size)

        self._group_phys: Dict[str, int] = {}
        for name, g in self.layout["groups"].items():
            if g["ring"] is not None:
                self._group_phys[name] = n_slots * g["n_blk"]
            else:
                cap = (n_slots * g["n_blk"] if full_blocks is None
                       else full_blocks)
                self._group_phys[name] = cap

        self._tables_np: Dict[str, np.ndarray] = {}
        for name, g in self.layout["groups"].items():
            if g["ring"] is not None:
                nb = g["n_blk"]
                t = np.arange(n_slots * nb, dtype=np.int32).reshape(
                    n_slots, nb)
            else:
                # everything starts unmapped: point at the trash block
                t = np.full((n_slots, g["n_blk"]), self._group_phys[name],
                            np.int32)
            self._tables_np[name] = t
        self._tables_dev: Dict[str, jnp.ndarray] | None = None

        self._free: List[int] = list(range(self._group_phys.get("full", 0)))
        self._owned: Dict[int, List[int]] = {}

        self.pools: Dict[str, Dict] = {}
        for i in range(cfg.n_layers):
            ent: Dict = {}
            lay = self.layout["layers"][f"L{i}"]
            if "attn" in lay:
                n_pool = self._group_phys[lay["attn"]["group"]] + 1
                shape = (n_pool, block_size, cfg.n_kv_heads, cfg.head_dim)
                ent["attn"] = PagedKV(k=jnp.zeros(shape, dtype),
                                      v=jnp.zeros(shape, dtype))
            if "ssm" in lay:
                ent["ssm"] = SSM.init_ssm_state(n_slots, cfg.d_model, cfg.ssm,
                                                jnp.float32)
            self.pools[f"L{i}"] = ent

    # -- block tables -------------------------------------------------------

    @property
    def tables(self) -> Dict[str, jnp.ndarray]:
        if self._tables_dev is None:
            self._tables_dev = {k: jnp.asarray(v)
                                for k, v in self._tables_np.items()}
        return self._tables_dev

    def blocks_needed(self, n_tokens: int) -> int:
        if "full" not in self.layout["groups"]:
            return 0
        return -(-n_tokens // self.block_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.blocks_needed(n_tokens) <= len(self._free)

    def free_blocks(self) -> int:
        return len(self._free)

    def used_width(self) -> int | None:
        """Width (in blocks) of the full-group table prefix that is actually
        backed by reserved blocks, bucketed up to a multiple of four so a
        jitted consumer sees at most ``n_blk / 4`` distinct shapes.
        ``reserve`` fills each row's table as a contiguous prefix, so
        slicing to this width drops only trash-mapped (masked-off) columns.
        None when the config has no full-attention group or nothing is
        reserved."""
        if "full" not in self.layout["groups"]:
            return None
        used = max((len(b) for b in self._owned.values()), default=0)
        if used == 0:
            return None
        n_blk = self.layout["groups"]["full"]["n_blk"]
        return min(n_blk, 4 * (-(-used // 4)))

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Reserve the request's full token budget up front so the decode
        loop never allocates."""
        need = self.blocks_needed(n_tokens)
        if need > len(self._free):
            raise RuntimeError(
                f"paged cache exhausted: need {need} blocks for slot {slot}, "
                f"{len(self._free)} free")
        blocks = [self._free.pop() for _ in range(need)]
        self._owned[slot] = blocks
        if need:
            self._tables_np["full"][slot, :need] = blocks
            self._tables_dev = None

    def release(self, slot: int) -> None:
        self._free.extend(self._owned.pop(slot, []))
        for name, g in self.layout["groups"].items():
            if g["ring"] is None:
                self._tables_np[name][slot, :] = self._group_phys[name]
        self._tables_dev = None

    # -- admission ----------------------------------------------------------

    def write_prefill(self, slot: int, mono_cache: Dict,
                      n_prompt: int, row: int = 0) -> None:
        """Scatter row ``row`` of a monolithic ``prefill`` cache into the
        pools at ``slot``. Linear layers gather mono positions
        ``0..n_prompt-1``;
        ring layers re-place the retained tail from the mono ring layout
        (slot ``p % size``) onto the padded ring (slot ``p % R``).

        Index arrays are built host-side; the scatter over all layers runs
        as one jitted call (cached per prompt-length bucket), so admission
        costs a handful of dispatches rather than a handful per layer."""
        bs = self.block_size
        idx: Dict[str, tuple] = {}
        for i in range(self.cfg.n_layers):
            lay = self.layout["layers"][f"L{i}"]
            if "attn" not in lay:
                continue
            al = lay["attn"]
            size_m = mono_cache[f"L{i}"]["kv"].k.shape[1]
            keep = min(n_prompt, size_m)
            pos = np.arange(n_prompt - keep, n_prompt)
            src = pos % size_m              # == pos when nothing wrapped
            ring = al["ring"]
            new_slot = pos % ring if ring is not None else pos
            pb = self._tables_np[al["group"]][slot, new_slot // bs]
            idx[f"L{i}"] = (pb.astype(np.int32), (new_slot % bs).astype(
                np.int32), src.astype(np.int32))
        self.pools = self._scatter(self.pools, mono_cache, idx,
                                   jnp.int32(slot), jnp.int32(row))

    @functools.cached_property
    def _scatter(self):
        cfg, layout = self.cfg, self.layout

        def scatter(pools, mono, idx, slot, row):
            new: Dict[str, Dict] = {}
            for i in range(cfg.n_layers):
                lay = layout["layers"][f"L{i}"]
                ent = dict(pools[f"L{i}"])
                m = mono[f"L{i}"]
                if "attn" in lay:
                    pb, off, src = idx[f"L{i}"]
                    kv, pool = m["kv"], ent["attn"]
                    ent["attn"] = PagedKV(
                        k=pool.k.at[pb, off].set(
                            kv.k[row, src].astype(pool.k.dtype)),
                        v=pool.v.at[pb, off].set(
                            kv.v[row, src].astype(pool.v.dtype)))
                if "ssm" in lay:
                    st = m["ssm"]
                    ent["ssm"] = SSM.SSMState(
                        s=ent["ssm"].s.at[slot].set(st.s[row]),
                        conv=ent["ssm"].conv.at[slot].set(st.conv[row]))
                new[f"L{i}"] = ent
            return new

        return jax.jit(scatter)
