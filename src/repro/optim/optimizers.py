"""Native pytree optimizers (no optax): SGD, momentum-SGD, AdamW.

The paper's method is defined over plain SGD (Eq. 10 subtracts eta*g after
the aggregation); momentum/AdamW are provided for the substrate's generality.
Optimizer state is element-wise, so the WASGD worker dimension is transparent.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Dict], Any]
    update: Callable[[Dict, Any, Dict], Tuple[Dict, Any]]
    name: str


def _tree_zeros(params):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    """Scale the whole gradient pytree so its global norm <= max_norm."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def lr_schedule(kind: str, base_lr: float, warmup_steps: int = 0,
                total_steps: int = 10000, min_ratio: float = 0.1
                ) -> Callable[[jax.Array], jax.Array]:
    """constant | linear_warmup | cosine (with linear warmup)."""
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))
        if kind == "constant":
            return base_lr * (warm if warmup_steps else 1.0)
        if kind == "linear_warmup":
            return base_lr * warm
        if kind == "cosine":
            t = jnp.clip((step - warmup_steps)
                         / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
            cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
            return base_lr * warm * (min_ratio + (1 - min_ratio) * cos)
        raise ValueError(kind)
    return fn


def make_optimizer(name: str = "sgd", learning_rate: float = 1e-3,
                   momentum: float = 0.9, weight_decay: float = 0.0,
                   b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
                   ) -> Optimizer:
    lr = learning_rate

    if name == "sgd":
        def init(params):
            return ()

        def update(grads, state, params):
            new_p = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * (g.astype(jnp.float32)
                                      + weight_decay * p.astype(jnp.float32))
                              ).astype(p.dtype),
                params, grads)
            return new_p, state

    elif name == "momentum":
        def init(params):
            return _tree_zeros(params)

        def update(grads, state, params):
            new_m = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state, grads)
            new_p = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                params, new_m)
            return new_p, new_m

    elif name == "adamw":
        class AdamState(NamedTuple):
            mu: Dict
            nu: Dict
            count: jax.Array

        def init(params):
            return AdamState(_tree_zeros(params), _tree_zeros(params),
                             jnp.zeros((), jnp.int32))

        def update(grads, state, params):
            count = state.count + 1
            mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) *
                              g.astype(jnp.float32), state.mu, grads)
            nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                              jnp.square(g.astype(jnp.float32)),
                              state.nu, grads)
            c1 = 1 - b1 ** count.astype(jnp.float32)
            c2 = 1 - b2 ** count.astype(jnp.float32)

            def upd(p, m, v):
                step = (m / c1) / (jnp.sqrt(v / c2) + eps)
                return (p.astype(jnp.float32)
                        - lr * (step + weight_decay * p.astype(jnp.float32))
                        ).astype(p.dtype)

            return (jax.tree.map(upd, params, mu, nu),
                    AdamState(mu, nu, count))
    else:
        raise ValueError(f"unknown optimizer {name!r}")

    return Optimizer(init, update, name)
