from repro.parallel.sharding import (
    SERVE_LONG_RULES,
    SERVE_RULES,
    TRAIN_RULES,
    num_workers,
    sharding_for,
    spec_for,
    tree_shardings,
)

__all__ = [
    "SERVE_LONG_RULES",
    "SERVE_RULES",
    "TRAIN_RULES",
    "num_workers",
    "sharding_for",
    "spec_for",
    "tree_shardings",
]
