"""Logical-axis sharding rules.

Parameters and inputs are annotated with *logical* axis names (``"worker"``,
``"heads"``, ``"ffn"`` ...). A rule table maps logical names to physical mesh
axes; ``spec_for`` resolves a tuple of logical names + a concrete shape into a
``PartitionSpec``, silently falling back to replication for any dimension the
mesh axis does not divide evenly (e.g. gemma3's 4 query heads over a 16-way
model axis, or yi's 4 KV heads).

The model code never touches physical axes — swapping the sharding scheme is
a rules-table edit, which is how the §Perf iterations change layouts.
"""
from __future__ import annotations

import math
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxes = Tuple[Optional[str], ...]
Rule = Union[None, str, Tuple[str, ...]]


# Default rule tables ---------------------------------------------------------

# Training: the WASGD worker axis spans ("pod", "data"); tensor parallelism
# spans "model". Batch inside a worker is NOT sharded (each worker is one
# data-parallel group).
TRAIN_RULES: Dict[str, Rule] = {
    "worker": ("pod", "data"),
    "batch": None,
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "data",          # expert-parallel single copy over the worker axis
    "expert_ffn": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "media": None,
    "kv_seq": None,
}

# Serving (no worker axis): batch over ("pod","data"), TP over "model".
SERVE_RULES: Dict[str, Rule] = {
    **TRAIN_RULES,
    "worker": None,
    "batch": ("pod", "data"),
    "experts": "model",         # single-copy serving: EP folds into the TP axis
    "expert_ffn": None,
    # KV caches dominate decode memory: when kv_heads < model-axis size the
    # heads dim falls back to replicated and the head_dim picks up "model"
    # (the PartitionSpec dedupe keeps whichever resolves first).
    "head_dim": "model",
}

# Long-context serving (batch=1): shard the KV-cache/sequence dim over "data"
# (flash-decode partial-softmax combine), batch replicated.
SERVE_LONG_RULES: Dict[str, Rule] = {
    **SERVE_RULES,
    "batch": None,
    "kv_seq": "data",
    "seq": "data",
}


def _axis_size(mesh: Mesh, rule: Rule) -> int:
    if rule is None:
        return 1
    names = (rule,) if isinstance(rule, str) else rule
    size = 1
    for n in names:
        if n in mesh.shape:
            size *= mesh.shape[n]
    return size


def _present(mesh: Mesh, rule: Rule) -> Rule:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)."""
    if rule is None:
        return None
    names = (rule,) if isinstance(rule, str) else rule
    kept = tuple(n for n in names if n in mesh.shape)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else kept


def spec_for(
    mesh: Mesh,
    axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    rules: Optional[Mapping[str, Rule]] = None,
) -> P:
    """Resolve logical axes (+ optional concrete shape) to a PartitionSpec."""
    rules = TRAIN_RULES if rules is None else rules
    out = []
    for i, name in enumerate(axes):
        rule = _present(mesh, rules.get(name)) if name is not None else None
        if rule is not None and shape is not None:
            if shape[i] % _axis_size(mesh, rule) != 0:
                rule = None  # divisibility fallback: replicate this dim
        out.append(rule)
    # PartitionSpec forbids repeated mesh axes; keep the first occurrence.
    seen: set = set()
    cleaned = []
    for rule in out:
        names = () if rule is None else ((rule,) if isinstance(rule, str) else tuple(rule))
        if any(n in seen for n in names):
            cleaned.append(None)
        else:
            seen.update(names)
            cleaned.append(rule)
    return P(*cleaned)


def sharding_for(
    mesh: Mesh,
    axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    rules: Optional[Mapping[str, Rule]] = None,
) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, axes, shape, rules))


def tree_shardings(mesh: Mesh, shapes_tree, axes_tree, rules=None):
    """Map parallel (ShapeDtypeStruct, axes) pytrees to a NamedSharding tree.

    The shapes tree leads so empty containers (e.g. an SGD optimizer state of
    ``()``) contribute no sharding leaves; axes tuples are picked up by
    ``flatten_up_to`` at the corresponding leaf positions.
    """
    return jax.tree.map(
        lambda s, axes: sharding_for(mesh, axes, s.shape, rules),
        shapes_tree,
        axes_tree,
    )


def num_workers(mesh: Mesh) -> int:
    """WASGD worker count = product of the worker-axis mesh dims."""
    return _axis_size(mesh, _present(mesh, TRAIN_RULES["worker"]))


def bytes_of(shape: Sequence[int], dtype) -> int:
    return math.prod(shape) * jax.numpy.dtype(dtype).itemsize
