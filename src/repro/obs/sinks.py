"""Telemetry sinks.

The contract is three members — ``enabled``, ``emit(event)``,
``close()`` — and the load-bearing one is ``enabled``: every
instrumentation site in the repo gates ALL telemetry work (fences,
host readbacks, timestamps) on it, so with the default ``NullSink``
the hot path is byte-for-byte the uninstrumented program (asserted in
``tests/test_obs.py`` under ``jax.transfer_guard("disallow")``).

``emit`` must be thread-safe: the Trainer's round loop, the async
checkpoint writer, and a serving engine may all emit into one sink.
``RingSink`` leans on the GIL-atomic ``deque.append``; ``JsonlSink``
serializes on the caller's thread and hands the finished line to a
single-worker ``concurrent.futures`` executor, so file writes are
ordered and the emitting thread never blocks on disk. Writer-thread
failures are latched under a lock and re-raised on the next ``emit``
or ``close`` — a run whose telemetry silently vanished is worse than
one that failed loud.
"""
from __future__ import annotations

import collections
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Iterator, List, Protocol, runtime_checkable

from repro.obs.events import event_from_record, to_record


@runtime_checkable
class Telemetry(Protocol):
    """Structural protocol every sink satisfies (duck-typed; the Trainer
    only ever touches these three members)."""
    enabled: bool

    def emit(self, event) -> None: ...

    def close(self) -> None: ...


class NullSink:
    """The default: telemetry off. ``enabled = False`` short-circuits
    every instrumentation site, so no fences, no host readbacks, no
    event construction — the hot path is identical to a telemetry-absent
    build."""
    enabled = False

    def emit(self, event) -> None:
        pass

    def close(self) -> None:
        pass


NULL = NullSink()


class RingSink:
    """In-memory ring of the last ``maxlen`` events — the test/debug
    sink. ``events()`` snapshots, ``by_kind`` filters."""
    enabled = True

    def __init__(self, maxlen: int = 4096):
        self._ring: "collections.deque" = collections.deque(maxlen=maxlen)

    def emit(self, event) -> None:
        self._ring.append(event)       # deque.append is atomic under the GIL

    def events(self) -> List:
        return list(self._ring)

    def by_kind(self, kind: str) -> List:
        return [e for e in self._ring if e.kind == kind]

    def close(self) -> None:
        pass


class JsonlSink:
    """Background-writer JSONL sink: one event per line (``to_record``
    payloads). Serialization happens on the emitting thread (events may
    hold references the caller mutates later — e.g. the engine's
    request lists); only the finished line crosses to the writer."""
    enabled = True

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")
        self._lock = threading.Lock()
        self._exc = None
        self._n_emitted = 0
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="obs-jsonl")

    def emit(self, event) -> None:
        line = json.dumps(to_record(event))
        self._raise_pending()
        with self._lock:
            self._n_emitted += 1
        self._pool.submit(self._write, line)

    def _write(self, line: str) -> None:
        try:
            self._f.write(line + "\n")
            self._f.flush()
        except BaseException as e:     # latch; surface on the emitter
            with self._lock:
                self._exc = e

    def _raise_pending(self) -> None:
        with self._lock:
            exc, self._exc = self._exc, None
        if exc is not None:
            raise RuntimeError(
                f"telemetry writer failed for {self.path}") from exc

    @property
    def n_emitted(self) -> int:
        with self._lock:
            return self._n_emitted

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        self._f.close()
        self._raise_pending()


def read_events(path: str) -> Iterator:
    """Iterate the typed events of a JSONL run (inverse of
    ``JsonlSink``; blank lines tolerated)."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield event_from_record(json.loads(line))
