"""Observability subsystem: structured telemetry for rounds, worker
assessment, and serving.

Typed events (``obs/events.py``) flow into a ``Telemetry`` sink
(``obs/sinks.py``): ``NullSink`` (default, hot path untouched),
``RingSink`` (in-memory), ``JsonlSink`` (background-writer JSONL —
summarize a recorded run with ``tools/obs_report.py``). Producers:
``Trainer.run(telemetry=)`` (RoundTrace, WorkerAssessment,
MembershipChange), ``AsyncCheckpointer`` (CheckpointSave),
``ContinuousEngine(telemetry=)`` (ServeSample), ``HotSwapBridge``
(HotSwap).
"""
from repro.obs.events import (CheckpointSave, HotSwap, MembershipChange,
                              PHASE_NAMES, RoundTrace, ServeSample,
                              WorkerAssessment, event_from_record, to_record,
                              summarize_policy_state)
from repro.obs.sinks import (JsonlSink, NULL, NullSink, RingSink, Telemetry,
                             read_events)

__all__ = [
    "CheckpointSave", "HotSwap", "JsonlSink", "MembershipChange", "NULL",
    "NullSink", "PHASE_NAMES", "RingSink", "RoundTrace", "ServeSample",
    "Telemetry", "WorkerAssessment", "event_from_record", "read_events",
    "summarize_policy_state", "to_record",
]
