"""Typed telemetry event records.

One dataclass per observable fact, each carrying a ``kind`` tag and a
host wall-clock stamp ``t_wall``. Events round-trip through JSONL:
``event.to_record()`` is a plain-JSON dict (numpy arrays become lists)
and ``event_from_record`` rebuilds the typed event from it, so a
recorded run can be re-analysed with the same types the live sinks saw
(``tools/obs_report.py`` does exactly that).

The schema is deliberately flat — every field is a scalar, a short list,
or a ``{phase: seconds}`` dict — so a JSONL line stays greppable and the
reporter never needs the repo's pytree machinery.

``RoundTrace`` phase names (``PHASE_NAMES``) mirror the structure of one
WASGD round: host staging of the round batch, the tau local steps
(lax.scan), the Judge/energy -> theta policy forward, the aggregation
schedule's reduce phase(s) (``reduce_scatter``/``all_gather`` for 2-phase
schedules, ``reduce`` for 1-phase), the overlap seam thunk, and the
Eq. 10 finalize + state assembly. Phases are only populated when the
Trainer runs the phase-fenced instrumented step (``detail="phased"``);
runs the instrumented step cannot decompose (pipelined rounds, baseline
rules) report a fenced ``total_s`` only (``detail="fused"``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np

PHASE_NAMES = ("host_staging", "local_steps", "judge", "reduce",
               "reduce_scatter", "overlap", "all_gather", "finalize")


def _now() -> float:
    return time.time()


def _jsonable(v):
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


@dataclasses.dataclass
class RoundTrace:
    """Device-accurate timing breakdown of one training round.

    ``phases`` maps phase names (see ``PHASE_NAMES``) to seconds; each
    phase is fenced with ``jax.block_until_ready`` before its timer
    stops, so the numbers measure compute, not dispatch. ``total_s`` is
    the fenced wall time of the whole device round (excluding
    ``host_staging_s``, which is the host-side batch pull + staging)."""
    kind = "round_trace"
    round: int
    total_s: float
    host_staging_s: float = 0.0
    phases: Dict[str, float] = dataclasses.field(default_factory=dict)
    detail: str = "phased"          # "phased" | "fused"
    p: Optional[int] = None         # live worker count
    t_wall: float = dataclasses.field(default_factory=_now)


@dataclasses.dataclass
class WorkerAssessment:
    """Per-round worker assessment: the paper's central signal.

    ``theta`` is the Eq. 10 weight vector the round aggregated with,
    ``energies`` the per-worker accumulated energies (h) the Judge
    scored, ``active`` the Alg. 4 activity mask (None on sync rounds),
    ``policy_state`` a small summary of the stateful policy's carried
    state (leaf count + L2), not the state itself."""
    kind = "worker_assessment"
    round: int
    theta: List[float]
    energies: List[float]
    theta_entropy: float
    active: Optional[List[bool]] = None
    policy: str = ""
    policy_state: Optional[Dict[str, Any]] = None
    t_wall: float = dataclasses.field(default_factory=_now)


@dataclasses.dataclass
class ServeSample:
    """One ``ContinuousEngine.step()`` scheduling round.

    ``ttft_s`` holds time-to-first-token for the requests admitted this
    step (submit -> first token sampled at the end of their prefill);
    ``e2e_s`` holds submit-to-finish latency for requests that finished
    this step. ``itl_s`` is the chunk's mean inter-token latency
    (fenced chunk wall / decode-loop iterations)."""
    kind = "serve_sample"
    chunk_s: float
    steps: int
    tokens: int
    itl_s: float
    n_running: int
    queue_depth: int
    admitted: int
    finished: int
    blocks_free: int
    blocks_total: int
    occupancy: float
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    e2e_s: List[float] = dataclasses.field(default_factory=list)
    t_wall: float = dataclasses.field(default_factory=_now)


@dataclasses.dataclass
class MembershipChange:
    """A committed ``WorkerSet`` resize at a round boundary."""
    kind = "membership_change"
    round: int
    old_p: int
    new_p: int
    generation: int = 0
    t_wall: float = dataclasses.field(default_factory=_now)


@dataclasses.dataclass
class CheckpointSave:
    """One completed async sharded checkpoint write. ``duration_s``
    covers the device-to-host gather plus the shard writes, measured on
    the writer thread (the part that rides the next rounds' device
    time)."""
    kind = "checkpoint_save"
    path: str
    round: int
    duration_s: float
    nbytes: int
    t_wall: float = dataclasses.field(default_factory=_now)


@dataclasses.dataclass
class HotSwap:
    """One train-to-serve ``HotSwapBridge`` swap with its staleness
    record (rounds since the engine last saw fresh params, tokens served
    under the stale copy, L2 drift the swap closed)."""
    kind = "hot_swap"
    round: int
    rounds_since_last: Optional[int]
    tokens_under_prev: int
    param_drift_l2: float
    in_flight: int
    t_wall: float = dataclasses.field(default_factory=_now)


EVENT_TYPES = {cls.kind: cls for cls in
               (RoundTrace, WorkerAssessment, ServeSample, MembershipChange,
                CheckpointSave, HotSwap)}


def to_record(event) -> Dict[str, Any]:
    """Event -> plain-JSON dict (one JSONL line's payload)."""
    rec = {"kind": event.kind}
    for f in dataclasses.fields(event):
        rec[f.name] = _jsonable(getattr(event, f.name))
    return rec


def event_from_record(rec: Dict[str, Any]):
    """Inverse of ``to_record``. Unknown kinds raise (a run recorded by
    a newer schema should fail loud, not be silently dropped); unknown
    FIELDS of a known kind are dropped, so minor schema growth stays
    readable."""
    rec = dict(rec)
    kind = rec.pop("kind")
    cls = EVENT_TYPES.get(kind)
    if cls is None:
        raise ValueError(f"unknown telemetry event kind {kind!r}; "
                         f"known: {sorted(EVENT_TYPES)}")
    names = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in rec.items() if k in names})


def summarize_policy_state(pstate) -> Optional[Dict[str, Any]]:
    """Small host-side summary of a policy's carried state: leaf count
    and total L2. ``None`` for the empty (stateless) state."""
    leaves = [np.asarray(x) for x in _leaves(pstate)]
    if not leaves:
        return None
    l2 = float(np.sqrt(sum(float(np.sum(np.square(x.astype(np.float64))))
                           for x in leaves)))
    return {"n_leaves": len(leaves), "l2": l2}


def _leaves(tree):
    if isinstance(tree, dict):
        for v in tree.values():
            yield from _leaves(v)
    elif isinstance(tree, (tuple, list)):
        for v in tree:
            yield from _leaves(v)
    elif tree is not None:
        yield tree
