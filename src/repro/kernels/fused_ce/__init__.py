from repro.kernels.fused_ce.ops import fused_ce
from repro.kernels.fused_ce.ref import fused_ce_ref

__all__ = ["fused_ce", "fused_ce_ref"]
