"""jit'd entry point for fused_ce (interpret mode off-TPU)."""
from __future__ import annotations

import jax

from repro.kernels.fused_ce.fused_ce import fused_ce as _fused_ce
from repro.kernels.runtime import default_interpret
from repro.kernels.fused_ce.ref import fused_ce_ref


def fused_ce(logits, labels, **kw):
    kw.setdefault("interpret", default_interpret())
    return _fused_ce(logits, labels, **kw)


__all__ = ["fused_ce", "fused_ce_ref"]
