"""Pure-jnp oracle for the fused_ce kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_ce_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    x = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(x, axis=-1)
    picked = jnp.take_along_axis(x, labels[:, None].astype(jnp.int32),
                                 axis=-1)[:, 0]
    return lse - picked
