"""Pallas TPU kernel: fused vocab-tiled cross-entropy.

    nll[t] = logsumexp(logits[t, :]) - logits[t, labels[t]]

The §Perf analysis showed the CE epilogue is where large-vocab architectures
(gemma3: 262k) burn HBM and collective bytes: XLA materializes log_softmax
over the full vocab and (under SPMD) all-gathers logits for the label
gather. This kernel streams (block_rows x block_v) logits tiles through VMEM
once, keeping a running (max, sumexp) flash-style accumulator per row and
picking the label logit in whichever vocab tile owns it — never
materializing probabilities. It is the kernel-level twin of the
``sharded_ce`` formulation (models/transformer.loss_fn).

VMEM: block 256 x 2048 f32 = 2 MiB/tile + 3 row vectors.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import default_interpret

NEG_INF = -1e30


def _ce_kernel(labels_ref, logits_ref, o_ref, m_ref, l_ref, lab_ref, *,
               block_v: int, n_vblocks: int, vocab: int):
    v_idx = pl.program_id(1)

    @pl.when(v_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        lab_ref[...] = jnp.zeros_like(lab_ref)

    x = logits_ref[...].astype(jnp.float32)            # (br, bv)
    v0 = v_idx * block_v
    cols = v0 + jax.lax.broadcasted_iota(jnp.int32, (x.shape[1],), 0)
    x = jnp.where((cols < vocab)[None, :], x, NEG_INF)  # mask padding

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, x.max(axis=-1))
    p = jnp.exp(x - m_new[:, None])
    l_new = l_prev * jnp.exp(m_prev - m_new) + p.sum(axis=-1)
    m_ref[...], l_ref[...] = m_new, l_new

    labels = labels_ref[...]                           # (br,)
    in_tile = (labels >= v0) & (labels < v0 + block_v)
    local = jnp.clip(labels - v0, 0, block_v - 1)
    onehot = jax.nn.one_hot(local, block_v, dtype=jnp.float32)
    picked = (x * onehot).sum(axis=-1)
    lab_ref[...] = lab_ref[...] + jnp.where(in_tile, picked, 0.0)

    @pl.when(v_idx == n_vblocks - 1)
    def _finalize():
        o_ref[...] = (m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
                      - lab_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "block_v", "interpret"))
def fused_ce(logits: jax.Array, labels: jax.Array, *, block_rows: int = 256,
             block_v: int = 2048,
             interpret: Optional[bool] = None) -> jax.Array:
    """logits: (T, V); labels: (T,) int32. Returns per-token nll (T,) f32."""
    interpret = default_interpret() if interpret is None else interpret
    t, v = logits.shape
    br = min(block_rows, t)
    bv = min(block_v, v)
    pad_t = (-t) % br
    pad_v = (-v) % bv
    if pad_t or pad_v:
        logits = jnp.pad(logits, ((0, pad_t), (0, pad_v)))
        labels = jnp.pad(labels, (0, pad_t))
    tp, vp = t + pad_t, v + pad_v

    out = pl.pallas_call(
        functools.partial(_ce_kernel, block_v=bv, n_vblocks=vp // bv,
                          vocab=v),
        grid=(tp // br, vp // bv),
        in_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),
            pl.BlockSpec((br, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((tp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((br,), jnp.float32),    # running max
            pltpu.VMEM((br,), jnp.float32),    # running sumexp
            pltpu.VMEM((br,), jnp.float32),    # label logit
        ],
        interpret=interpret,
    )(labels.astype(jnp.int32), logits)
    return out[:t]
