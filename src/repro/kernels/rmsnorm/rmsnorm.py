"""Pallas TPU kernel: fused RMSNorm.

    out = x * rsqrt(mean(x^2, -1) + eps) * scale

Row-blocked: grid over the flattened row dim; each tile is (block_rows, d)
in VMEM with the f32 mean-square reduction fused with the scale multiply —
one HBM pass instead of XLA's (square, reduce, rsqrt, mul, mul) chain.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import default_interpret


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (br, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            block_rows: int = 256,
            interpret: Optional[bool] = None) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    interpret = default_interpret() if interpret is None else interpret
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=float(eps)),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:rows].reshape(orig_shape)
