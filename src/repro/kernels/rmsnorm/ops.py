"""jit'd wrapper for the rmsnorm kernel (interpret mode off-TPU)."""
from __future__ import annotations

import jax

from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.runtime import default_interpret
from repro.kernels.rmsnorm.rmsnorm import rmsnorm as _rmsnorm


def rmsnorm(x, scale, eps: float = 1e-6):
    return _rmsnorm(x, scale, eps, interpret=default_interpret())


__all__ = ["rmsnorm", "rmsnorm_ref"]
