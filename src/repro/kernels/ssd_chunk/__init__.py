from repro.kernels.ssd_chunk.ops import ssd_chunked_kernel
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk

__all__ = ["ssd_chunk", "ssd_chunk_ref", "ssd_chunked_kernel"]
