"""Pallas TPU kernel: Mamba2 SSD within-chunk block (arXiv:2405.21060).

For each (batch, chunk, head) grid cell, computes the quadratic
"attention-like" diagonal block and the chunk's contribution to the
recurrent state:

    ll     = dt * a                      (L,)  log-decays
    cum    = cumsum(ll)
    y      = [tril(exp(cum_i - cum_j)) * (C B^T) * dt_j] @ x      (L, hd)
    state  = (exp(cum_L - cum) * dt * B)^T @ x                    (ds, hd)
    total  = cum_L                                                ()

The inter-chunk linear recurrence and the off-diagonal C·S_prev term stay in
pure JAX (tiny: one (nh, ds, hd) einsum per chunk) — this kernel owns the
O(L^2) and O(L·ds·hd) matmuls, which dominate SSD training FLOPs.

VMEM per cell at L=64, hd=64, ds=128: x 16 KiB + B/C 64 KiB + two (L, L)
f32 blocks 32 KiB — comfortably resident; both matmuls are MXU-shaped.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import default_interpret


def _ssd_chunk_kernel(xs_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, total_ref):
    xs = xs_ref[0, 0, :, 0].astype(jnp.float32)       # (L, hd)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)       # (L,)
    a = a_ref[0].astype(jnp.float32)                  # ()
    B = b_ref[0, 0].astype(jnp.float32)               # (L, ds)
    C = c_ref[0, 0].astype(jnp.float32)               # (L, ds)
    L = xs.shape[0]

    ll = dt * a
    cum = jnp.cumsum(ll)                              # (L,)
    total = cum[L - 1]

    cb = jnp.dot(C, B.T)                              # (L, L)
    dmat = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    att = jnp.where(mask, jnp.exp(dmat), 0.0) * cb * dt[None, :]
    y = jnp.dot(att, xs)                              # (L, hd)

    decay_to_end = jnp.exp(total - cum) * dt          # (L,)
    state = jnp.dot((decay_to_end[:, None] * B).T, xs)  # (ds, hd)

    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0, 0] = state.astype(state_ref.dtype)
    total_ref[0, 0, 0] = total.astype(total_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(xs: jax.Array, dt: jax.Array, a: jax.Array, B: jax.Array,
              C: jax.Array, *, interpret: Optional[bool] = None):
    """Within-chunk SSD.

    xs: (b, nc, L, nh, hd); dt: (b, nc, L, nh); a: (nh,);
    B, C: (b, nc, L, ds).
    Returns (y_diag (b, nc, L, nh, hd), states (b, nc, nh, ds, hd),
             totals (b, nc, nh)).
    """
    interpret = default_interpret() if interpret is None else interpret
    b, nc, L, nh, hd = xs.shape
    ds = B.shape[-1]
    y, states, totals = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(b, nc, nh),
        in_specs=[
            pl.BlockSpec((1, 1, L, 1, hd), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1,), lambda bi, ci, hi: (hi,)),
            pl.BlockSpec((1, 1, L, ds), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, L, ds), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, 1, hd), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, ds, hd), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, ci, hi: (bi, ci, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, L, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, nh, ds, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, nh), jnp.float32),
        ],
        interpret=interpret,
    )(xs, dt, a, B, C)
    return y, states, totals
