"""jit'd wrapper: a full chunked-SSD forward that uses the Pallas kernel for
the within-chunk blocks and pure JAX for the (tiny) inter-chunk recurrence —
a drop-in for ``models.ssm.ssd_chunked``."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.runtime import default_interpret
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk


def _interpret() -> bool:
    return default_interpret()


def ssd_chunked_kernel(xs: jax.Array, dt: jax.Array, a: jax.Array,
                       B: jax.Array, C: jax.Array, chunk: int,
                       init_state: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as models.ssm.ssd_chunked (y, final_state)."""
    b, s, nh, hd = xs.shape
    ds = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xs_c = xs.reshape(b, nc, chunk, nh, hd)
    dt_c = dt.reshape(b, nc, chunk, nh)
    B_c = B.reshape(b, nc, chunk, ds)
    C_c = C.reshape(b, nc, chunk, ds)

    y_diag, states, totals = ssd_chunk(xs_c, dt_c, a, B_c, C_c,
                                       interpret=_interpret())

    s0 = jnp.zeros((b, nh, ds, hd), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(carry, inp):
        st, tot = inp
        prev = carry
        new = jnp.exp(tot)[:, :, None, None] * prev + st
        return new, prev

    final, prevs = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   totals.transpose(1, 0, 2)))
    prevs = prevs.transpose(1, 0, 2, 3, 4)

    cum = jnp.cumsum(dt_c.astype(jnp.float32)
                     * a.astype(jnp.float32), axis=2)
    y_off = jnp.einsum("bnls,bnhsd,bnlh->bnlhd", C_c.astype(jnp.float32),
                       prevs, jnp.exp(cum))
    y = (y_diag + y_off).reshape(b, s, nh, hd)
    return y, final


__all__ = ["ssd_chunk", "ssd_chunk_ref", "ssd_chunked_kernel"]
