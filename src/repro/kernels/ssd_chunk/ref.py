"""Pure-jnp oracle for the ssd_chunk kernel (the within-chunk part of
models/ssm.ssd_chunked)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(xs, dt, a, B, C):
    """Same contract as kernels.ssd_chunk.ssd_chunk."""
    xs = xs.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    L = xs.shape[2]

    ll = dt * a                                       # (b, nc, L, nh)
    cum = jnp.cumsum(ll, axis=2)
    totals = cum[:, :, -1]                            # (b, nc, nh)

    cb = jnp.einsum("bnls,bnms->bnlm", C, B)
    dmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    att = jnp.where(mask[None, None, :, :, None], jnp.exp(dmat), 0.0) \
        * cb[..., None] * dt[:, :, None, :, :]
    y = jnp.einsum("bnlmh,bnmhd->bnlhd", att, xs)

    decay_to_end = jnp.exp(totals[:, :, None, :] - cum) * dt
    states = jnp.einsum("bnlh,bnls,bnlhd->bnhsd", decay_to_end, B, xs)
    return y, states, totals
