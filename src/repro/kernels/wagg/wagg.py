"""Pallas TPU kernel: fused WASGD weighted aggregation (Eq. 10).

    out[i, :] = (1 - beta) * x[i, :] + beta * sum_j theta[j] * x[j, :]

over a worker-stacked parameter block x: (p, N). A naive XLA lowering does
(reduce -> broadcast -> two muls -> add) with three HBM round trips over the
full parameter set; this kernel streams each (p, block_n) tile through VMEM
once. The worker dimension p (<= 32 on the production meshes) rides along in
full per tile, so the MXU-free VPU reduction over p stays in registers.

Tiling: grid over N in ``block_n`` VMEM tiles; block_n is chosen so
p * block_n * 4B (f32 accumulation) fits comfortably in VMEM (default
p=32 x 8192 x 4B = 1 MiB in, 1 MiB out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wagg_kernel(theta_ref, x_ref, o_ref, *, beta: float):
    x = x_ref[...].astype(jnp.float32)            # (p, bn)
    theta = theta_ref[...].astype(jnp.float32)    # (p,)
    agg = jnp.einsum("p,pn->n", theta, x)         # VPU reduction over workers
    out = (1.0 - beta) * x + beta * agg[None, :]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("beta", "block_n", "interpret"))
def wagg(x: jax.Array, theta: jax.Array, beta: float,
         block_n: int = 8192, interpret: bool = True) -> jax.Array:
    """x: (p, N); theta: (p,). Returns (p, N)."""
    p, n = x.shape
    bn = min(block_n, n)
    pad = (-n) % bn
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    np_ = n + pad
    out = pl.pallas_call(
        functools.partial(_wagg_kernel, beta=float(beta)),
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((p,), lambda j: (0,)),
            pl.BlockSpec((p, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((p, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((p, np_), x.dtype),
        interpret=interpret,
    )(theta, xp)
    return out[:, :n] if pad else out
