"""Pallas TPU kernel: fused WASGD weighted aggregation (Eq. 10), v2.

    out[i, :] = (1 - beta) * x[i, :] + beta * sum_j theta[j] * q[j, :]

over a worker-stacked parameter block x: (p, N). A naive XLA lowering does
(reduce -> broadcast -> two muls -> add) with three HBM round trips over the
full parameter set — and with a quantizing codec, encode/decode are further
separate XLA programs with their own round trips. This kernel streams each
(p, block_n) tile through VMEM once and fuses, in the same pass:

* **codec decode** — ``payload`` may be the codec's wire tiles (int8-carried
  int4/int8, or bf16); they are widened to f32 *in VMEM* and accumulated in
  f32. The per-leaf scalar scale (the codec ``aux``) is folded into theta by
  the ops wrapper, so integer tiles ride in untouched. ``payload=None``
  means the payload IS x (the f32 codec) and x is read once, not twice.
* **the Eq. 10 FMA** — ``(1-beta) x + beta m`` against the ORIGINAL x.
* **the Alg. 4 activity mask** — ``active`` (p,) selects the late-join rows
  (stragglers adopt the aggregate m; their theta is already 0 so m excludes
  them). ``active=None`` places no mask in the program at all.

The worker dimension p rides along in full per tile, so the MXU-free VPU
reduction over p stays in registers.

Tiling: grid over N in ``block_n`` VMEM tiles. ``auto_block_n`` guards the
VMEM budget: for large p the default ``block_n`` would over-allocate
(p * block_n * bytes/col), so the block is halved until the working set
fits instead of failing at compile time.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import default_interpret

# Per-kernel-invocation VMEM working-set budget. Real TPU cores have ~16 MiB
# of VMEM; half of it leaves room for double buffering of the streamed tiles.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

_MIN_BLOCK_N = 128


def _default_interpret() -> bool:
    # Shared policy (kernels/runtime.py): compiled on TPU, interpret
    # elsewhere. The old signature default hardcoded True, silently pinning
    # direct TPU callers to interpret mode.
    return default_interpret()


def auto_block_n(p: int, block_n: int, bytes_per_col: int,
                 budget: int = VMEM_BUDGET_BYTES) -> int:
    """Shrink ``block_n`` until the (p, block_n) tile working set fits VMEM.

    ``bytes_per_col`` is the per-element footprint across everything resident
    per tile (x in f32 + out + the separate payload when there is one). The
    block halves until ``p * block_n * bytes_per_col <= budget`` or the
    128-column floor, instead of over-allocating for large p.
    """
    bn = block_n
    while bn > _MIN_BLOCK_N and p * bn * bytes_per_col > budget:
        bn //= 2
    return bn


def _wagg_kernel(*refs, beta: float, masked: bool, separate_payload: bool):
    it = iter(refs)
    theta = next(it)[...].astype(jnp.float32)     # (p,)  scale pre-folded
    active = next(it)[...] if masked else None    # (p,)  f32 0/1
    q_ref = next(it) if separate_payload else None
    x_ref = next(it)
    o_ref = next(it)
    x = x_ref[...].astype(jnp.float32)            # (p, bn)
    src = q_ref[...].astype(jnp.float32) if separate_payload else x
    m = jnp.einsum("p,pn->n", theta, src)         # VPU reduction over workers
    out = (1.0 - beta) * x + beta * m[None, :]
    if masked:
        # Alg. 4 late-join: straggler rows adopt the aggregate wholesale.
        out = jnp.where(active[:, None] != 0, out, m[None, :])
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("beta", "block_n", "interpret"))
def wagg_fused(x: jax.Array, theta: jax.Array, beta: float,
               payload: Optional[jax.Array] = None,
               active: Optional[jax.Array] = None,
               block_n: int = 8192,
               interpret: Optional[bool] = None) -> jax.Array:
    """Fused decode + Alg. 4 mask + Eq. 10 over a (p, N) block.

    ``x``: (p, N) originals (any float dtype; the FMA runs in f32).
    ``theta``: (p,) effective weights — for a quantizing codec the per-leaf
    scale is already folded in (``theta * scale``), so ``payload`` tiles are
    consumed as-is. ``payload``: (p, N) codec wire tiles (int8/bf16/...), or
    ``None`` when the payload is x itself. ``active``: (p,) 0/1 mask (any
    numeric dtype), or ``None`` for the maskless program. Returns (p, N) in
    ``x.dtype``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    p, n = x.shape
    separate = payload is not None
    masked = active is not None
    per_col = 2 * 4 + (jnp.dtype(payload.dtype).itemsize if separate else 0)
    bn = auto_block_n(p, min(block_n, n), per_col)
    pad = (-n) % bn
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    np_ = n + pad

    tile = pl.BlockSpec((p, bn), lambda j: (0, j))
    vec = pl.BlockSpec((p,), lambda j: (0,))
    in_specs, operands = [vec], [theta]
    if masked:
        in_specs.append(vec)
        operands.append(active.astype(jnp.float32))
    if separate:
        qp = jnp.pad(payload, ((0, 0), (0, pad))) if pad else payload
        in_specs.append(tile)
        operands.append(qp)
    in_specs.append(tile)
    operands.append(xp)

    out = pl.pallas_call(
        functools.partial(_wagg_kernel, beta=float(beta), masked=masked,
                          separate_payload=separate),
        grid=(np_ // bn,),
        in_specs=in_specs,
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((p, np_), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :n] if pad else out


@functools.partial(jax.jit, static_argnames=("beta", "block_n", "interpret"))
def wagg(x: jax.Array, theta: jax.Array, beta: float,
         block_n: int = 8192, interpret: Optional[bool] = None) -> jax.Array:
    """x: (p, N); theta: (p,). Returns (p, N). The f32, maskless entry —
    the identical program ``wagg_fused`` emits with no payload and no mask
    (three refs: theta, x, out)."""
    return wagg_fused(x, theta, float(beta), block_n=block_n,
                      interpret=interpret)
