"""jit'd public entry points for the wagg kernel.

``aggregate_tree_wagg`` applies the kernel leaf-wise over a worker-stacked
parameter tree — a drop-in ``leaf_fn`` for ``core.aggregate.weighted_aggregate``,
and the implementation behind the ``"pallas_wagg"`` aggregation backend
(``core/backends.py``; select it with ``WASGDConfig(backend="pallas_wagg")``).
On non-TPU backends the kernel runs in interpret mode (CPU validation); the
pure-jnp reference is available as a fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wagg.wagg import wagg
from repro.kernels.wagg.ref import wagg_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def wagg_leaf(x: jax.Array, theta: jax.Array, beta) -> jax.Array:
    """One (p, ...) parameter leaf through the fused kernel."""
    p = x.shape[0]
    flat = x.reshape(p, -1)
    out = wagg(flat, theta, float(beta), interpret=_interpret())
    return out.reshape(x.shape)


def aggregate_tree_wagg(params, axes, theta, beta):
    from repro.core.aggregate import weighted_aggregate
    return weighted_aggregate(params, axes, theta, beta, leaf_fn=wagg_leaf)


__all__ = ["wagg", "wagg_ref", "wagg_leaf", "aggregate_tree_wagg"]
