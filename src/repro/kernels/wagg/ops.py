"""jit'd public entry points for the wagg kernels.

``wagg_leaf`` / ``wagg_fused_leaf`` apply the fused Eq. 10 kernel to one
worker-stacked parameter leaf; ``aggregate_tree_wagg`` maps it over a whole
tree — a drop-in ``leaf_fn`` for ``core.aggregate.weighted_aggregate`` and
the implementation behind the ``"pallas_wagg"`` aggregation schedule
(``core/backends.py``; select it with
``WASGDConfig(backend="pallas_wagg:<codec>")``). ``wagg_fused_leaf`` is the
v2 seam: it takes the codec's (payload, aux) pair and the Alg. 4 activity
mask, folds the per-leaf scalar scale into theta, and runs decode + mask +
FMA as ONE kernel pass. On non-TPU backends the kernels run in interpret
mode (CPU validation); the pure-jnp references are available as fallbacks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.runtime import default_interpret
from repro.kernels.wagg.wagg import auto_block_n, wagg, wagg_fused
from repro.kernels.wagg.ref import wagg_fused_ref, wagg_ref


def _interpret() -> bool:
    return default_interpret()


def wagg_leaf(x: jax.Array, theta: jax.Array, beta,
              active: Optional[jax.Array] = None) -> jax.Array:
    """One (p, ...) parameter leaf through the fused kernel (f32 payload)."""
    return wagg_fused_leaf(x, None, None, theta, beta, active=active)


def wagg_fused_leaf(x: jax.Array, payload: Optional[jax.Array], aux,
                    theta: jax.Array, beta,
                    active: Optional[jax.Array] = None) -> jax.Array:
    """One (p, ...) leaf: fused codec decode + Alg. 4 mask + Eq. 10 FMA.

    ``payload``/``aux`` are the codec's ``encode`` outputs (``payload=None``
    = the payload is x itself, the f32 codec). ``aux`` — the per-leaf scalar
    scale of the int8/int4 codecs — is folded into theta here
    (``m = sum_j (theta_j * scale) q_j``), so the kernel consumes the wire
    tiles untouched and needs no scalar plumbing of its own.
    """
    p = x.shape[0]
    theta_eff = theta.astype(jnp.float32)
    if aux is not None:
        theta_eff = theta_eff * jnp.asarray(aux, jnp.float32)
    flat_q = None if payload is None else payload.reshape(p, -1)
    act = None if active is None else active.astype(jnp.float32)
    out = wagg_fused(x.reshape(p, -1), theta_eff, float(beta),
                     payload=flat_q, active=act, interpret=_interpret())
    return out.reshape(x.shape)


def aggregate_tree_wagg(params, axes, theta, beta):
    from repro.core.aggregate import weighted_aggregate
    return weighted_aggregate(params, axes, theta, beta, leaf_fn=wagg_leaf)


__all__ = ["aggregate_tree_wagg", "auto_block_n", "wagg", "wagg_fused",
           "wagg_fused_leaf", "wagg_fused_ref", "wagg_leaf", "wagg_ref"]
