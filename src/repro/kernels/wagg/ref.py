"""Pure-jnp oracle for the wagg kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wagg_ref(x: jax.Array, theta: jax.Array, beta: float) -> jax.Array:
    """out[i] = (1-beta) x[i] + beta * sum_j theta[j] x[j]."""
    xf = x.astype(jnp.float32)
    agg = jnp.tensordot(theta.astype(jnp.float32), xf, axes=1)
    return ((1.0 - beta) * xf + beta * agg[None]).astype(x.dtype)
