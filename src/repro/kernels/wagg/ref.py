"""Pure-jnp oracles for the wagg kernels."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def wagg_ref(x: jax.Array, theta: jax.Array, beta: float) -> jax.Array:
    """out[i] = (1-beta) x[i] + beta * sum_j theta[j] x[j]."""
    xf = x.astype(jnp.float32)
    agg = jnp.tensordot(theta.astype(jnp.float32), xf, axes=1)
    return ((1.0 - beta) * xf + beta * agg[None]).astype(x.dtype)


def wagg_fused_ref(x: jax.Array, theta: jax.Array, beta: float,
                   payload: Optional[jax.Array] = None,
                   active: Optional[jax.Array] = None) -> jax.Array:
    """Oracle for the v2 fused kernel: the aggregate is taken over the codec
    ``payload`` (decoded to f32; per-leaf scale pre-folded into ``theta``,
    exactly the kernel's contract), the FMA against the original ``x``, and
    ``active`` rows late-join by adopting the aggregate."""
    xf = x.astype(jnp.float32)
    src = xf if payload is None else payload.astype(jnp.float32)
    m = jnp.tensordot(theta.astype(jnp.float32), src, axes=1)
    out = (1.0 - beta) * xf + beta * m[None]
    if active is not None:
        out = jnp.where(active[:, None] != 0, out,
                        jnp.broadcast_to(m[None], out.shape))
    return out.astype(x.dtype)
