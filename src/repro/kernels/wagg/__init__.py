from repro.kernels.wagg.ops import (aggregate_tree_wagg, wagg_fused_leaf,
                                    wagg_leaf)
from repro.kernels.wagg.ref import wagg_fused_ref, wagg_ref
from repro.kernels.wagg.wagg import auto_block_n, wagg, wagg_fused

__all__ = ["aggregate_tree_wagg", "auto_block_n", "wagg", "wagg_fused",
           "wagg_fused_leaf", "wagg_fused_ref", "wagg_leaf", "wagg_ref"]
