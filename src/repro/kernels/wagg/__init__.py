from repro.kernels.wagg.ops import aggregate_tree_wagg, wagg_leaf
from repro.kernels.wagg.ref import wagg_ref
from repro.kernels.wagg.wagg import wagg

__all__ = ["aggregate_tree_wagg", "wagg", "wagg_leaf", "wagg_ref"]
