"""Shared kernel runtime policy.

Every Pallas entry point in this package takes ``interpret=None`` and
resolves it here at call time: compiled on TPU, interpret mode everywhere
else. Hardcoding a literal default is exactly the bug PR 7 fixed in
``wagg`` (TPU callers silently pinned to interpret mode), and reprolint's
PAL001 now rejects the pattern tree-wide.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"
