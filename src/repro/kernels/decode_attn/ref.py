"""Pure-jnp oracle for the decode_attn kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    cache_len: jax.Array, *, window: Optional[int] = None
                    ) -> jax.Array:
    """q: (b, kv, g, hd); k, v: (b, S, kv, hd) -> (b, kv, g, hd)."""
    hd = q.shape[-1]
    S = k.shape[1]
    qf = q.astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32))
    pos = jnp.arange(S)
    valid = pos < cache_len
    if window is not None:
        valid &= (cache_len - 1 - pos) < window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
