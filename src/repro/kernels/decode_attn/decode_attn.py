"""Pallas TPU kernel: single-token GQA flash-decode attention.

One new query token per sequence attends over a long KV cache:

    q: (b, kv, g, hd)   (GQA groups folded out of h = kv * g)
    k, v: (b, S, kv, hd)
    out: (b, kv, g, hd)

The compute hot spot of the decode_32k / long_500k shapes. The grid is
(b, kv, S/block_s); TPU iterates the minor (S) axis sequentially per (b, kv),
so the running flash-softmax state (m, l, acc) lives in VMEM scratch across
S blocks and the output is written once at the last block. Masking handles
cache validity (pos < cache_len) and an optional sliding window.

VMEM per step: block_s x hd KV tile (e.g. 512 x 128 x 2 x 2B = 256 KiB)
plus (g, hd) accumulators — far under the ~16 MiB budget, leaving room for
double buffering of the K/V streams.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import default_interpret

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   block_s: int, n_blocks: int, window: Optional[int],
                   seq_len: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)        # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)        # (bs, hd)
    hd = q.shape[-1]

    cache_len = len_ref[0]
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, (k.shape[0],), 0)
    valid = pos < cache_len
    if window is not None:
        valid &= (cache_len - 1 - pos) < window

    s = jnp.einsum("gd,td->gt", q * hd ** -0.5, k)          # (g, bs)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.maximum(m_new, -0.5e30)
    p = jnp.exp(s - m_safe[:, None])
    corr = jnp.exp(m_prev - m_safe)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jnp.einsum("gt,td->gd", p, v)

    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(s_idx == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "block_s", "interpret"))
def decode_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                cache_len: jax.Array, *, window: Optional[int] = None,
                block_s: int = 512,
                interpret: Optional[bool] = None) -> jax.Array:
    """q: (b, kv, g, hd); k, v: (b, S, kv, hd); cache_len: () int32."""
    interpret = default_interpret() if interpret is None else interpret
    b, kv, g, hd = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = (S + pad) // bs

    kernel = functools.partial(
        _decode_kernel, block_s=bs, n_blocks=n_blocks, window=window,
        seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki, si: (0,)),
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda bi, ki, si: (bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),      # running max m
            pltpu.VMEM((g,), jnp.float32),      # running denominator l
            pltpu.VMEM((g, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(jnp.asarray(cache_len, jnp.int32).reshape(1), q, k, v)
    return out
