"""jit'd wrapper exposing the kernel in the model's (b, 1, h, hd) layout."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.runtime import default_interpret
from repro.kernels.decode_attn.decode_attn import decode_attn
from repro.kernels.decode_attn.paged import (paged_decode_attn,
                                             paged_decode_attn_ref)
from repro.kernels.decode_attn.ref import decode_attn_ref


def _interpret() -> bool:
    return default_interpret()


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: Optional[int] = None) -> jax.Array:
    """Model-layout entry: q (b, 1, h, hd), caches (b, S, kv, hd)."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    out = decode_attn(qg, k_cache, v_cache, jnp.asarray(cache_len, jnp.int32),
                      window=window, interpret=_interpret())
    return out.reshape(b, 1, h, hd)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           block_table: jax.Array, index: jax.Array, *,
                           ring: Optional[int] = None,
                           window: Optional[int] = None) -> jax.Array:
    """Model-layout entry for the paged cache: q (b, 1, h, hd), pools
    (n_pool, block_size, kv, hd), block_table (b, n_blk), index (b,).

    Off-TPU this routes to the pure-jnp reference rather than interpret-mode
    Pallas: the serving engine traces this inside a jitted ``lax.while_loop``
    decode body, where the interpreter's per-grid-step Python overhead would
    dominate; the reference lowers to plain XLA gather + masked softmax."""
    b, _, h, hd = q.shape
    kv = k_pool.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    if _interpret():
        out = paged_decode_attn_ref(qg, k_pool, v_pool, block_table, index,
                                    ring=ring, window=window)
    else:
        out = paged_decode_attn(qg, k_pool, v_pool, block_table, index,
                                ring=ring, window=window, interpret=False)
    return out.reshape(b, 1, h, hd)


__all__ = ["decode_attention", "decode_attn", "decode_attn_ref",
           "paged_decode_attention", "paged_decode_attn",
           "paged_decode_attn_ref"]
