"""jit'd wrapper exposing the kernel in the model's (b, 1, h, hd) layout."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.decode_attn import decode_attn
from repro.kernels.decode_attn.ref import decode_attn_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: Optional[int] = None) -> jax.Array:
    """Model-layout entry: q (b, 1, h, hd), caches (b, S, kv, hd)."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    out = decode_attn(qg, k_cache, v_cache, jnp.asarray(cache_len, jnp.int32),
                      window=window, interpret=_interpret())
    return out.reshape(b, 1, h, hd)


__all__ = ["decode_attention", "decode_attn", "decode_attn_ref"]
