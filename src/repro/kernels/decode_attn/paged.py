"""Pallas TPU kernel: paged single-token GQA flash-decode attention.

The serving engine's KV store is a pool of fixed-size blocks
(``serve/paged_cache.py``): each layer owns ``(n_blocks + 1, block_size,
kv, hd)`` K/V pools (the ``+1`` row is the trash block inactive slots write
into) and each request maps its logical cache onto physical blocks through a
``(b, n_blk)`` int32 block table. This kernel is the paged-aware variant of
``decode_attn``: the grid stays ``(b, kv, n_blk)``, but the K/V BlockSpec
index map reads the block table — delivered ahead of the kernel body via
``PrefetchScalarGridSpec`` scalar prefetch — so each grid step streams one
*physical* block straight from the pool, no gather materialization.

Layouts (per layer, static):

* linear (full-attention layers): logical slot ``s`` holds token position
  ``s``; block ``j`` covers positions ``[j*bs, (j+1)*bs)``; valid iff
  ``s <= index`` (``index`` = position of the newest token, per request).
* ring (sliding-window layers): capacity ``R = n_blk * bs`` slots, token
  position ``p`` lives at slot ``p % R``. The age of slot ``s`` is
  ``(index - s) mod R`` and the slot is valid iff
  ``age < min(window, index + 1)`` — this masks both tokens older than the
  window and ring slots not yet written, and degenerates to the monolithic
  ring-cache semantics when ``R == window``.

Per-request cache lengths (continuous batching: every running request sits
at a different ``index``) ride in as a second scalar-prefetch operand.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import default_interpret

NEG_INF = -1e30


def _paged_decode_kernel(table_ref, index_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         block_s: int, n_blocks: int, ring: Optional[int],
                         window: Optional[int]):
    bi = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)        # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)        # (bs, hd)
    hd = q.shape[-1]

    index = index_ref[bi]
    slot = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (block_s,), 0)
    if ring is None:
        valid = slot <= index
    else:
        age = jnp.mod(index - slot, ring)
        lim = jnp.minimum(jnp.int32(ring if window is None else window),
                          index + 1)
        valid = age < lim

    s = jnp.einsum("gd,td->gt", q * hd ** -0.5, k)          # (g, bs)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.maximum(m_new, -0.5e30)
    p = jnp.exp(s - m_safe[:, None])
    corr = jnp.exp(m_prev - m_safe)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jnp.einsum("gt,td->gd", p, v)

    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("ring", "window", "interpret"))
def paged_decode_attn(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      block_table: jax.Array, index: jax.Array, *,
                      ring: Optional[int] = None,
                      window: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """q: (b, kv, g, hd); pools: (n_pool, bs, kv, hd);
    block_table: (b, n_blk) int32 physical block per logical block;
    index: (b,) int32 position of each request's newest token."""
    interpret = default_interpret() if interpret is None else interpret
    b, kv, g, hd = q.shape
    bs = k_pool.shape[1]
    n_blk = block_table.shape[1]
    if ring is not None and ring != n_blk * bs:
        raise ValueError(
            f"ring capacity {ring} != table blocks x block_size "
            f"({n_blk}x{bs})")

    kernel = functools.partial(
        _paged_decode_kernel, block_s=bs, n_blocks=n_blk, ring=ring,
        window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, n_blk),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, ki, ji, tab, idx: (bi, ki, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda bi, ki, ji, tab, idx: (tab[bi, ji], 0, ki, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda bi, ki, ji, tab, idx: (tab[bi, ji], 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, ji, tab, idx: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),      # running max m
            pltpu.VMEM((g,), jnp.float32),      # running denominator l
            pltpu.VMEM((g, hd), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(index, jnp.int32),
      q, k_pool, v_pool)
    return out


def paged_decode_attn_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                          block_table: jax.Array, index: jax.Array, *,
                          ring: Optional[int] = None,
                          window: Optional[int] = None) -> jax.Array:
    """Pure-jnp reference: gather the table, run masked softmax attention.

    Same signature and masking semantics as the kernel; this is also the
    path the serving engine's jitted while_loop uses off-TPU (mirroring the
    monolithic decode, whose jnp reference serves on CPU)."""
    b, kv, g, hd = q.shape
    bs = k_pool.shape[1]
    n_blk = block_table.shape[1]
    S = n_blk * bs
    k = k_pool[block_table].reshape(b, S, kv, hd)
    v = v_pool[block_table].reshape(b, S, kv, hd)
    qg = (q * hd ** -0.5).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32))
    slot = jnp.arange(S, dtype=jnp.int32)
    idx = jnp.asarray(index, jnp.int32)[:, None]
    if ring is None:
        valid = slot[None, :] <= idx
    else:
        if ring != S:
            raise ValueError(
                f"ring capacity {ring} != table blocks x block_size "
                f"({n_blk}x{bs})")
        age = jnp.mod(idx - slot[None, :], ring)
        lim = jnp.minimum(jnp.int32(ring if window is None else window),
                          idx + 1)
        valid = age < lim
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
