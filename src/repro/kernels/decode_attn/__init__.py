from repro.kernels.decode_attn.decode_attn import decode_attn
from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.decode_attn.ref import decode_attn_ref

__all__ = ["decode_attn", "decode_attention", "decode_attn_ref"]
