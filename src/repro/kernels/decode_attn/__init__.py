from repro.kernels.decode_attn.decode_attn import decode_attn
from repro.kernels.decode_attn.ops import (decode_attention,
                                           paged_decode_attention)
from repro.kernels.decode_attn.paged import (paged_decode_attn,
                                             paged_decode_attn_ref)
from repro.kernels.decode_attn.ref import decode_attn_ref

__all__ = ["decode_attn", "decode_attention", "decode_attn_ref",
           "paged_decode_attention", "paged_decode_attn",
           "paged_decode_attn_ref"]
