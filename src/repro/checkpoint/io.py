"""Checkpointing (offline container — no orbax).

Two on-disk formats over the same flat-key pytree encoding:

* **flat** (legacy): one ``arrays.npz`` + ``manifest.json`` per
  checkpoint — ``save``/``restore``.
* **sharded** (elastic membership, ``save_sharded``/``restore_sharded``):
  per-host shard files ``shard_00000.npz`` ... plus a topology-aware
  ``manifest.json`` recording, beside every key's shape/dtype/shard
  assignment, the run topology — worker count ``p``, round, policy spec,
  comm-state structure — so a restore can detect a membership mismatch
  and route through the resize machinery (core/membership.py) to resume
  under a DIFFERENT ``p``. Keys are deterministically bin-packed across
  shards by byte size; on a multi-host fleet each host writes (and reads
  back) only its own shard file, so checkpoint bandwidth scales with the
  fleet. The manifest is written atomically (tmp + rename): a preempted
  save leaves the previous checkpoint readable, never a torn manifest.

``AsyncCheckpointer`` moves the host-side serialization off the critical
path: ``save`` snapshots the tree with a cheap on-device copy (safe
against donated buffers) and a daemon thread performs the device-to-host
gather and shard writes while the next rounds — including the rs_ag
overlap seam's collective phases — run on the devices, so a periodic
checkpoint costs no extra round time.

Restores verify structure AND dtype: the manifest dtype is checked
against both the stored array (corruption — always fatal) and the
``like`` leaf (mismatched resume target — fatal unless the explicit
``allow_cast=True`` escape hatch is passed).
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

SEP = "//"

SHARDED_FORMAT = "wasgd-sharded-v1"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{SEP}#{i}" if prefix else f"#{i}"))
    elif hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            v = getattr(tree, k)
            out.update(_flatten(v, f"{prefix}{SEP}@{k}" if prefix else f"@{k}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _check_structure(like_keys, stored_keys):
    """Structure mismatch split into the two distinct failure directions:
    keys the target expects but the checkpoint lacks, and keys the
    checkpoint holds that the target has no slot for — the symmetric
    difference reported as one "missing" list hid which side was wrong."""
    missing = sorted(set(like_keys) - set(stored_keys))
    unexpected = sorted(set(stored_keys) - set(like_keys))
    if missing or unexpected:
        parts = []
        if missing:
            parts.append(f"missing from checkpoint: {missing[:8]}"
                         + (f" (+{len(missing) - 8} more)"
                            if len(missing) > 8 else ""))
        if unexpected:
            parts.append(f"unexpected in checkpoint: {unexpected[:8]}"
                         + (f" (+{len(unexpected) - 8} more)"
                            if len(unexpected) > 8 else ""))
        raise ValueError("checkpoint structure mismatch: " + "; ".join(parts))


def _check_leaf(key: str, arr: np.ndarray, entry: Dict, like_leaf,
                allow_cast: bool):
    """Shape + dtype verification for one restored leaf.

    The manifest is the contract: a stored array that disagrees with its
    own manifest entry is corruption and always fatal; a manifest dtype
    that disagrees with the restore target ``like`` is a mismatched resume
    (e.g. an f32 checkpoint into a bf16 state) and fatal unless the caller
    explicitly passes ``allow_cast=True`` — the silent-cast behaviour this
    replaces converted every leaf to ``like``'s dtype without a word.
    """
    if tuple(arr.shape) != tuple(np.shape(like_leaf)):
        raise ValueError(f"shape mismatch for {key}: "
                         f"{arr.shape} vs {np.shape(like_leaf)}")
    man_dtype = entry.get("dtype")
    if man_dtype is not None and str(arr.dtype) != man_dtype:
        raise ValueError(
            f"checkpoint corruption for {key}: stored dtype {arr.dtype} "
            f"disagrees with its manifest entry {man_dtype}")
    like_dtype = str(jnp.asarray(like_leaf).dtype)
    if man_dtype is not None and man_dtype != like_dtype and not allow_cast:
        raise ValueError(
            f"dtype mismatch for {key}: checkpoint holds {man_dtype}, "
            f"restore target expects {like_dtype}; pass allow_cast=True to "
            f"cast explicitly")
    return jnp.asarray(arr, dtype=like_dtype if allow_cast else man_dtype)


def _restore_flat(data_of_key, manifest: Dict, like: Any, allow_cast: bool):
    """Rebuild ``like``'s structure leaf-by-leaf along the SAME traversal
    ``_flatten`` uses to derive keys — pairing flat keys with
    ``jax.tree.flatten`` leaves (as the code this replaces did) silently
    mis-pairs once a dict's insertion order differs from jax's sorted-key
    flatten order."""
    _check_structure(_flatten(like), manifest["keys"])

    def build(sub, prefix=""):
        if isinstance(sub, dict):
            return {k: build(v, f"{prefix}{SEP}{k}" if prefix else str(k))
                    for k, v in sub.items()}
        if hasattr(sub, "_fields"):         # NamedTuple
            return type(sub)(*(
                build(getattr(sub, k),
                      f"{prefix}{SEP}@{k}" if prefix else f"@{k}")
                for k in sub._fields))
        if isinstance(sub, (tuple, list)):
            return type(sub)(
                build(v, f"{prefix}{SEP}#{i}" if prefix else f"#{i}")
                for i, v in enumerate(sub))
        return _check_leaf(prefix, data_of_key(prefix),
                           manifest["keys"][prefix], sub, allow_cast)

    return build(like)


# ---------------------------------------------------------------------------
# Flat (legacy) format
# ---------------------------------------------------------------------------

def save(path: str, tree: Any, meta: Dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in flat.items()},
        "meta": meta or {},
    }
    _write_manifest(path, manifest)


def restore(path: str, like: Any, allow_cast: bool = False
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shape/dtype verified —
    see ``_check_leaf``; ``allow_cast=True`` is the explicit escape hatch
    for dtype-converting restores). A sharded checkpoint at ``path`` is
    detected from its manifest and delegated to ``restore_sharded``."""
    manifest = _read_manifest(path)
    if manifest.get("format") == SHARDED_FORMAT:
        return restore_sharded(path, like, allow_cast=allow_cast)
    data = np.load(os.path.join(path, "arrays.npz"))
    tree = _restore_flat(lambda k: data[k], manifest, like, allow_cast)
    return tree, manifest["meta"]


# ---------------------------------------------------------------------------
# Sharded format
# ---------------------------------------------------------------------------

def _write_manifest(path: str, manifest: Dict):
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(path, "manifest.json"))


def _read_manifest(path: str) -> Dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def _shard_file(s: int) -> str:
    return f"shard_{s:05d}.npz"


def _assign_shards(flat: Dict[str, np.ndarray], n_shards: int
                   ) -> List[List[str]]:
    """Deterministic byte-balanced bin-packing: keys in descending size
    (ties by key) each go to the currently lightest shard (ties by index)
    — every host computes the same assignment without coordination."""
    bins: List[List[str]] = [[] for _ in range(n_shards)]
    loads = [0] * n_shards
    for k in sorted(flat, key=lambda k: (-flat[k].nbytes, k)):
        s = min(range(n_shards), key=lambda i: (loads[i], i))
        bins[s].append(k)
        loads[s] += flat[k].nbytes
    return bins


def save_sharded(path: str, tree: Any, meta: Dict | None = None,
                 topology: Dict | None = None, n_shards: int | None = None,
                 process_index: int | None = None):
    """Write a sharded checkpoint: ``n_shards`` npz shard files plus the
    topology-aware manifest.

    ``n_shards`` defaults to the process count (one shard per host); pass
    more to bound file sizes on a single host. On a multi-host fleet every
    process computes the same deterministic assignment and
    ``process_index`` (defaults to ``jax.process_index()``) writes only
    its own shard — the manifest comes from process 0. ``topology`` is the
    membership record (``{"p", "round", "policy", "rule", "comm_state"}``)
    that lets ``restore_sharded`` / the Trainer resume under a different
    worker count by routing through core/membership.py.
    """
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    if n_shards is None:
        n_shards = max(1, jax.process_count())
    if process_index is None:
        process_index = jax.process_index()
    bins = _assign_shards(flat, n_shards)
    per_process = max(1, n_shards // max(1, jax.process_count()))
    for s, keys in enumerate(bins):
        if jax.process_count() > 1 and s // per_process != process_index:
            continue                       # another host owns this shard
        np.savez(os.path.join(path, _shard_file(s)),
                 **{k: flat[k] for k in keys})
    if process_index == 0:
        manifest = {
            "format": SHARDED_FORMAT,
            "n_shards": n_shards,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                         "shard": s}
                     for s, keys in enumerate(bins)
                     for k, v in ((k, flat[k]) for k in keys)},
            "topology": topology or {},
            "meta": meta or {},
        }
        _write_manifest(path, manifest)


def restore_sharded(path: str, like: Any, allow_cast: bool = False
                    ) -> Tuple[Any, Dict]:
    """Restore a sharded checkpoint into the structure of ``like``.

    Structure and dtype are verified (``_check_structure``/``_check_leaf``).
    ``like`` must already be shaped for the checkpoint's topology — to
    resume under a different worker count, read ``saved_topology(path)``,
    build the ``like`` at the saved ``p``, restore, then resize through
    core/membership.py (``Trainer.resume_from`` does exactly this).
    """
    manifest = _read_manifest(path)
    if manifest.get("format") != SHARDED_FORMAT:
        raise ValueError(
            f"{path} is not a sharded checkpoint "
            f"(format={manifest.get('format')!r}); use restore()")
    shards: Dict[int, Any] = {}

    def data_of_key(k):
        s = manifest["keys"][k]["shard"]
        if s not in shards:
            shards[s] = np.load(os.path.join(path, _shard_file(s)))
        return shards[s][k]

    tree = _restore_flat(data_of_key, manifest, like, allow_cast)
    return tree, manifest["meta"]


def saved_topology(path: str) -> Dict:
    """The topology block of a checkpoint's manifest (``{}`` for legacy
    flat checkpoints) plus its meta — read without touching any shard, so
    a resume can decide on the resize route before loading bytes."""
    manifest = _read_manifest(path)
    return {"format": manifest.get("format", "flat"),
            "n_shards": manifest.get("n_shards", 1),
            "topology": manifest.get("topology", {}),
            "meta": manifest.get("meta", {})}


# ---------------------------------------------------------------------------
# Async save: serialization rides the next round's device time
# ---------------------------------------------------------------------------

class AsyncCheckpointer:
    """Background-thread sharded saver.

    ``save`` is cheap on the caller's thread: it snapshots every leaf with
    an on-device copy — dispatch-only, and the copy is ordered before any
    later donation of the source buffers (the train step donates its
    state), so the snapshot is consistent even though the next round
    starts immediately — then enqueues the write. The daemon thread
    performs the device-to-host gather (blocking only itself) and the
    ``save_sharded`` shard writes while subsequent rounds run on the
    devices; with the rs_ag schedule the gather overlaps the same
    phase-gap seam the pipelined round uses, so a periodic checkpoint
    costs no extra round time on the training critical path.

    Worker-thread failures are held and re-raised on the next ``save`` or
    ``wait`` — a checkpoint that cannot be written must not be discovered
    at restore time.
    """

    def __init__(self, depth: int = 2, telemetry=None):
        """``telemetry`` (a ``repro.obs`` sink, optional) receives one
        ``CheckpointSave`` event per completed save — duration measured on
        the writer thread (gather + shard writes), i.e. the work that
        rides the next rounds' device time. The attribute is only ever
        written from caller threads; the worker reads it."""
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._exc: Optional[BaseException] = None
        self.telemetry = telemetry
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="ckpt-writer")
        self._thread.start()

    def _worker(self):
        while True:
            job = self._q.get()
            try:
                if job is None:
                    return
                path, snap, meta, topology, n_shards = job
                t0 = time.perf_counter()
                host = jax.tree.map(np.asarray, snap)
                save_sharded(path, host, meta=meta, topology=topology,
                             n_shards=n_shards)
                tele = self.telemetry
                if tele is not None and getattr(tele, "enabled", False):
                    from repro.obs.events import CheckpointSave
                    tele.emit(CheckpointSave(
                        path=path, round=int((meta or {}).get("round", -1)),
                        duration_s=time.perf_counter() - t0,
                        nbytes=sum(np.asarray(x).nbytes
                                   for x in jax.tree.leaves(host))))
            except BaseException as e:     # surface on the trainer thread
                # reprolint: allow=THR001 -- single-ref write is atomic under
                # the GIL; held until _raise_pending re-raises on the caller
                self._exc = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise RuntimeError("async checkpoint save failed") from exc

    def save(self, path: str, tree: Any, meta: Dict | None = None,
             topology: Dict | None = None, n_shards: int | None = None):
        self._raise_pending()
        snap = jax.tree.map(
            lambda x: jnp.array(x, copy=True) if isinstance(x, jax.Array)
            else np.asarray(x), tree)
        self._q.put((path, snap, meta, topology, n_shards))

    def wait(self):
        """Block until every enqueued save has hit disk; re-raise failures."""
        self._q.join()
        self._raise_pending()

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=5.0)
