"""Checkpointing: flat-key npz payload + JSON manifest (offline container —
no orbax). Saves/restores arbitrary pytrees of arrays (params, optimizer
state, worker-stacked or not) with dtype/shape verification on restore.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

SEP = "//"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{SEP}#{i}" if prefix else f"#{i}"))
    elif hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            v = getattr(tree, k)
            out.update(_flatten(v, f"{prefix}{SEP}@{k}" if prefix else f"@{k}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def save(path: str, tree: Any, meta: Dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in flat.items()},
        "meta": meta or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like)
    if set(flat_like) != set(data.files):
        missing = set(flat_like) ^ set(data.files)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:8]}")
    leaves, treedef = jax.tree.flatten(like)
    flat_keys = list(_flatten(like).keys())
    assert len(flat_keys) == len(leaves)
    restored = []
    for k, leaf in zip(flat_keys, leaves):
        arr = data[k]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        restored.append(jnp.asarray(arr, dtype=jnp.asarray(leaf).dtype))
    return jax.tree.unflatten(treedef, restored), manifest["meta"]
