from repro.checkpoint.io import (
    AsyncCheckpointer,
    restore,
    restore_sharded,
    save,
    save_sharded,
    saved_topology,
)

__all__ = ["AsyncCheckpointer", "restore", "restore_sharded", "save",
           "save_sharded", "saved_topology"]
