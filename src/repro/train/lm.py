"""LM glue: wire a ModelConfig into the WASGD round builder, and produce the
abstract (ShapeDtypeStruct) state + logical-axes trees the multi-pod dry-run
lowers against — full-size parameters are never allocated.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.weights import policy_from_config
from repro.models import abstract_params, loss_fn as lm_loss
from repro.models.param import add_worker_axis, is_expert_path
from repro.optim import Optimizer, make_optimizer
from repro.train.state import TrainState


def make_lm_loss(cfg: ModelConfig):
    def loss(params, batch):
        return lm_loss(cfg, params, batch)
    return loss


def opt_axes_like(opt_name: str, opt_shapes, param_axes):
    """Logical axes for the optimizer state (mirrors params where stateful)."""
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return param_axes
    if opt_name == "adamw":
        return type(opt_shapes)(mu=param_axes, nu=param_axes, count=())
    raise ValueError(opt_name)


def abstract_lm_state(cfg: ModelConfig, tcfg: TrainConfig, n_workers: int
                      ) -> Tuple[TrainState, TrainState, Optimizer]:
    """(state ShapeDtypeStructs, state logical-axes, optimizer)."""
    shapes, axes = abstract_params(cfg)
    skip = is_expert_path if (cfg.moe is not None
                              and cfg.expert_sharding == "ep_data") else None
    shapes, axes = add_worker_axis(shapes, axes, n_workers, skip=skip)
    optimizer = make_optimizer(tcfg.optimizer, tcfg.learning_rate,
                               tcfg.momentum, tcfg.weight_decay)
    opt_shapes = jax.eval_shape(optimizer.init, shapes)
    o_axes = opt_axes_like(optimizer.name, opt_shapes, axes)

    # comm_state mirrors train/step.py:init_comm_state: the (w,) Alg. 4
    # activity mask for on-device async rounds, the worker-assessment
    # policy's state when it is stateful (riding alongside the mask as
    # {"active", "policy"} in the async case), () otherwise.
    pol = policy_from_config(tcfg.wasgd)
    pstate = pol.init_state(n_workers)         # tiny concrete leaves

    def _sds(x):
        return jax.ShapeDtypeStruct(jnp.shape(x), x.dtype)

    def _pax(x):
        shp = jnp.shape(x)
        return tuple("worker" if (i == 0 and shp[0] == n_workers) else None
                     for i in range(len(shp)))

    pol_shapes = jax.tree.map(_sds, pstate)
    pol_axes = jax.tree.map(_pax, pstate)
    on_device_async = tcfg.wasgd.async_mode == "on_device"
    if on_device_async:
        mask_shape = jax.ShapeDtypeStruct((n_workers,), jnp.bool_)
        if pol.stateful:
            comm_shapes = {"active": mask_shape, "policy": pol_shapes}
            comm_axes = {"active": ("worker",), "policy": pol_axes}
        else:
            comm_shapes, comm_axes = mask_shape, ("worker",)
    else:
        comm_shapes, comm_axes = pol_shapes, pol_axes
    state_shapes = TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=shapes,
        opt_state=opt_shapes,
        energy=jax.ShapeDtypeStruct((n_workers,), jnp.float32),
        comm_state=comm_shapes,
    )
    state_axes = TrainState(
        step=(),
        params=axes,
        opt_state=o_axes,
        energy=("worker",),
        comm_state=comm_axes,
    )
    return state_shapes, state_axes, optimizer


def lm_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int
                   ) -> Tuple[Dict, Dict]:
    """(batch ShapeDtypeStructs, batch logical-axes) for one training round."""
    if cfg.n_codebooks > 0:
        tok = jax.ShapeDtypeStruct((global_batch, seq_len, cfg.n_codebooks),
                                   jnp.int32)
        tok_axes = ("worker", None, None)
    else:
        tok = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
        tok_axes = ("worker", None)
    shapes = {"tokens": tok, "labels": tok}
    axes = {"tokens": tok_axes, "labels": tok_axes}
    if cfg.n_media_tokens > 0:
        shapes["media"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.n_media_tokens, cfg.d_model), jnp.bfloat16)
        axes["media"] = ("worker", None, None)
    return shapes, axes
