"""LM glue: wire a ModelConfig into the WASGD round builder, and produce the
abstract (ShapeDtypeStruct) state + logical-axes trees the multi-pod dry-run
lowers against — full-size parameters are never allocated.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import abstract_params, loss_fn as lm_loss
from repro.models.param import add_worker_axis, is_expert_path
from repro.optim import Optimizer, make_optimizer
from repro.train.state import TrainState


def make_lm_loss(cfg: ModelConfig):
    def loss(params, batch):
        return lm_loss(cfg, params, batch)
    return loss


def opt_axes_like(opt_name: str, opt_shapes, param_axes):
    """Logical axes for the optimizer state (mirrors params where stateful)."""
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return param_axes
    if opt_name == "adamw":
        return type(opt_shapes)(mu=param_axes, nu=param_axes, count=())
    raise ValueError(opt_name)


def abstract_lm_state(cfg: ModelConfig, tcfg: TrainConfig, n_workers: int
                      ) -> Tuple[TrainState, TrainState, Optimizer]:
    """(state ShapeDtypeStructs, state logical-axes, optimizer)."""
    shapes, axes = abstract_params(cfg)
    skip = is_expert_path if (cfg.moe is not None
                              and cfg.expert_sharding == "ep_data") else None
    shapes, axes = add_worker_axis(shapes, axes, n_workers, skip=skip)
    optimizer = make_optimizer(tcfg.optimizer, tcfg.learning_rate,
                               tcfg.momentum, tcfg.weight_decay)
    opt_shapes = jax.eval_shape(optimizer.init, shapes)
    o_axes = opt_axes_like(optimizer.name, opt_shapes, axes)

    # async on-device rounds carry the (w,) Alg. 4 activity mask in
    # comm_state (train/step.py:async_wasgd_rule); sync rounds carry ().
    on_device_async = tcfg.wasgd.async_mode == "on_device"
    comm_shapes = (jax.ShapeDtypeStruct((n_workers,), jnp.bool_)
                   if on_device_async else ())
    comm_axes = ("worker",) if on_device_async else ()
    state_shapes = TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=shapes,
        opt_state=opt_shapes,
        energy=jax.ShapeDtypeStruct((n_workers,), jnp.float32),
        comm_state=comm_shapes,
    )
    state_axes = TrainState(
        step=(),
        params=axes,
        opt_state=o_axes,
        energy=("worker",),
        comm_state=comm_axes,
    )
    return state_shapes, state_axes, optimizer


def lm_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int
                   ) -> Tuple[Dict, Dict]:
    """(batch ShapeDtypeStructs, batch logical-axes) for one training round."""
    if cfg.n_codebooks > 0:
        tok = jax.ShapeDtypeStruct((global_batch, seq_len, cfg.n_codebooks),
                                   jnp.int32)
        tok_axes = ("worker", None, None)
    else:
        tok = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
        tok_axes = ("worker", None)
    shapes = {"tokens": tok, "labels": tok}
    axes = {"tokens": tok_axes, "labels": tok_axes}
    if cfg.n_media_tokens > 0:
        shapes["media"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.n_media_tokens, cfg.d_model), jnp.bfloat16)
        axes["media"] = ("worker", None, None)
    return shapes, axes
