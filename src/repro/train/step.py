"""One compiled WASGD round: ``tau`` per-worker local SGD steps (lax.scan,
zero cross-worker collectives) followed by one communication.

The same builder hosts the paper's baselines through pluggable communication
rules, so benchmark comparisons isolate exactly the aggregation rule:

    rule(params, axes, h, comm_state) -> (params, comm_state, theta, metrics)

Shape contract: every batch leaf has leading dim B = tau * p * b_local,
sharded over the worker mesh axes; it is reshaped worker-major to
(p, tau, b_local, ...) so the worker dim lands exactly on its shards, then
scanned over tau.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import dataclasses

from repro.configs.base import WASGDConfig
from repro.core import aggregate as agg
from repro.core import async_device
from repro.core import backends
from repro.core import baselines as bl
from repro.core.energy import record_mask
from repro.core.order import judge_scores
from repro.core.weights import (compute_theta, masked_compute_theta, omega,
                                theta_entropy)
from repro.optim import Optimizer
from repro.train.state import TrainState

LossFn = Callable[[Dict, Dict], Tuple[jax.Array, Dict]]


# ---------------------------------------------------------------------------
# Communication rules
# ---------------------------------------------------------------------------

def wasgd_rule(wcfg: WASGDConfig, leaf_fn=None, mesh=None, overlap=None):
    """Eq. 10 communication rule, routed through the two-axis aggregation
    API (core/backends.py). The ``schedule:codec`` spec comes from
    ``wcfg.backend`` (``"auto"`` resolves per parameter tree at trace time)
    or is composed from the legacy boolean knobs; ``comm_dtype``/``n_pods``/
    ``mesh`` ride in the backend context. ``leaf_fn`` is the legacy escape
    hatch that bypasses the registry.

    ``overlap`` is an optional nullary compute thunk: its ops are placed
    between the schedule's collective phases (for ``rs_ag``, between the
    reduce-scatter and the all-gather) so independent work — the next
    round's first forward, metric reductions — can hide the second
    collective. Its result rides out in ``metrics["overlap"]`` and never
    feeds the aggregate, so params are identical with or without it."""
    if leaf_fn is None:
        # fail fast at build time, not at the first jitted step: unknown
        # backend names/specs, missing meshes, and a degenerate n_pods are
        # all config errors. "auto" is the one name resolved per tree.
        name = backends.backend_name_from_config(wcfg)
        if name != "auto":
            backend = backends.get_backend(name)
            if getattr(backend, "needs_mesh", False) and mesh is None:
                raise ValueError(
                    f"aggregation backend {backend.name!r} needs a mesh; "
                    f"pass mesh= through Trainer/build_train_step/"
                    f"wasgd_rule")
            try:
                sched = backends.resolve_spec(name)[0]
            except KeyError:
                sched = None                     # monolithic registration
            if sched == "hierarchical" and wcfg.n_pods < 2:
                raise ValueError(
                    "'hierarchical' aggregation schedule needs "
                    f"WASGDConfig.n_pods >= 2 (got {wcfg.n_pods})")

    def rule(params, axes, h, comm_state):
        if wcfg.a_schedule == "anneal":
            # beyond-paper: simulated-annealing-style temperature schedule on
            # the paper's own Boltzmann weights — start near equal weighting
            # (exploration), cool toward best-worker broadcast (exploitation).
            t = comm_state if isinstance(comm_state, jax.Array)                 else jnp.zeros((), jnp.float32)
            a_eff = wcfg.a_tilde * (1.0 + wcfg.anneal_rate * t)
            comm_state = t + 1.0
        else:
            a_eff = wcfg.a_tilde
        theta = compute_theta(h, wcfg.strategy, a_eff)
        res = backends.aggregate_from_config(
            wcfg, params, axes, theta, mesh=mesh, leaf_fn=leaf_fn,
            overlap=overlap)
        if overlap is not None:
            new_params, overlap_out = res
            return new_params, comm_state, theta, {"overlap": overlap_out}
        return res, comm_state, theta, {}
    return rule


def async_wasgd_rule(wcfg: WASGDConfig, mesh=None, overlap=None):
    """Alg. 4 (p-of-(p+b)) communication rule for ``async_mode="on_device"``.

    ``comm_state`` carries the round's ``(w,)`` boolean activity mask (the
    host loop injects a fresh mask per round — ``Trainer.run``'s
    ``straggler_schedule``); theta is masked so stragglers get exactly 0,
    and the aggregation + straggler late-join run through any composed
    ``schedule:codec`` spec (every spec honors ``ctx.active``; see
    core/async_device.py) as part of the jitted round. ``overlap`` is the
    same compute-thunk hook as ``wasgd_rule``'s.
    """
    if wcfg.a_schedule == "anneal":
        raise ValueError(
            "async_mode='on_device' uses comm_state for the activity mask; "
            "the 'anneal' a_schedule (which also rides comm_state) is not "
            "supported in the same run")
    name = backends.backend_name_from_config(wcfg)
    if name != "auto":
        name = async_device.async_backend_name(name)
        backend = backends.get_backend(name)
        if getattr(backend, "needs_mesh", False) and mesh is None:
            raise ValueError(
                f"aggregation backend {backend.name!r} needs a mesh; pass "
                f"mesh= through Trainer/build_train_step/async_wasgd_rule")

    def rule(params, axes, h, comm_state):
        active = comm_state                        # (w,) bool mask
        theta = masked_compute_theta(h, active, wcfg.a_tilde, wcfg.strategy)
        ctx = dataclasses.replace(
            backends.context_from_config(wcfg, mesh), active=active)
        nm = name
        if nm == "auto":                           # resolve per tree, traced
            nm = async_device.async_backend_name(
                backends.select_auto_spec(params, axes, mesh,
                                          n_pods=wcfg.n_pods,
                                          require_mask=True))
        metrics = {"active": active.astype(jnp.float32)}
        if overlap is not None:
            new_params, overlap_out = backends.aggregate_with(
                nm, params, axes, theta, wcfg.beta, ctx=ctx, overlap=overlap)
            metrics["overlap"] = overlap_out
        else:
            new_params = backends.aggregate_with(nm, params, axes, theta,
                                                 wcfg.beta, ctx=ctx)
        return new_params, comm_state, theta, metrics
    return rule


def spsgd_rule():
    def rule(params, axes, h, comm_state):
        theta = compute_theta(h, "equal")
        new_params = agg.weighted_aggregate(params, axes, theta, beta=1.0)
        return new_params, comm_state, theta, {}
    return rule


def easgd_rule(alpha: float):
    def rule(params, axes, h, comm_state):
        new_params, new_center = bl.easgd_communicate(params, axes,
                                                      comm_state, alpha)
        theta = compute_theta(h, "equal")
        return new_params, new_center, theta, {}
    return rule


def mwu_rule(eps: float = 0.5):
    def rule(params, axes, h, comm_state):
        new_params, new_state = bl.mwu_communicate(params, axes, comm_state,
                                                   h, eps)
        theta = jax.nn.one_hot(jnp.argmax(new_state.log_w), h.shape[0],
                               dtype=jnp.float32)
        return new_params, new_state, theta, {}
    return rule


def no_comm_rule():
    """beta = 0 / sequential limit: workers never talk."""
    def rule(params, axes, h, comm_state):
        theta = compute_theta(h, "equal")
        return params, comm_state, theta, {}
    return rule


# ---------------------------------------------------------------------------
# Round builder
# ---------------------------------------------------------------------------

def build_train_step(loss_fn: LossFn, optimizer: Optimizer, axes: Dict,
                     wcfg: WASGDConfig, n_workers: int,
                     rule: Optional[Callable] = None,
                     donate: bool = True, mesh=None,
                     overlap: Optional[Callable] = None) -> Callable:
    """Build ``train_step(state, batch) -> (state, metrics)`` for one round.

    ``mesh`` reaches the aggregation-backend context when the default
    ``wasgd_rule`` is built here (required by the shard_map/rs_ag
    schedules). ``wcfg.async_mode="on_device"`` swaps in the Alg. 4 masked
    rule (``async_wasgd_rule``): the round's straggler mask rides in
    ``state.comm_state``. ``overlap`` (a nullary compute thunk returning an
    array) is threaded into the default rule so its ops straddle the
    schedule's collective phases — with ``rs_ag`` it lands between the
    reduce-scatter and the all-gather; the result comes back in
    ``metrics["overlap"]`` and the params are identical either way.
    """
    if rule is None:
        rule = (async_wasgd_rule(wcfg, mesh=mesh, overlap=overlap)
                if wcfg.async_mode == "on_device"
                else wasgd_rule(wcfg, mesh=mesh, overlap=overlap))
    in_axes_params = agg.worker_in_axes(axes)
    tau = wcfg.tau
    mask = record_mask(tau, wcfg.m_estimate, wcfg.record_chunks)

    def per_worker_losses(params, mb):
        def one(p, b):
            loss, _ = loss_fn(p, b)
            return loss
        return jax.vmap(one, in_axes=(in_axes_params, 0))(params, mb)

    def scan_loss(params, mb):
        losses = per_worker_losses(params, mb)
        return losses.mean(), losses

    grad_fn = jax.value_and_grad(scan_loss, has_aux=True)

    def rescale(grads):
        # mean over workers -> per-worker gradient for worker leaves;
        # expert (shared) leaves keep the mean = synchronous DP average.
        return agg.map_worker_leaves(lambda g: g * n_workers, grads, axes)

    def reshape_batch(batch):
        def r(x):
            b = x.shape[0]
            assert b % (tau * n_workers) == 0, (
                f"batch {b} not divisible by tau*p = {tau}*{n_workers}")
            bl_ = b // (tau * n_workers)
            x = x.reshape(n_workers, tau, bl_, *x.shape[1:])
            return jnp.swapaxes(x, 0, 1)        # (tau, p, b_local, ...)
        return jax.tree.map(r, batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        mb = reshape_batch(batch)

        def inner(carry, inp):
            params, opt_state, energy = carry
            mb_t, mask_t = inp
            (loss, losses), grads = grad_fn(params, mb_t)
            grads = rescale(grads)
            params, opt_state = optimizer.update(grads, opt_state, params)
            energy = energy + jnp.where(mask_t, losses, 0.0)
            return (params, opt_state, energy), loss

        (params, opt_state, energy), round_losses = jax.lax.scan(
            inner, (state.params, state.opt_state, state.energy), (mb, mask))

        params, comm_state, theta, rule_metrics = rule(
            params, axes, energy, state.comm_state)
        scores = judge_scores(energy)

        new_state = TrainState(
            step=state.step + 1,
            params=params,
            opt_state=opt_state,
            energy=jnp.zeros_like(state.energy),
            comm_state=comm_state,
        )
        metrics = {
            "loss": round_losses.mean(),
            "loss_last": round_losses[-1],
            "h": energy,
            "theta": theta,
            "scores": scores,
            "theta_entropy": theta_entropy(theta),
            "omega": omega(theta),
            **rule_metrics,
        }
        return new_state, metrics

    return train_step


def init_comm_state(rule_name: str, params: Dict, axes: Dict, n_workers: int,
                    wcfg: Optional[WASGDConfig] = None):
    if rule_name == "easgd":
        return bl.easgd_init(params, axes)
    if rule_name in ("omwu", "mmwu", "mwu"):
        return bl.mwu_init(n_workers)
    if wcfg is not None and wcfg.async_mode == "on_device":
        # Alg. 4 activity mask; all-active until the host loop injects the
        # round's straggler set (Trainer.run straggler_schedule=).
        return jnp.ones((n_workers,), bool)
    if wcfg is not None and wcfg.a_schedule == "anneal":
        return jnp.zeros((), jnp.float32)
    return ()
