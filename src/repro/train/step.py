"""One compiled WASGD round: ``tau`` per-worker local SGD steps (lax.scan,
zero cross-worker collectives) followed by one communication.

The same builder hosts the paper's baselines through pluggable communication
rules, so benchmark comparisons isolate exactly the aggregation rule:

    rule(params, axes, h, comm_state) -> (params, comm_state, theta, metrics)

Shape contract: every batch leaf has leading dim B = tau * p * b_local,
sharded over the worker mesh axes; it is reshaped worker-major to
(p, tau, b_local, ...) so the worker dim lands exactly on its shards, then
scanned over tau.
"""
from __future__ import annotations

import time
import types
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import dataclasses

from repro.configs.base import WASGDConfig
from repro.core import aggregate as agg
from repro.core import async_device
from repro.core import backends
from repro.core import baselines as bl
from repro.core.energy import record_mask
from repro.core.order import judge_scores
from repro.core.weights import (compute_theta, omega, policy_from_config,
                                theta_entropy)
from repro.optim import Optimizer
from repro.train.state import TrainState

LossFn = Callable[[Dict, Dict], Tuple[jax.Array, Dict]]


# ---------------------------------------------------------------------------
# Communication rules
# ---------------------------------------------------------------------------

def wasgd_rule(wcfg: WASGDConfig, leaf_fn=None, mesh=None, overlap=None):
    """Eq. 10 communication rule, routed through the two-axis aggregation
    API (core/backends.py). The ``schedule:codec`` spec comes from
    ``wcfg.backend`` (``"auto"`` resolves per parameter tree at trace time)
    or is composed from the legacy boolean knobs; ``comm_dtype``/``n_pods``/
    ``mesh`` ride in the backend context. ``leaf_fn`` is the legacy escape
    hatch that bypasses the registry.

    ``overlap`` is an optional nullary compute thunk: its ops are placed
    between the schedule's collective phases (for ``rs_ag``, between the
    reduce-scatter and the all-gather) so independent work — the next
    round's first forward, metric reductions — can hide the second
    collective. The thunk may return any pytree (the pipelined round stages
    whole batches through the seam); its result rides out in
    ``metrics["overlap"]`` and never feeds the aggregate, so params are
    identical with or without it. The built rule also accepts a per-call
    ``overlap=`` keyword overriding the build-time thunk — that is how the
    pipelined train step threads a fresh seam closure (over this round's
    params and the staged next batch) into every invocation.

    theta comes from the configured worker-assessment policy
    (``wcfg.policy`` spec, or the legacy ``strategy``/``a_tilde``/
    ``a_schedule`` aliases — core/weights.py:policy_from_config); a stateful
    policy's state IS ``comm_state`` here, threaded through every round
    (the legacy ``a_schedule="anneal"`` round counter now rides as the
    anneal policy's ``{"t": ...}`` state)."""
    if leaf_fn is None:
        # fail fast at build time, not at the first jitted step: unknown
        # backend names/specs, missing meshes, and a degenerate n_pods are
        # all config errors. "auto" is the one name resolved per tree.
        name = backends.backend_name_from_config(wcfg)
        if name != "auto":
            backend = backends.get_backend(name)
            if getattr(backend, "needs_mesh", False) and mesh is None:
                raise ValueError(
                    f"aggregation backend {backend.name!r} needs a mesh; "
                    f"pass mesh= through Trainer/build_train_step/"
                    f"wasgd_rule")
            try:
                sched = backends.resolve_spec(name)[0]
            except KeyError:
                sched = None                     # monolithic registration
            if sched == "hierarchical" and wcfg.n_pods < 2:
                raise ValueError(
                    "'hierarchical' aggregation schedule needs "
                    f"WASGDConfig.n_pods >= 2 (got {wcfg.n_pods})")

    pol = policy_from_config(wcfg)

    def rule(params, axes, h, comm_state, overlap=overlap):
        theta, comm_state = pol(h, None, comm_state)
        res = backends.aggregate_from_config(
            wcfg, params, axes, theta, mesh=mesh, leaf_fn=leaf_fn,
            overlap=overlap)
        if overlap is not None:
            new_params, overlap_out = res
            return new_params, comm_state, theta, {"overlap": overlap_out}
        return res, comm_state, theta, {}
    return rule


def async_wasgd_rule(wcfg: WASGDConfig, mesh=None, overlap=None):
    """Alg. 4 (p-of-(p+b)) communication rule for ``async_mode="on_device"``.

    ``comm_state`` carries the round's ``(w,)`` boolean activity mask (the
    host loop injects a fresh mask per round — ``Trainer.run``'s
    ``straggler_schedule``); theta is masked so stragglers get exactly 0,
    and the aggregation + straggler late-join run through any composed
    ``schedule:codec`` spec (every spec honors ``ctx.active``; see
    core/async_device.py) as part of the jitted round. ``overlap`` is the
    same compute-thunk hook as ``wasgd_rule``'s (build-time default,
    per-call ``overlap=`` override).

    With a *stateful* worker-assessment policy (``wcfg.policy`` — e.g.
    ``"ema(0.9)"`` or an anneal schedule) the policy state rides
    ``comm_state`` ALONGSIDE the mask: ``comm_state = {"active": mask,
    "policy": state}``. The host loop replaces only ``"active"`` per round;
    the policy state threads through the jitted rounds untouched by the
    host. (The legacy bare-mask comm_state is kept for stateless policies,
    bitwise-compatibly.)
    """
    name = backends.backend_name_from_config(wcfg)
    if name != "auto":
        name = async_device.async_backend_name(name)
        backend = backends.get_backend(name)
        if getattr(backend, "needs_mesh", False) and mesh is None:
            raise ValueError(
                f"aggregation backend {backend.name!r} needs a mesh; pass "
                f"mesh= through Trainer/build_train_step/async_wasgd_rule")
    pol = policy_from_config(wcfg)

    def rule(params, axes, h, comm_state, overlap=overlap):
        if pol.stateful:
            active = comm_state["active"]          # (w,) bool mask
            pstate = comm_state["policy"]
        else:
            active, pstate = comm_state, ()
        theta, pstate = pol(h, active, pstate)
        ctx = dataclasses.replace(
            backends.context_from_config(wcfg, mesh), active=active)
        nm = name
        if nm == "auto":                           # resolve per tree, traced
            nm = async_device.async_backend_name(
                backends.select_auto_spec(params, axes, mesh,
                                          n_pods=wcfg.n_pods,
                                          require_mask=True))
        metrics = {"active": active.astype(jnp.float32)}
        if overlap is not None:
            new_params, overlap_out = backends.aggregate_with(
                nm, params, axes, theta, wcfg.beta, ctx=ctx, overlap=overlap)
            metrics["overlap"] = overlap_out
        else:
            new_params = backends.aggregate_with(nm, params, axes, theta,
                                                 wcfg.beta, ctx=ctx)
        out_comm = ({"active": active, "policy": pstate} if pol.stateful
                    else comm_state)
        return new_params, out_comm, theta, metrics
    return rule


def spsgd_rule():
    def rule(params, axes, h, comm_state):
        theta = compute_theta(h, "equal")
        new_params = agg.weighted_aggregate(params, axes, theta, beta=1.0)
        return new_params, comm_state, theta, {}
    return rule


def easgd_rule(alpha: float):
    def rule(params, axes, h, comm_state):
        new_params, new_center = bl.easgd_communicate(params, axes,
                                                      comm_state, alpha)
        theta = compute_theta(h, "equal")
        return new_params, new_center, theta, {}
    return rule


def mwu_rule(eps: float = 0.5):
    def rule(params, axes, h, comm_state):
        new_params, new_state = bl.mwu_communicate(params, axes, comm_state,
                                                   h, eps)
        theta = jax.nn.one_hot(jnp.argmax(new_state.log_w), h.shape[0],
                               dtype=jnp.float32)
        return new_params, new_state, theta, {}
    return rule


def no_comm_rule():
    """beta = 0 / sequential limit: workers never talk."""
    def rule(params, axes, h, comm_state):
        theta = compute_theta(h, "equal")
        return params, comm_state, theta, {}
    return rule


# ---------------------------------------------------------------------------
# Round builder
# ---------------------------------------------------------------------------

PIPELINE_MODES = ("parity", "speculative")


def _round_parts(loss_fn: LossFn, optimizer: Optimizer, axes: Dict,
                 wcfg: WASGDConfig, n_workers: int) -> types.SimpleNamespace:
    """The round's shared building blocks — batch reshape, the tau-step
    local scan, per-worker losses/L2, and the state/metrics assembly —
    used by ``build_train_step``'s fused round, its pipelined variant,
    AND the phase-fenced instrumented round
    (``build_phased_train_step``). Parity between all three is
    structural: they run the same closures, not maintained-by-hand
    copies."""
    in_axes_params = agg.worker_in_axes(axes)
    tau = wcfg.tau
    mask = record_mask(tau, wcfg.m_estimate, wcfg.record_chunks)

    def per_worker_losses(params, mb):
        def one(p, b):
            loss, _ = loss_fn(p, b)
            return loss
        return jax.vmap(one, in_axes=(in_axes_params, 0))(params, mb)

    def scan_loss(params, mb):
        losses = per_worker_losses(params, mb)
        return losses.mean(), losses

    grad_fn = jax.value_and_grad(scan_loss, has_aux=True)

    def rescale(grads):
        # mean over workers -> per-worker gradient for worker leaves;
        # expert (shared) leaves keep the mean = synchronous DP average.
        return agg.map_worker_leaves(lambda g: g * n_workers, grads, axes)

    def reshape_batch(batch):
        def r(x):
            b = x.shape[0]
            assert b % (tau * n_workers) == 0, (
                f"batch {b} not divisible by tau*p = {tau}*{n_workers}")
            bl_ = b // (tau * n_workers)
            x = x.reshape(n_workers, tau, bl_, *x.shape[1:])
            return jnp.swapaxes(x, 0, 1)        # (tau, p, b_local, ...)
        return jax.tree.map(r, batch)

    def worker_l2(tree_a, tree_b=None):
        """Per-worker L2 norm over the worker-stacked leaves: (w,)."""
        total = jnp.zeros((n_workers,), jnp.float32)
        leaves_ax, treedef = jax.tree_util.tree_flatten(
            axes, is_leaf=agg._axes_is_leaf)
        la = treedef.flatten_up_to(tree_a)
        lb = treedef.flatten_up_to(tree_b) if tree_b is not None else la
        for xa, xb, ax in zip(la, lb, leaves_ax):
            if not agg.is_worker_leaf(ax):
                continue
            d = xa.astype(jnp.float32)
            if tree_b is not None:
                d = d - xb.astype(jnp.float32)
            total = total + jnp.square(d).reshape(n_workers, -1).sum(axis=1)
        return jnp.sqrt(total)

    def run_scan(state, mb, collect_gnorm=False):
        def inner(carry, inp):
            params, opt_state, energy = carry
            mb_t, mask_t = inp
            (loss, losses), grads = grad_fn(params, mb_t)
            grads = rescale(grads)
            gnorm = worker_l2(grads) if collect_gnorm else jnp.zeros(())
            params, opt_state = optimizer.update(grads, opt_state, params)
            energy = energy + jnp.where(mask_t, losses, 0.0)
            return (params, opt_state, energy), (loss, losses, gnorm)

        return jax.lax.scan(inner, (state.params, state.opt_state,
                                    state.energy), (mb, mask))

    def assemble(state, params, opt_state, comm_state, round_losses, energy,
                 theta, rule_metrics, extra=None):
        new_state = TrainState(
            step=state.step + 1,
            params=params,
            opt_state=opt_state,
            energy=jnp.zeros_like(state.energy),
            comm_state=comm_state,
        )
        metrics = {
            "loss": round_losses.mean(),
            "loss_last": round_losses[-1],
            "h": energy,
            "theta": theta,
            "scores": judge_scores(energy),
            "theta_entropy": theta_entropy(theta),
            "omega": omega(theta),
            **rule_metrics,
            **(extra or {}),
        }
        return new_state, metrics

    return types.SimpleNamespace(
        mask=mask, per_worker_losses=per_worker_losses,
        reshape_batch=reshape_batch, worker_l2=worker_l2, run_scan=run_scan,
        assemble=assemble)


def build_train_step(loss_fn: LossFn, optimizer: Optimizer, axes: Dict,
                     wcfg: WASGDConfig, n_workers: int,
                     rule: Optional[Callable] = None,
                     donate: bool = True, mesh=None,
                     overlap: Optional[Callable] = None,
                     pipeline: Optional[str] = None) -> Callable:
    """Build ``train_step(state, batch) -> (state, metrics)`` for one round.

    ``mesh`` reaches the aggregation-backend context when the default
    ``wasgd_rule`` is built here (required by the shard_map/rs_ag
    schedules). ``wcfg.async_mode="on_device"`` swaps in the Alg. 4 masked
    rule (``async_wasgd_rule``): the round's straggler mask rides in
    ``state.comm_state``. ``overlap`` (a nullary compute thunk; may return
    any pytree) is threaded into the default rule so its ops straddle the
    schedule's collective phases — with ``rs_ag`` it lands between the
    reduce-scatter and the all-gather; the result comes back in
    ``metrics["overlap"]`` and the params are identical either way.

    Pipelined rounds (``pipeline="parity" | "speculative"``)
    =======================================================

    With ``pipeline`` set the builder returns the software-pipelined round

        ``train_step(state, batch, next_first, carry)
            -> (state, metrics, carry)``

    where ``next_first`` is round ``r+1``'s first worker-major microbatch
    (leading dims ``(p, b_local)``; host-staged by
    ``data/pipeline.RoundPrefetcher``) and ``carry`` is the pipeline state
    handed from round to round (``train_step.primer(params, batch)`` builds
    round 0's). The round's seam thunk — threaded through the rule's
    per-call ``overlap=`` into the aggregation schedule's phase gap, i.e.
    between ``rs_ag``'s reduce-scatter and all-gather — performs the NEXT
    round's staged work so it hides behind the second collective:

    * batch materialization: the staged ``next_first`` pytree rides the
      seam and round ``r+1`` consumes it as its ``t = 0`` microbatch
      (prefetch correctness makes it bitwise-equal to the slice the step
      would have computed itself);
    * ``pipeline="speculative"`` additionally runs the Judge-score / energy
      bookkeeping forward for that microbatch on the PRE-aggregate local
      params.

    ``"parity"`` (the default mode of Trainer's pipelined path) produces
    params and per-round metrics bitwise-identical to the unpipelined step:
    the seam only stages values that are bitwise-equal to what the next
    round would compute, and the thunk never feeds the aggregate.

    ``"speculative"`` feeds the seam forward's stale losses into round
    ``r+1``'s ``t = 0`` energy contribution (the Judge of WASGD+ is a
    heuristic, so stale scores are admissible — paper Sec. 3.4). The
    staleness is exactly one Eq. 10 communication: the seam evaluates at
    ``x_i`` where the true round evaluates at
    ``x_i' = x_i + beta (m - x_i)`` (stragglers: ``x_i' = m``), so by the
    mean-value theorem

        ``|L_i(x_i) - L_i(x_i')| <= sup_seg ||grad L_i|| * ||x_i' - x_i||``.

    The step MEASURES both sides every round: ``metrics["spec_dev"]`` is
    the per-worker deviation ``|spec - true|`` and ``metrics["spec_bound"]``
    the endpoint surrogate ``||grad L_i(x_i')||_2 * ||x_i' - x_i||_2``
    (t = 0 gradient norm of round ``r+1`` times round ``r``'s communication
    delta); tests/test_pipeline.py holds the measured deviation to the
    stated bound, and at ``beta = 0`` the deviation is exactly zero.
    Params still never take the seam losses — only the energy/Judge
    bookkeeping does.
    """
    if pipeline is not None:
        if pipeline not in PIPELINE_MODES:
            raise ValueError(f"unknown pipeline mode {pipeline!r}; "
                             f"known: {PIPELINE_MODES}")
        if overlap is not None:
            raise ValueError(
                "pipeline= and overlap= both claim the aggregation "
                "schedule's phase-gap seam; pass one or the other")
        if rule is not None:
            import inspect
            if "overlap" not in inspect.signature(rule).parameters:
                raise ValueError(
                    "pipelined rounds thread the seam thunk through the "
                    "rule's per-call overlap= keyword; the supplied rule "
                    "does not accept one (use wasgd_rule/async_wasgd_rule, "
                    "or add an overlap= kwarg)")
    if rule is None:
        rule = (async_wasgd_rule(wcfg, mesh=mesh, overlap=overlap)
                if wcfg.async_mode == "on_device"
                else wasgd_rule(wcfg, mesh=mesh, overlap=overlap))
    speculative = pipeline == "speculative"

    # One scan body and one state/metrics assembly shared by the unpipelined,
    # pipelined, AND phase-fenced instrumented rounds — the parity guarantee
    # is structural, not a maintained-by-hand mirror of copies.
    parts = _round_parts(loss_fn, optimizer, axes, wcfg, n_workers)
    mask = parts.mask
    per_worker_losses = parts.per_worker_losses
    reshape_batch = parts.reshape_batch
    worker_l2 = parts.worker_l2
    run_scan = parts.run_scan
    assemble = parts.assemble

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        mb = reshape_batch(batch)
        (params, opt_state, energy), (round_losses, _, _) = run_scan(state,
                                                                     mb)
        params, comm_state, theta, rule_metrics = rule(
            params, axes, energy, state.comm_state)
        return assemble(state, params, opt_state, comm_state, round_losses,
                        energy, theta, rule_metrics)

    if pipeline is None:
        return train_step

    # -- the pipelined round ------------------------------------------------

    def stage_first(next_first):
        # device-side batch materialization: pin each staged leaf to the
        # dtype/layout the scan consumes, so round r+1 can take it as-is.
        return jax.tree.map(jnp.asarray, next_first)

    def pipelined_step(state: TrainState, batch: Dict, next_first: Dict,
                       carry: Dict):
        mb = reshape_batch(batch)
        # consume round r-1's seam output as this round's t=0 microbatch
        # (bitwise-equal to mb[0] by prefetch correctness).
        mb = jax.tree.map(lambda m, f: m.at[0].set(f), mb, carry["first"])
        (params, opt_state, energy), (round_losses, losses_tw, gnorms) = \
            run_scan(state, mb, collect_gnorm=speculative)

        extra = {}
        if speculative:
            # swap the t=0 energy contribution for the seam forward's stale
            # losses (computed on round r-1's pre-aggregate params); the
            # gradient path is untouched.
            true0 = losses_tw[0]
            spec = carry["spec_losses"]
            energy = energy + jnp.where(mask[0], spec - true0, 0.0)
            extra["spec_losses"] = spec
            extra["spec_dev"] = jnp.abs(spec - true0)
            extra["spec_bound"] = gnorms[0] * carry["comm_delta"]

        pre_agg = params

        def seam():
            staged = {"first": stage_first(next_first)}
            if speculative:
                staged["spec_losses"] = per_worker_losses(
                    pre_agg, staged["first"])
            return staged

        params, comm_state, theta, rule_metrics = rule(
            pre_agg, axes, energy, state.comm_state, overlap=seam)
        seam_out = rule_metrics.pop("overlap")
        carry_out = {"first": seam_out["first"]}
        if speculative:
            carry_out["spec_losses"] = seam_out["spec_losses"]
            carry_out["comm_delta"] = worker_l2(params, pre_agg)

        new_state, metrics = assemble(state, params, opt_state, comm_state,
                                      round_losses, energy, theta,
                                      rule_metrics, extra)
        return new_state, metrics, carry_out

    def primer(params: Dict, batch: Dict) -> Dict:
        """Round 0's pipeline carry: stage the round's own first microbatch
        (and, speculatively, its forward on the initial params — which ARE
        round 0's starting params, so the round-0 deviation is exactly 0)."""
        first = jax.tree.map(lambda m: m[0], reshape_batch(batch))
        carry = {"first": first}
        if speculative:
            carry["spec_losses"] = per_worker_losses(params, first)
            carry["comm_delta"] = jnp.zeros((n_workers,), jnp.float32)
        return carry

    pipelined_step.primer = primer
    pipelined_step.pipeline = pipeline
    return pipelined_step


# ---------------------------------------------------------------------------
# Phase-fenced instrumented round (obs RoundTrace)
# ---------------------------------------------------------------------------

def build_phased_train_step(loss_fn: LossFn, optimizer: Optimizer, axes: Dict,
                            wcfg: WASGDConfig, n_workers: int, mesh=None,
                            overlap: Optional[Callable] = None) -> Callable:
    """The same WASGD round as ``build_train_step`` with the default
    wasgd/async-wasgd rule, split into separately-jitted programs so the
    Trainer can attribute round wall time to phases:

        local_steps  the tau-step lax.scan (grads + optimizer + energy)
        judge        the Judge/energy -> theta worker-assessment policy
        reduce[_scatter] / all_gather
                     the aggregation schedule's reduce phase(s)
                     (prepare is fused into the first; 2-phase schedules
                     split as reduce_scatter / all_gather)
        overlap      the build-time ``overlap=`` seam thunk, if any
        finalize     the schedule's Eq. 10 finalize + state assembly

    Returns ``phased_step(state, batch) -> (state, metrics, phases)``
    where ``phases`` is ``{name: seconds}``; every program is fenced with
    ``jax.block_until_ready`` before its timer stops, so the numbers are
    device-accurate, not dispatch time. This builder exists for the
    telemetry path ONLY (``Trainer.run(telemetry=)`` with a real sink):
    it fences every phase and does not donate its inputs — the fence-free
    fused ``build_train_step`` remains the production default. Phase
    programs are jitted once per resolved spec and memoized, so a run
    retraces exactly as the fused step would.
    """
    parts = _round_parts(loss_fn, optimizer, axes, wcfg, n_workers)
    pol = policy_from_config(wcfg)
    async_mode = wcfg.async_mode == "on_device"
    stateful = pol.stateful
    beta = wcfg.beta
    ctx_base = backends.context_from_config(wcfg, mesh)
    name = backends.backend_name_from_config(wcfg)
    if name != "auto":
        if async_mode:
            name = async_device.async_backend_name(name)
        backend = backends.get_backend(name)
        if getattr(backend, "needs_mesh", False) and mesh is None:
            raise ValueError(
                f"aggregation backend {backend.name!r} needs a mesh; pass "
                f"mesh= through build_phased_train_step")

    @jax.jit
    def scan_fn(state, batch):
        mb = parts.reshape_batch(batch)
        (params, opt_state, energy), (round_losses, _, _) = parts.run_scan(
            state, mb)
        return params, opt_state, energy, round_losses

    if async_mode:
        @jax.jit
        def judge_fn(energy, active, pstate):
            return pol(energy, active, pstate)
    else:
        @jax.jit
        def judge_fn(energy, pstate):
            return pol(energy, None, pstate)

    @jax.jit
    def assemble_fn(state, params, opt_state, comm_in, round_losses, energy,
                    theta, active, pstate):
        if async_mode:
            out_comm = ({"active": active, "policy": pstate} if stateful
                        else comm_in)
            rule_metrics = {"active": active.astype(jnp.float32)}
        else:
            out_comm = pstate
            rule_metrics = {}
        return parts.assemble(state, params, opt_state, out_comm,
                              round_losses, energy, theta, rule_metrics)

    overlap_fn = jax.jit(lambda: overlap()) if overlap is not None else None
    programs: Dict[str, Any] = {}        # resolved spec -> phase programs

    def _programs_for(spec):
        cached = programs.get(spec)
        if cached is not None:
            return cached
        backend = backends.get_backend(spec)
        if not isinstance(backend, backends.ComposedBackend):
            # monolithic registration: one opaque aggregate call.
            def communicate(params, theta, active):
                ctx = dataclasses.replace(
                    ctx_base, active=active if async_mode else None)
                return backend.aggregate(params, axes, theta, beta, ctx=ctx)
            progs = ([("reduce", jax.jit(communicate))], None)
            programs[spec] = progs
            return progs
        sched = backend.schedule
        codec = backend._codec(ctx_base)
        validate = getattr(sched, "validate", None)
        if validate is not None:
            validate(jnp.zeros((n_workers,), jnp.float32), ctx_base)
        leaves_ax, treedef = jax.tree_util.tree_flatten(
            axes, is_leaf=agg._axes_is_leaf)
        idx = [i for i, ax in enumerate(leaves_ax)
               if agg.is_worker_leaf(ax)]

        def _ctxs(active):
            a = active if async_mode else None
            return {i: dataclasses.replace(ctx_base, active=a, leaf_index=i)
                    for i in idx}

        def phase0(params, theta, active):
            theta = theta.astype(jnp.float32)
            lx = treedef.flatten_up_to(params)
            c = _ctxs(active)
            states = {i: sched.prepare(lx[i], theta, codec, c[i])
                      for i in idx}
            return {i: sched.reduce_phase(0, st, theta, codec, c[i])
                    for i, st in states.items()}

        def later_phase(k):
            def f(states, theta, active):
                th = theta.astype(jnp.float32)
                c = _ctxs(active)
                return {i: sched.reduce_phase(k, st, th, codec, c[i])
                        for i, st in states.items()}
            return f

        def finalize_fn(states, params, theta, active):
            theta = theta.astype(jnp.float32)
            lx = treedef.flatten_up_to(params)
            c = _ctxs(active)
            out = list(lx)
            for i in idx:
                out[i] = sched.finalize(states[i], lx[i], theta, beta,
                                        codec, c[i])
            return jax.tree_util.tree_unflatten(treedef, out)

        if sched.n_phases == 2:
            phase_list = [("reduce_scatter", jax.jit(phase0)),
                          ("all_gather", jax.jit(later_phase(1)))]
        else:
            phase_list = [("reduce", jax.jit(phase0))]
        progs = (phase_list, jax.jit(finalize_fn))
        programs[spec] = progs
        return progs

    dummy_active = jnp.ones((n_workers,), bool)

    def phased_step(state: TrainState, batch: Dict):
        phases: Dict[str, float] = {}

        def timed(nm, thunk):
            t0 = time.perf_counter()
            out = jax.block_until_ready(thunk())
            phases[nm] = phases.get(nm, 0.0) + (time.perf_counter() - t0)
            return out

        params, opt_state, energy, round_losses = timed(
            "local_steps", lambda: scan_fn(state, batch))
        cs = state.comm_state
        if async_mode:
            active, pstate = ((cs["active"], cs["policy"]) if stateful
                              else (cs, ()))
            theta, pstate = timed(
                "judge", lambda: judge_fn(energy, active, pstate))
        else:
            active, pstate = dummy_active, cs
            theta, pstate = timed("judge", lambda: judge_fn(energy, pstate))
        spec = name
        if spec == "auto":                   # static per shapes, like the
            spec = backends.select_auto_spec(  # fused rule's trace-time pick
                params, axes, mesh, n_pods=wcfg.n_pods,
                require_mask=async_mode)
            if async_mode:
                spec = async_device.async_backend_name(spec)
        phase_list, finalize_fn = _programs_for(spec)
        pname0, pfn0 = phase_list[0]
        states = timed(pname0, lambda: pfn0(params, theta, active))
        overlap_out = None
        if overlap_fn is not None:
            overlap_out = timed("overlap", overlap_fn)
        for pname, pfn in phase_list[1:]:
            states = timed(pname,
                           lambda pfn=pfn: pfn(states, theta, active))

        def fin():
            new_params = (states if finalize_fn is None
                          else finalize_fn(states, params, theta, active))
            return assemble_fn(state, new_params, opt_state, cs,
                               round_losses, energy, theta, active, pstate)

        new_state, metrics = timed("finalize", fin)
        if overlap_out is not None:
            metrics = {**metrics, "overlap": overlap_out}
        return new_state, metrics, phases

    return phased_step


def init_comm_state(rule_name: str, params: Dict, axes: Dict, n_workers: int,
                    wcfg: Optional[WASGDConfig] = None, prev=None):
    """Build (or, given ``prev=``, re-shard) a rule's communication state.

    ``prev`` threads membership through: at a ``WorkerSet`` resize the
    Trainer passes the old round's comm state and gets it re-sharded to
    ``n_workers`` workers — surviving slots keep their state, newcomers
    re-init from the fleet (core/membership.resize_comm_state) — instead of
    a cold ``init_state`` that would forget the policy's learned assessment.
    Rules whose comm state has a center/master variable (easgd, mwu) have no
    elastic re-shard and reject ``prev``.
    """
    if prev is not None:
        from repro.core.membership import resize_comm_state
        if rule_name not in ("wasgd", "wasgd+"):
            raise ValueError(
                f"rule {rule_name!r} has no elastic comm-state re-shard")
        pol = (policy_from_config(wcfg)
               if wcfg is not None and policy_from_config(wcfg).stateful
               else None)
        return resize_comm_state(prev, n_workers, policy=pol)
    if rule_name == "easgd":
        return bl.easgd_init(params, axes)
    if rule_name in ("omwu", "mmwu", "mwu"):
        return bl.mwu_init(n_workers)
    if wcfg is None or rule_name not in ("wasgd", "wasgd+"):
        return ()
    pol = policy_from_config(wcfg)
    pstate = pol.init_state(n_workers)
    if wcfg.async_mode == "on_device":
        # Alg. 4 activity mask; all-active until the host loop injects the
        # round's straggler set (Trainer.run straggler_schedule=). A
        # stateful policy's state rides alongside it.
        mask = jnp.ones((n_workers,), bool)
        return {"active": mask, "policy": pstate} if pol.stateful else mask
    return pstate
