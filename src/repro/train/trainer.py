"""Host-side training loop: rounds, order search, checkpointing.

The device side (one WASGD round) is ``train/step.py``; the Trainer drives
it with batches whose per-worker sample order comes from the paper's
``Judge``/``OrderGen`` search (core/order.py), and feeds the round's Judge
scores back into the order state.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig, WASGDConfig
from repro.core import replicate_workers
from repro.core.order import OrderState
from repro.optim import make_optimizer
from repro.train.state import TrainState, init_state
from repro.train.step import build_train_step, init_comm_state, wasgd_rule
from repro.train import step as step_mod


def _wasgd_rule_for(tcfg, mesh=None, overlap=None):
    """Sync Eq. 10 rule, or the Alg. 4 masked rule when the config selects
    ``async_mode="on_device"`` (the mask rides in ``state.comm_state``).
    ``overlap`` is the compute thunk threaded between the aggregation
    schedule's collective phases (train/step.py)."""
    if tcfg.wasgd.async_mode == "on_device":
        return step_mod.async_wasgd_rule(tcfg.wasgd, mesh=mesh,
                                         overlap=overlap)
    return step_mod.wasgd_rule(tcfg.wasgd, mesh=mesh, overlap=overlap)


RULES = {
    "wasgd": _wasgd_rule_for,
    "wasgd+": _wasgd_rule_for,
    "spsgd": lambda tcfg, mesh=None, overlap=None: step_mod.spsgd_rule(),
    "easgd": lambda tcfg, mesh=None, overlap=None:
        step_mod.easgd_rule(alpha=0.9 / 16),
    "omwu": lambda tcfg, mesh=None, overlap=None: step_mod.mwu_rule(),
    "mmwu": lambda tcfg, mesh=None, overlap=None: step_mod.mwu_rule(),
    "seq": lambda tcfg, mesh=None, overlap=None: step_mod.no_comm_rule(),
}


class Trainer:
    def __init__(self, loss_fn, params: Dict, axes: Dict, tcfg: TrainConfig,
                 n_workers: int, rule: str = "wasgd",
                 replicate: bool = True, jit: bool = True,
                 easgd_alpha: Optional[float] = None, mesh=None,
                 overlap=None):
        """``mesh`` feeds the aggregation-backend context — required when
        ``tcfg.wasgd`` selects a schedule that places explicit collectives
        (``shard_map``/``rs_ag``, incl. legacy ``sharded_aggregate=True``).
        ``overlap`` (nullary compute thunk returning an array) rides between
        the schedule's collective phases; its per-round result lands in
        ``history[r]["overlap"]``."""
        self.tcfg = tcfg
        self.n_workers = n_workers
        self.rule_name = rule
        if replicate:
            params, axes = replicate_workers(
                params, axes, n_workers,
                expert_copies=getattr(tcfg, "expert_copies", False))
        self.axes = axes
        self.optimizer = make_optimizer(
            tcfg.optimizer, tcfg.learning_rate, tcfg.momentum,
            tcfg.weight_decay)
        opt_state = self.optimizer.init(params)
        comm_state = init_comm_state(rule, params, axes, n_workers,
                                     wcfg=tcfg.wasgd)
        self.state: TrainState = init_state(params, opt_state, n_workers,
                                            comm_state)
        if rule == "easgd" and easgd_alpha is not None:
            rule_fn = step_mod.easgd_rule(easgd_alpha)
        else:
            rule_fn = RULES[rule](tcfg, mesh=mesh, overlap=overlap)
        self._step = build_train_step(loss_fn, self.optimizer, axes,
                                      tcfg.wasgd, n_workers, rule=rule_fn)
        if jit:
            self._step = jax.jit(self._step, donate_argnums=(0,))
        self.history: list = []

    def run(self, batches: Iterator[Dict], n_rounds: int,
            order_state: Optional[OrderState] = None,
            segment_fn: Optional[Callable[[int], int]] = None,
            log_every: int = 0, metrics_path: Optional[str] = None,
            checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None,
            straggler_schedule=None) -> Dict:
        """``straggler_schedule`` (async_mode="on_device" only): a
        ``StragglerSchedule`` or ``(rounds, w)`` bool array covering all
        ``n_rounds``; round ``r``'s activity mask is injected into
        ``state.comm_state`` before the step, so the jitted Alg. 4 round
        excludes that round's stragglers."""
        active_rounds = None
        if straggler_schedule is not None:
            if self.tcfg.wasgd.async_mode != "on_device":
                raise ValueError(
                    "straggler_schedule requires "
                    "WASGDConfig(async_mode='on_device')")
            if self.rule_name not in ("wasgd", "wasgd+"):
                # only the Alg. 4 rule reads the mask out of comm_state —
                # fail loud instead of running a fully synchronous baseline
                # labeled as a straggler experiment.
                raise ValueError(
                    f"straggler_schedule is only consumed by the wasgd/"
                    f"wasgd+ rules (got rule={self.rule_name!r})")
            active_rounds = np.asarray(
                getattr(straggler_schedule, "active", straggler_schedule),
                bool)
            if len(active_rounds) < n_rounds:
                raise ValueError(
                    f"straggler_schedule covers {len(active_rounds)} rounds "
                    f"but run() was asked for {n_rounds}; build the "
                    f"schedule with rounds={n_rounds} (silent reuse would "
                    f"correlate the exclusion statistics)")
        t0 = time.time()
        mf = open(metrics_path, "a") if metrics_path else None
        for r in range(n_rounds):
            batch = next(batches)
            if active_rounds is not None:
                self.state = self.state._replace(
                    comm_state=jnp.asarray(active_rounds[r]))
            self.state, metrics = self._step(self.state, batch)
            rec = {k: np.asarray(v) for k, v in metrics.items()}
            rec["round"] = r
            self.history.append(rec)
            if order_state is not None:
                seg = segment_fn(r) if segment_fn else 0
                order_state.record_scores(seg, rec["scores"])
            if mf is not None:
                mf.write(json.dumps(
                    {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                     for k, v in rec.items()}) + "\n")
                mf.flush()
            if checkpoint_every and checkpoint_path \
                    and (r + 1) % checkpoint_every == 0:
                from repro.checkpoint import save
                save(os.path.join(checkpoint_path, f"round_{r+1}"),
                     self.state.params, meta={"round": r + 1})
            if log_every and (r + 1) % log_every == 0:
                print(f"round {r+1}/{n_rounds} loss={rec['loss']:.4f} "
                      f"theta_entropy={rec['theta_entropy']:.3f}")
        if mf is not None:
            mf.close()
        return {"rounds": n_rounds, "wall": time.time() - t0,
                "final_loss": float(self.history[-1]["loss"])}

    def losses(self) -> np.ndarray:
        return np.array([h["loss"] for h in self.history])
