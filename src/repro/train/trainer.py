"""Host-side training loop: rounds, order search, checkpointing.

The device side (one WASGD round) is ``train/step.py``; the Trainer drives
it with batches whose per-worker sample order comes from the paper's
``Judge``/``OrderGen`` search (core/order.py), and feeds the round's Judge
scores back into the order state.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig, WASGDConfig
from repro.core import replicate_workers
from repro.core.order import OrderState
from repro.data.pipeline import RoundPrefetcher
from repro.optim import make_optimizer
from repro.train.state import TrainState, init_state
from repro.train.step import build_train_step, init_comm_state, wasgd_rule
from repro.train import step as step_mod


def _wasgd_rule_for(tcfg, mesh=None, overlap=None):
    """Sync Eq. 10 rule, or the Alg. 4 masked rule when the config selects
    ``async_mode="on_device"`` (the mask rides in ``state.comm_state``).
    ``overlap`` is the compute thunk threaded between the aggregation
    schedule's collective phases (train/step.py)."""
    if tcfg.wasgd.async_mode == "on_device":
        return step_mod.async_wasgd_rule(tcfg.wasgd, mesh=mesh,
                                         overlap=overlap)
    return step_mod.wasgd_rule(tcfg.wasgd, mesh=mesh, overlap=overlap)


RULES = {
    "wasgd": _wasgd_rule_for,
    "wasgd+": _wasgd_rule_for,
    "spsgd": lambda tcfg, mesh=None, overlap=None: step_mod.spsgd_rule(),
    "easgd": lambda tcfg, mesh=None, overlap=None:
        step_mod.easgd_rule(alpha=0.9 / 16),
    "omwu": lambda tcfg, mesh=None, overlap=None: step_mod.mwu_rule(),
    "mmwu": lambda tcfg, mesh=None, overlap=None: step_mod.mwu_rule(),
    "seq": lambda tcfg, mesh=None, overlap=None: step_mod.no_comm_rule(),
}


class Trainer:
    def __init__(self, loss_fn, params: Dict, axes: Dict, tcfg: TrainConfig,
                 n_workers: int, rule: str = "wasgd",
                 replicate: bool = True, jit: bool = True,
                 easgd_alpha: Optional[float] = None, mesh=None,
                 overlap=None, pipeline: Optional[str] = None):
        """``mesh`` feeds the aggregation-backend context — required when
        ``tcfg.wasgd`` selects a schedule that places explicit collectives
        (``shard_map``/``rs_ag``, incl. legacy ``sharded_aggregate=True``).
        ``overlap`` (nullary compute thunk; may return any pytree) rides
        between the schedule's collective phases; its per-round result lands
        in ``history[r]["overlap"]``.

        ``pipeline="parity" | "speculative"`` software-pipelines the round
        (``train/step.py``): ``run`` wraps the batch iterator in a
        double-buffered ``RoundPrefetcher`` so round ``r+1``'s host staging
        and first worker-major microbatch ride the aggregation schedule's
        phase-gap seam during round ``r``'s communication. ``"parity"`` is
        bitwise-identical to the unpipelined trainer; ``"speculative"``
        additionally runs the next round's Judge/energy forward on
        pre-aggregate params (stale by one Eq. 10 step, measured per round
        in ``history[r]["spec_dev"]`` / ``["spec_bound"]``). Only the
        wasgd/wasgd+ rules thread the seam. NOTE: with an
        ``OrderedDataset``, the prefetcher's generator runs up to
        ``RoundPrefetcher.run_ahead()`` (= depth + 2, default 4) rounds
        ahead, so pass ``boundary_delay=RoundPrefetcher.run_ahead()`` to
        keep OrderGen's per-segment decision aligned with the recorded
        Judge scores."""
        self.tcfg = tcfg
        self.n_workers = n_workers
        self.rule_name = rule
        self.pipeline = pipeline
        if pipeline is not None and rule not in ("wasgd", "wasgd+"):
            raise ValueError(
                f"pipeline={pipeline!r} threads the seam thunk through the "
                f"wasgd/wasgd+ rules only (got rule={rule!r})")
        if replicate:
            params, axes = replicate_workers(
                params, axes, n_workers,
                expert_copies=getattr(tcfg, "expert_copies", False))
        self.axes = axes
        self.optimizer = make_optimizer(
            tcfg.optimizer, tcfg.learning_rate, tcfg.momentum,
            tcfg.weight_decay)
        opt_state = self.optimizer.init(params)
        comm_state = init_comm_state(rule, params, axes, n_workers,
                                     wcfg=tcfg.wasgd)
        self.state: TrainState = init_state(params, opt_state, n_workers,
                                            comm_state)
        if rule == "easgd" and easgd_alpha is not None:
            rule_fn = step_mod.easgd_rule(easgd_alpha)
        else:
            rule_fn = RULES[rule](tcfg, mesh=mesh, overlap=overlap)
        self._step = build_train_step(loss_fn, self.optimizer, axes,
                                      tcfg.wasgd, n_workers, rule=rule_fn,
                                      pipeline=pipeline)
        self._primer = getattr(self._step, "primer", None)
        if jit:
            self._step = jax.jit(self._step, donate_argnums=(0,))
            if self._primer is not None:
                self._primer = jax.jit(self._primer)
        self.history: list = []

    def run(self, batches: Iterator[Dict], n_rounds: int,
            order_state: Optional[OrderState] = None,
            segment_fn: Optional[Callable[[int], int]] = None,
            log_every: int = 0, metrics_path: Optional[str] = None,
            checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None,
            straggler_schedule=None) -> Dict:
        """``batches`` is a round-batch iterator, or an ``OrderedDataset``
        instance — passing the dataset itself lets a pipelined run VALIDATE
        that its OrderGen decisions are deferred past the prefetcher's
        run-ahead (``boundary_delay``), and defaults ``order_state`` /
        ``segment_fn`` from the dataset.

        ``straggler_schedule`` (async_mode="on_device" only): a
        ``StragglerSchedule`` or ``(rounds, w)`` bool array covering all
        ``n_rounds``; round ``r``'s activity mask is injected into
        ``state.comm_state`` before the step, so the jitted Alg. 4 round
        excludes that round's stragglers."""
        from repro.data.pipeline import OrderedDataset
        if isinstance(batches, OrderedDataset):
            ds = batches
            if self.pipeline is not None \
                    and ds.boundary_delay < RoundPrefetcher.run_ahead():
                raise ValueError(
                    f"pipelined run: the prefetcher's generator runs up to "
                    f"{RoundPrefetcher.run_ahead()} rounds ahead of score "
                    f"recording, but this OrderedDataset commits OrderGen "
                    f"decisions after boundary_delay={ds.boundary_delay} "
                    f"rounds — its keep-or-reshuffle would read truncated "
                    f"Judge scores; build it with boundary_delay="
                    f"RoundPrefetcher.run_ahead()")
            if order_state is None and segment_fn is None:
                order_state, segment_fn = ds.order, ds.segment_of_round
            batches = ds.batches()
        elif self.pipeline is not None and order_state is not None:
            import warnings
            warnings.warn(
                "pipelined run over a bare iterator with an order_state: "
                "the Trainer cannot verify the generator defers its "
                "OrderGen decisions past the prefetch run-ahead "
                f"({RoundPrefetcher.run_ahead()} rounds); pass the "
                "OrderedDataset itself (run(ds, ...)) or build it with "
                "boundary_delay=RoundPrefetcher.run_ahead() to avoid "
                "decisions that miss the final rounds' Judge scores",
                stacklevel=2)
        active_rounds = None
        if straggler_schedule is not None:
            if self.tcfg.wasgd.async_mode != "on_device":
                raise ValueError(
                    "straggler_schedule requires "
                    "WASGDConfig(async_mode='on_device')")
            if self.rule_name not in ("wasgd", "wasgd+"):
                # only the Alg. 4 rule reads the mask out of comm_state —
                # fail loud instead of running a fully synchronous baseline
                # labeled as a straggler experiment.
                raise ValueError(
                    f"straggler_schedule is only consumed by the wasgd/"
                    f"wasgd+ rules (got rule={self.rule_name!r})")
            active_rounds = np.asarray(
                getattr(straggler_schedule, "active", straggler_schedule),
                bool)
            if len(active_rounds) < n_rounds:
                raise ValueError(
                    f"straggler_schedule covers {len(active_rounds)} rounds "
                    f"but run() was asked for {n_rounds}; build the "
                    f"schedule with rounds={n_rounds} (silent reuse would "
                    f"correlate the exclusion statistics)")
            from repro.core.async_device import validate_active_rounds
            validate_active_rounds(active_rounds, rounds=n_rounds)
        t0 = time.time()
        mf = open(metrics_path, "a") if metrics_path else None
        prefetch = None
        if self.pipeline is not None and not isinstance(batches,
                                                        RoundPrefetcher):
            prefetch = RoundPrefetcher(batches, self.n_workers,
                                       self.tcfg.wasgd.tau)
            batches = prefetch
        carry = None
        try:
            for r in range(n_rounds):
                if self.pipeline is not None:
                    batch, next_first = next(batches)
                else:
                    batch = next(batches)
                if active_rounds is not None:
                    # comm_state is the bare (w,) mask for stateless
                    # policies, or {"active": mask, "policy": state} when a
                    # stateful worker-assessment policy rides along — only
                    # the mask is the host's to replace.
                    mask = jnp.asarray(active_rounds[r])
                    cs = self.state.comm_state
                    cs = ({**cs, "active": mask} if isinstance(cs, dict)
                          else mask)
                    self.state = self.state._replace(comm_state=cs)
                if self.pipeline is not None:
                    if carry is None:
                        carry = self._primer(self.state.params, batch)
                    self.state, metrics, carry = self._step(
                        self.state, batch, next_first, carry)
                else:
                    self.state, metrics = self._step(self.state, batch)
                rec = {k: np.asarray(v) for k, v in metrics.items()}
                rec["round"] = r
                self.history.append(rec)
                if order_state is not None:
                    seg = segment_fn(r) if segment_fn else 0
                    order_state.record_scores(seg, rec["scores"])
                if mf is not None:
                    mf.write(json.dumps(
                        {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                         for k, v in rec.items()}) + "\n")
                    mf.flush()
                if checkpoint_every and checkpoint_path \
                        and (r + 1) % checkpoint_every == 0:
                    from repro.checkpoint import save
                    save(os.path.join(checkpoint_path, f"round_{r+1}"),
                         self.state.params, meta={"round": r + 1})
                if log_every and (r + 1) % log_every == 0:
                    print(f"round {r+1}/{n_rounds} loss={rec['loss']:.4f} "
                          f"theta_entropy={rec['theta_entropy']:.3f}")
        finally:
            if mf is not None:
                mf.close()
            if prefetch is not None:
                prefetch.close()
        return {"rounds": n_rounds, "wall": time.time() - t0,
                "final_loss": float(self.history[-1]["loss"])}

    def losses(self) -> np.ndarray:
        return np.array([h["loss"] for h in self.history])
