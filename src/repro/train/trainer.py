"""Host-side training loop: rounds, order search, checkpointing.

The device side (one WASGD round) is ``train/step.py``; the Trainer drives
it with batches whose per-worker sample order comes from the paper's
``Judge``/``OrderGen`` search (core/order.py), and feeds the round's Judge
scores back into the order state.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig, WASGDConfig
from repro.core import replicate_workers
from repro.core.membership import (MembershipSchedule, WorkerSet,
                                   resize_train_state)
from repro.core.order import OrderState
from repro.core.weights import policy_from_config
from repro.data.pipeline import RoundPrefetcher
from repro.obs import (NULL, MembershipChange, RoundTrace, WorkerAssessment,
                       summarize_policy_state)
from repro.optim import make_optimizer
from repro.train.state import TrainState, init_state
from repro.train.step import build_train_step, init_comm_state, wasgd_rule
from repro.train import step as step_mod


def _wasgd_rule_for(tcfg, mesh=None, overlap=None):
    """Sync Eq. 10 rule, or the Alg. 4 masked rule when the config selects
    ``async_mode="on_device"`` (the mask rides in ``state.comm_state``).
    ``overlap`` is the compute thunk threaded between the aggregation
    schedule's collective phases (train/step.py)."""
    if tcfg.wasgd.async_mode == "on_device":
        return step_mod.async_wasgd_rule(tcfg.wasgd, mesh=mesh,
                                         overlap=overlap)
    return step_mod.wasgd_rule(tcfg.wasgd, mesh=mesh, overlap=overlap)


RULES = {
    "wasgd": _wasgd_rule_for,
    "wasgd+": _wasgd_rule_for,
    "spsgd": lambda tcfg, mesh=None, overlap=None: step_mod.spsgd_rule(),
    "easgd": lambda tcfg, mesh=None, overlap=None:
        step_mod.easgd_rule(alpha=0.9 / 16),
    "omwu": lambda tcfg, mesh=None, overlap=None: step_mod.mwu_rule(),
    "mmwu": lambda tcfg, mesh=None, overlap=None: step_mod.mwu_rule(),
    "seq": lambda tcfg, mesh=None, overlap=None: step_mod.no_comm_rule(),
}


class Trainer:
    def __init__(self, loss_fn, params: Dict, axes: Dict, tcfg: TrainConfig,
                 n_workers: int, rule: str = "wasgd",
                 replicate: bool = True, jit: bool = True,
                 easgd_alpha: Optional[float] = None, mesh=None,
                 overlap=None, pipeline: Optional[str] = None):
        """``mesh`` feeds the aggregation-backend context — required when
        ``tcfg.wasgd`` selects a schedule that places explicit collectives
        (``shard_map``/``rs_ag``, incl. legacy ``sharded_aggregate=True``).
        ``overlap`` (nullary compute thunk; may return any pytree) rides
        between the schedule's collective phases; its per-round result lands
        in ``history[r]["overlap"]``.

        ``pipeline="parity" | "speculative"`` software-pipelines the round
        (``train/step.py``): ``run`` wraps the batch iterator in a
        double-buffered ``RoundPrefetcher`` so round ``r+1``'s host staging
        and first worker-major microbatch ride the aggregation schedule's
        phase-gap seam during round ``r``'s communication. ``"parity"`` is
        bitwise-identical to the unpipelined trainer; ``"speculative"``
        additionally runs the next round's Judge/energy forward on
        pre-aggregate params (stale by one Eq. 10 step, measured per round
        in ``history[r]["spec_dev"]`` / ``["spec_bound"]``). Only the
        wasgd/wasgd+ rules thread the seam. NOTE: with an
        ``OrderedDataset``, the prefetcher's generator runs up to
        ``RoundPrefetcher.run_ahead()`` (= depth + 2, default 4) rounds
        ahead, so pass ``boundary_delay=RoundPrefetcher.run_ahead()`` to
        keep OrderGen's per-segment decision aligned with the recorded
        Judge scores."""
        self.tcfg = tcfg
        self.workers = WorkerSet(n_workers)
        self.rule_name = rule
        self.pipeline = pipeline
        if pipeline is not None and rule not in ("wasgd", "wasgd+"):
            raise ValueError(
                f"pipeline={pipeline!r} threads the seam thunk through the "
                f"wasgd/wasgd+ rules only (got rule={rule!r})")
        if replicate:
            params, axes = replicate_workers(
                params, axes, n_workers,
                expert_copies=getattr(tcfg, "expert_copies", False))
        self.axes = axes
        self.optimizer = make_optimizer(
            tcfg.optimizer, tcfg.learning_rate, tcfg.momentum,
            tcfg.weight_decay)
        opt_state = self.optimizer.init(params)
        comm_state = init_comm_state(rule, params, axes, n_workers,
                                     wcfg=tcfg.wasgd)
        self.state: TrainState = init_state(params, opt_state, n_workers,
                                            comm_state)
        self._loss_fn = loss_fn
        self._mesh = mesh
        self._overlap = overlap
        self._jit = jit
        self._easgd_alpha = easgd_alpha
        self._ckpt = None                      # lazy AsyncCheckpointer
        self._telemetry = NULL                 # set per run(telemetry=)
        self._phased_cache: Dict[int, Any] = {}
        self._build_step()
        self.history: list = []

    @property
    def n_workers(self) -> int:
        """The live worker count — a round-boundary-mutable property of the
        ``WorkerSet`` (changes only through ``resize``)."""
        return self.workers.p

    def _build_step(self):
        """(Re)build the jitted round step for the current membership. The
        step closes over ``n_workers`` (batch reshape, mask shapes), so
        every ``resize`` swaps it; built steps are memoized per worker
        count — a chaos schedule that revisits a ``p`` reuses that jit
        wrapper (and its compilation cache) instead of recompiling."""
        if not hasattr(self, "_step_cache"):
            self._step_cache = {}
        cached = self._step_cache.get(self.n_workers)
        if cached is not None:
            self._step, self._primer = cached
            return
        if self.rule_name == "easgd" and self._easgd_alpha is not None:
            rule_fn = step_mod.easgd_rule(self._easgd_alpha)
        else:
            rule_fn = RULES[self.rule_name](self.tcfg, mesh=self._mesh,
                                            overlap=self._overlap)
        step = build_train_step(self._loss_fn, self.optimizer, self.axes,
                                self.tcfg.wasgd, self.n_workers,
                                rule=rule_fn, pipeline=self.pipeline)
        self._primer = getattr(step, "primer", None)
        if self._jit:
            step = jax.jit(step, donate_argnums=(0,))
            if self._primer is not None:
                self._primer = jax.jit(self._primer)
        self._step = step
        self._step_cache[self.n_workers] = (self._step, self._primer)

    def _policy_for_resize(self):
        if self.rule_name not in ("wasgd", "wasgd+"):
            return None
        pol = policy_from_config(self.tcfg.wasgd)
        return pol if pol.stateful else None

    def resize(self, new_p: int, round: Optional[int] = None):
        """Commit a membership change at a round boundary: re-shard the
        worker-stacked train state (survivors keep their slots bitwise,
        newcomers adopt the aggregate — core/membership.py), re-shard the
        comm state through ``init_comm_state(prev=)``, and rebuild the
        jitted step for the new shapes. Returns the ``MembershipEvent``
        (or None when ``new_p`` is already the live count)."""
        if self.rule_name not in ("wasgd", "wasgd+"):
            raise ValueError(
                f"elastic membership is a wasgd/wasgd+ capability — rule "
                f"{self.rule_name!r} pins worker count at construction")
        new_p = int(new_p)
        if new_p == self.n_workers:
            return None
        comm = init_comm_state(self.rule_name, self.state.params, self.axes,
                               new_p, wcfg=self.tcfg.wasgd,
                               prev=self.state.comm_state)
        self.state = resize_train_state(self.state, self.axes, new_p,
                                        policy=self._policy_for_resize(),
                                        comm_state=comm)
        old_p = self.n_workers
        event = self.workers.resize(new_p, round=round)
        self._build_step()
        if event is not None and self._telemetry.enabled:
            self._telemetry.emit(MembershipChange(
                round=round if round is not None else -1,
                old_p=old_p, new_p=new_p,
                generation=getattr(self.workers, "generation", 0)))
        return event

    # -- sharded, resumable checkpoints -----------------------------------

    def _topology(self, round: int) -> Dict:
        """The membership record a sharded checkpoint carries: enough for a
        restore to rebuild the saved state's shapes (``p``), place itself in
        the run (``round``), and verify the rule/policy/comm-state structure
        it is being restored into."""
        from repro.checkpoint.io import _flatten
        return {
            "p": self.n_workers,
            "round": int(round),
            "rule": self.rule_name,
            "policy": self.tcfg.wasgd.policy,
            "comm_state": sorted(_flatten({"cs": self.state.comm_state})),
        }

    def save_checkpoint(self, path: str, round: int):
        """Async sharded save of the FULL train state (params, optimizer
        state, energy, comm state — not the params-only legacy format). The
        call returns after an on-device snapshot; serialization rides the
        next rounds' device time (checkpoint/io.AsyncCheckpointer)."""
        from repro.checkpoint import AsyncCheckpointer
        if self._ckpt is None:
            self._ckpt = AsyncCheckpointer(telemetry=self._telemetry)
        self._ckpt.save(path, self.state, meta={"round": int(round)},
                        topology=self._topology(round))

    def resume(self, path: str, allow_cast: bool = False) -> int:
        """Restore a checkpoint into this trainer and return the round to
        resume at. A sharded checkpoint saved under a DIFFERENT worker count
        restores at its recorded ``p`` (the manifest topology shapes the
        template) and then resizes to this trainer's live membership — the
        saved survivors land bitwise in their slots, extra slots are filled
        by the resize machinery's late-join rule."""
        from repro.checkpoint import restore, saved_topology
        info = saved_topology(path)
        topo = info["topology"]
        saved_p = int(topo.get("p", self.n_workers))
        if topo.get("rule") is not None and topo["rule"] != self.rule_name:
            raise ValueError(
                f"checkpoint was saved by rule {topo['rule']!r}; this "
                f"trainer runs {self.rule_name!r}")
        pol = self._policy_for_resize()
        like = self.state
        if saved_p != self.n_workers:
            if self.rule_name not in ("wasgd", "wasgd+"):
                raise ValueError(
                    f"checkpoint p={saved_p} != trainer p={self.n_workers} "
                    f"and rule {self.rule_name!r} has no elastic resize")
            like = resize_train_state(self.state, self.axes, saved_p,
                                      policy=pol)
        restored, meta = restore(path, like, allow_cast=allow_cast)
        if saved_p != self.n_workers:
            restored = resize_train_state(restored, self.axes,
                                          self.n_workers, policy=pol)
        self.state = restored
        return int(topo.get("round", meta.get("round", 0)))

    # -- telemetry ---------------------------------------------------------

    def _phased_step(self):
        """The phase-fenced instrumented round for the current membership
        (memoized per worker count, like ``_build_step``), or None when
        this run cannot decompose into phases — pipelined rounds and the
        baseline rules fall back to a coarse fenced RoundTrace. Only
        consulted when a real telemetry sink is attached; the default
        path never builds (or pays for) it."""
        if self.rule_name not in ("wasgd", "wasgd+") \
                or self.pipeline is not None or not self._jit:
            return None
        fn = self._phased_cache.get(self.n_workers)
        if fn is None:
            fn = step_mod.build_phased_train_step(
                self._loss_fn, self.optimizer, self.axes, self.tcfg.wasgd,
                self.n_workers, mesh=self._mesh, overlap=self._overlap)
            self._phased_cache[self.n_workers] = fn
        return fn

    def _emit_round(self, tele, r: int, rec: Dict, total_s: float,
                    host_staging_s: float, phase_times) -> None:
        """Emit the round's RoundTrace + WorkerAssessment. Called after
        the metrics readback (outside any transfer guard), so the host
        conversions here add no transfers the fused path would not do."""
        tele.emit(RoundTrace(
            round=r, total_s=total_s, host_staging_s=host_staging_s,
            phases=dict(phase_times) if phase_times is not None else {},
            detail="phased" if phase_times is not None else "fused",
            p=self.n_workers))
        theta = rec.get("theta")
        h = rec.get("h")
        active = rec.get("active")
        pstate = None
        if self.rule_name in ("wasgd", "wasgd+"):
            cs = self.state.comm_state
            if isinstance(cs, dict):
                pstate = cs.get("policy")
            elif self.tcfg.wasgd.async_mode != "on_device":
                pstate = cs
        tele.emit(WorkerAssessment(
            round=r,
            theta=(np.ravel(theta).astype(float).tolist()
                   if theta is not None else []),
            energies=(np.ravel(h).astype(float).tolist()
                      if h is not None else []),
            theta_entropy=float(rec.get("theta_entropy", 0.0)),
            active=([bool(x) for x in np.ravel(active)]
                    if active is not None else None),
            policy=self.tcfg.wasgd.policy or self.tcfg.wasgd.strategy,
            policy_state=summarize_policy_state(pstate)))

    def run(self, batches: Iterator[Dict], n_rounds: int,
            order_state: Optional[OrderState] = None,
            segment_fn: Optional[Callable[[int], int]] = None,
            log_every: int = 0, metrics_path: Optional[str] = None,
            checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None,
            straggler_schedule=None,
            membership_schedule: Optional[MembershipSchedule] = None,
            resume_from: Optional[str] = None,
            serve_hook: Optional[Callable[[int, Dict, Dict], Any]] = None,
            serve_every: int = 1,
            transfer_guard: Optional[str] = None,
            telemetry=None) -> Dict:
        """``batches`` is a round-batch iterator, or an ``OrderedDataset``
        instance — passing the dataset itself lets a pipelined run VALIDATE
        that its OrderGen decisions are deferred past the prefetcher's
        run-ahead (``boundary_delay``), and defaults ``order_state`` /
        ``segment_fn`` from the dataset.

        ``straggler_schedule`` (async_mode="on_device" only): a
        ``StragglerSchedule`` or ``(rounds, w)`` bool array covering all
        ``n_rounds``; round ``r``'s activity mask is injected into
        ``state.comm_state`` before the step, so the jitted Alg. 4 round
        excludes that round's stragglers.

        ``membership_schedule`` makes the run ELASTIC: at each round
        boundary where the schedule's ``p_of(r)`` differs from the live
        ``WorkerSet``, the trainer resizes (``Trainer.resize``), the
        OrderedDataset re-shards its per-worker index rows, and the round
        generator (and prefetcher, when pipelined) restarts at round ``r``
        with the new worker count. Requires ``batches`` to be the
        ``OrderedDataset`` itself — a bare iterator bakes in a fixed ``p``.
        Mutually exclusive with ``straggler_schedule`` (whose mask table is
        a fixed ``(rounds, p)``); transient stragglers within a fixed
        membership are that path, membership changes are this one.

        ``checkpoint_every``/``checkpoint_path`` save the FULL train state
        every N rounds as a sharded, topology-aware checkpoint
        (``checkpoint_path/round_{r+1}``), asynchronously — serialization
        rides the following rounds. ``resume_from`` restores such a
        checkpoint (``Trainer.resume``) and continues at its recorded
        round; a checkpoint from a different worker count resizes into this
        trainer's membership on the way in.

        ``serve_hook(round, params, axes)`` is called every ``serve_every``
        rounds after the step with the live worker-stacked params — the
        train-to-serve bridge (``serve.HotSwapBridge`` extracts the beta=1
        consensus and hot-swaps it into a running engine, recording per-swap
        staleness).

        ``transfer_guard`` (debug): a ``jax.transfer_guard`` level
        (``"log"`` / ``"disallow"``, see jax docs) applied around each
        jitted step call. Round batches are explicitly ``jax.device_put``
        first — iterator batches are host arrays and their per-round h2d
        staging is expected — so the guard only fires on implicit
        transfers INSIDE the round (the ``.item()``/``np.*``-in-hot-path
        family; ``tools/trace_audit.py`` runs the same check over the
        backend grid). Metrics are read back after the guard exits.

        ``telemetry`` is a ``repro.obs`` sink (``RingSink``/``JsonlSink``;
        default ``NullSink`` = off). With a real sink attached the run
        emits per-round ``RoundTrace`` (wasgd/wasgd+ unpipelined rounds run
        the phase-fenced instrumented step — per-phase device-accurate
        breakdown; pipelined rounds and baseline rules report a fenced
        total only) and ``WorkerAssessment`` events, plus
        ``MembershipChange`` on elastic resizes and ``CheckpointSave`` from
        the async checkpoint writer. With the default ``NullSink`` every
        instrumentation site short-circuits: no fences, no host readbacks,
        bitwise-identical params (tests/test_obs.py)."""
        from repro.data.pipeline import OrderedDataset
        ds = None
        if isinstance(batches, OrderedDataset):
            ds = batches
            if self.pipeline is not None \
                    and ds.boundary_delay < RoundPrefetcher.run_ahead():
                raise ValueError(
                    f"pipelined run: the prefetcher's generator runs up to "
                    f"{RoundPrefetcher.run_ahead()} rounds ahead of score "
                    f"recording, but this OrderedDataset commits OrderGen "
                    f"decisions after boundary_delay={ds.boundary_delay} "
                    f"rounds — its keep-or-reshuffle would read truncated "
                    f"Judge scores; build it with boundary_delay="
                    f"RoundPrefetcher.run_ahead()")
            if order_state is None and segment_fn is None:
                order_state, segment_fn = ds.order, ds.segment_of_round
        elif self.pipeline is not None and order_state is not None:
            import warnings
            warnings.warn(
                "pipelined run over a bare iterator with an order_state: "
                "the Trainer cannot verify the generator defers its "
                "OrderGen decisions past the prefetch run-ahead "
                f"({RoundPrefetcher.run_ahead()} rounds); pass the "
                "OrderedDataset itself (run(ds, ...)) or build it with "
                "boundary_delay=RoundPrefetcher.run_ahead() to avoid "
                "decisions that miss the final rounds' Judge scores",
                stacklevel=2)
        active_rounds = None
        if straggler_schedule is not None:
            if self.tcfg.wasgd.async_mode != "on_device":
                raise ValueError(
                    "straggler_schedule requires "
                    "WASGDConfig(async_mode='on_device')")
            if self.rule_name not in ("wasgd", "wasgd+"):
                # only the Alg. 4 rule reads the mask out of comm_state —
                # fail loud instead of running a fully synchronous baseline
                # labeled as a straggler experiment.
                raise ValueError(
                    f"straggler_schedule is only consumed by the wasgd/"
                    f"wasgd+ rules (got rule={self.rule_name!r})")
            active_rounds = np.asarray(
                getattr(straggler_schedule, "active", straggler_schedule),
                bool)
            if len(active_rounds) < n_rounds:
                raise ValueError(
                    f"straggler_schedule covers {len(active_rounds)} rounds "
                    f"but run() was asked for {n_rounds}; build the "
                    f"schedule with rounds={n_rounds} (silent reuse would "
                    f"correlate the exclusion statistics)")
            from repro.core.async_device import validate_active_rounds
            validate_active_rounds(active_rounds, rounds=n_rounds)
        if membership_schedule is not None:
            if self.rule_name not in ("wasgd", "wasgd+"):
                raise ValueError(
                    f"membership_schedule is a wasgd/wasgd+ capability "
                    f"(got rule={self.rule_name!r})")
            if straggler_schedule is not None:
                raise ValueError(
                    "membership_schedule and straggler_schedule are "
                    "mutually exclusive: the straggler mask table is a "
                    "fixed (rounds, p) — model leaving workers as "
                    "membership events instead")
            if ds is None:
                raise ValueError(
                    "membership_schedule requires run(OrderedDataset, ...) "
                    "— a bare batch iterator bakes in a fixed worker "
                    "count, so its rounds cannot be re-sharded at a "
                    "membership event")
        start = 0
        if resume_from is not None:
            start = self.resume(resume_from)
            if start >= n_rounds:
                raise ValueError(
                    f"checkpoint {resume_from} is at round {start}, at or "
                    f"past n_rounds={n_rounds} — nothing left to run")
            if ds is not None and ds.p != self.n_workers:
                ds.resize(self.n_workers)
        tele = telemetry if telemetry is not None else NULL
        self._telemetry = tele
        obs_on = bool(getattr(tele, "enabled", False))
        if self._ckpt is not None:
            self._ckpt.telemetry = tele
        if ds is not None:
            batches = ds.batches(start_round=start)
        t0 = time.time()
        if transfer_guard is not None:
            _guard = lambda: jax.transfer_guard(transfer_guard)  # noqa: E731
        else:
            _guard = contextlib.nullcontext
        mf = open(metrics_path, "a") if metrics_path else None
        prefetch = None
        if self.pipeline is not None and not isinstance(batches,
                                                        RoundPrefetcher):
            prefetch = RoundPrefetcher(batches, self.n_workers,
                                       self.tcfg.wasgd.tau)
            batches = prefetch
        carry = None
        try:
            for r in range(start, n_rounds):
                if membership_schedule is not None:
                    target = membership_schedule.p_of(r)
                    if target != self.n_workers:
                        self.resize(target, round=r)
                        ds.resize(target)
                        gen = ds.batches(start_round=r)
                        if prefetch is not None:
                            prefetch.resize(target, gen)
                        else:
                            batches = gen
                        carry = None      # re-prime the pipelined seam
                t_host = time.perf_counter() if obs_on else 0.0
                if self.pipeline is not None:
                    batch, next_first = next(batches)
                else:
                    batch = next(batches)
                if active_rounds is not None:
                    # comm_state is the bare (w,) mask for stateless
                    # policies, or {"active": mask, "policy": state} when a
                    # stateful worker-assessment policy rides along — only
                    # the mask is the host's to replace.
                    mask = jnp.asarray(active_rounds[r])
                    cs = self.state.comm_state
                    cs = ({**cs, "active": mask} if isinstance(cs, dict)
                          else mask)
                    self.state = self.state._replace(comm_state=cs)
                if transfer_guard is not None:
                    batch = jax.device_put(batch)
                    if self.pipeline is not None:
                        next_first = jax.device_put(next_first)
                host_staging_s = (time.perf_counter() - t_host
                                  if obs_on else 0.0)
                phased = self._phased_step() if obs_on else None
                phase_times = None
                t_step = time.perf_counter() if obs_on else 0.0
                with _guard():
                    if self.pipeline is not None:
                        if carry is None:
                            carry = self._primer(self.state.params, batch)
                        self.state, metrics, carry = self._step(
                            self.state, batch, next_first, carry)
                    else:
                        if phased is not None:
                            self.state, metrics, phase_times = phased(
                                self.state, batch)
                        else:
                            self.state, metrics = self._step(self.state,
                                                             batch)
                if obs_on:
                    if phase_times is None:      # fused program: fence once
                        jax.block_until_ready(self.state)
                    total_s = time.perf_counter() - t_step
                rec = {k: np.asarray(v) for k, v in metrics.items()}
                rec["round"] = r
                if membership_schedule is not None:
                    rec["p"] = self.n_workers
                self.history.append(rec)
                if obs_on:
                    self._emit_round(tele, r, rec, total_s, host_staging_s,
                                     phase_times)
                if order_state is not None:
                    seg = segment_fn(r) if segment_fn else 0
                    order_state.record_scores(seg, rec["scores"])
                if mf is not None:
                    mf.write(json.dumps(
                        {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                         for k, v in rec.items()}) + "\n")
                    mf.flush()
                if serve_hook is not None \
                        and (r + 1) % max(1, serve_every) == 0:
                    serve_hook(r, self.state.params, self.axes)
                if checkpoint_every and checkpoint_path \
                        and (r + 1) % checkpoint_every == 0:
                    self.save_checkpoint(
                        os.path.join(checkpoint_path, f"round_{r+1}"), r + 1)
                if log_every and (r + 1) % log_every == 0:
                    print(f"round {r+1}/{n_rounds} loss={rec['loss']:.4f} "
                          f"theta_entropy={rec['theta_entropy']:.3f}")
        finally:
            if mf is not None:
                mf.close()
            if prefetch is not None:
                prefetch.close()
            if self._ckpt is not None:
                self._ckpt.wait()          # surface async save failures here
        return {"rounds": n_rounds - start, "wall": time.time() - t0,
                "final_loss": float(self.history[-1]["loss"])}

    def losses(self) -> np.ndarray:
        return np.array([h["loss"] for h in self.history])
