"""Evaluation harness: held-out perplexity / accuracy for LM checkpoints
(worker-0 slice or the aggregated consensus)."""
from __future__ import annotations

import functools
from typing import Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import take_worker, weighted_aggregate, equal_weights
from repro.models import loss_fn as lm_loss


def consensus_params(params: Dict, axes: Dict) -> Dict:
    """Final beta=1 equal aggregation, then worker 0's slice — the served
    copy (all workers coincide after a beta=1 communication, Sec. 4.1)."""
    w = None
    for leaf, ax in zip(jax.tree.leaves(params),
                        jax.tree.leaves(axes, is_leaf=lambda x: isinstance(
                            x, tuple))):
        if isinstance(ax, tuple) and ax and ax[0] == "worker":
            w = leaf.shape[0]
            break
    if w is None:
        return params
    agg = weighted_aggregate(params, axes, equal_weights(w), beta=1.0)
    return take_worker(agg, axes, 0)


def evaluate_lm(cfg: ModelConfig, params: Dict, batches, n_batches: int = 8
                ) -> Dict[str, float]:
    """Mean NLL / perplexity / next-token accuracy over held-out batches."""
    @jax.jit
    def eval_batch(p, batch):
        loss, metrics = lm_loss(cfg, p, batch)
        from repro.models import forward
        logits, _ = forward(cfg, p, batch["tokens"], batch.get("media"))
        pred = jnp.argmax(logits, axis=-1)
        acc = (pred == batch["labels"]).mean()
        return metrics["ce"], acc

    nlls, accs = [], []
    for _ in range(n_batches):
        batch = next(batches)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        nll, acc = eval_batch(params, batch)
        nlls.append(float(nll))
        accs.append(float(acc))
    nll = float(np.mean(nlls))
    return {"nll": nll, "ppl": float(np.exp(min(nll, 30.0))),
            "acc": float(np.mean(accs))}
