from repro.train.state import TrainState, init_state
from repro.train.step import (
    build_train_step,
    easgd_rule,
    init_comm_state,
    mwu_rule,
    no_comm_rule,
    spsgd_rule,
    wasgd_rule,
)
from repro.train.trainer import RULES, Trainer

__all__ = [
    "TrainState", "init_state", "build_train_step", "easgd_rule",
    "init_comm_state", "mwu_rule", "no_comm_rule", "spsgd_rule",
    "wasgd_rule", "RULES", "Trainer",
]
