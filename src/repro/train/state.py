"""Training state for WASGD rounds."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array          # round counter (int32 scalar)
    params: Dict             # worker-stacked parameter tree
    opt_state: Any
    energy: jax.Array        # (p,) accumulated loss energies (reset per round)
    comm_state: Any          # rule-specific (EASGD center, MWU weights, ())


def init_state(params: Dict, opt_state: Any, n_workers: int,
               comm_state: Any = ()) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=opt_state,
        energy=jnp.zeros((n_workers,), jnp.float32),
        comm_state=comm_state,
    )
