"""Primitive layers: RMSNorm, rotary embeddings, gated MLP, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.param import ParamBuilder


# -- RMSNorm -------------------------------------------------------------------

def rmsnorm_init(b: ParamBuilder, name: str, dim: int):
    b.scope(name).param("scale", (dim,), ("embed",), init="ones")


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# -- Rotary position embeddings --------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- Gated (SwiGLU) MLP -----------------------------------------------------------

def mlp_init(b: ParamBuilder, name: str, d_model: int, d_ff: int):
    s = b.scope(name)
    s.param("w_gate", (d_model, d_ff), ("embed", "ffn"))
    s.param("w_up", (d_model, d_ff), ("embed", "ffn"))
    s.param("w_down", (d_ff, d_model), ("ffn", "embed"))


def mlp(params, x: jax.Array, compute_dtype) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(compute_dtype))


# -- Embedding / LM head ------------------------------------------------------------

def embed_init(b: ParamBuilder, name: str, vocab: int, d_model: int,
               n_codebooks: int = 0):
    s = b.scope(name)
    if n_codebooks > 0:
        s.param("tok", (n_codebooks, vocab, d_model), (None, "vocab", "embed"),
                scale=d_model ** -0.5)
    else:
        s.param("tok", (vocab, d_model), ("vocab", "embed"), scale=d_model ** -0.5)


def embed(params, tokens: jax.Array, compute_dtype) -> jax.Array:
    tok = params["tok"].astype(compute_dtype)
    if tok.ndim == 3:            # audio: (n_q, V, d), tokens (b, s, n_q)
        per_cb = jnp.einsum("bsqv,qvd->bsd",
                            jax.nn.one_hot(tokens, tok.shape[1], dtype=compute_dtype),
                            tok)
        return per_cb
    return tok[tokens]


def head_init(b: ParamBuilder, name: str, d_model: int, vocab: int,
              n_codebooks: int = 0):
    s = b.scope(name)
    if n_codebooks > 0:
        s.param("w", (n_codebooks, d_model, vocab), (None, "embed", "vocab"))
    else:
        s.param("w", (d_model, vocab), ("embed", "vocab"))


def head(params, x: jax.Array, compute_dtype, softcap: float = 0.0) -> jax.Array:
    w = params["w"].astype(compute_dtype)
    if w.ndim == 3:              # audio: logits (b, s, n_q, V)
        logits = jnp.einsum("bsd,qdv->bsqv", x, w)
    else:
        logits = jnp.einsum("...d,dv->...v", x, w)
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def tied_head(embed_params, x: jax.Array, compute_dtype, softcap: float = 0.0):
    tok = embed_params["tok"].astype(compute_dtype)
    logits = jnp.einsum("...d,vd->...v", x, tok)
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
