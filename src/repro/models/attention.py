"""GQA attention: chunked (flash-style) training/prefill path, single-token
decode path against a KV cache, sliding-window masking, and cross-attention
for the VLM backbone.

The chunked path is the pure-JAX reference of the Pallas ``decode_attn``
kernel (kernels/decode_attn) and keeps peak memory at
O(seq * block) instead of O(seq^2), which is what lets the 32k-prefill
shapes lower with sane ``memory_analysis``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope
from repro.models.param import ParamBuilder

NEG_INF = -1e30


def attention_init(b: ParamBuilder, name: str, d_model: int, n_heads: int,
                   n_kv_heads: int, head_dim: int):
    s = b.scope(name)
    s.param("wq", (d_model, n_heads, head_dim), ("embed", "heads", "head_dim"))
    s.param("wk", (d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"))
    s.param("wv", (d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"))
    s.param("wo", (n_heads, head_dim, d_model), ("heads", "head_dim", "embed"))


# -- core softmax-attention over chunked KV --------------------------------------

def _block_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                window: Optional[int], k_valid: Optional[jax.Array] = None):
    """(sq, bk) boolean mask of allowed attention edges."""
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    if k_valid is not None:
        mask &= k_valid[None, :]
    return mask


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, block_k: int = 512,
                    unroll: bool = False) -> jax.Array:
    """Chunked attention with running softmax.

    q: (b, sq, h, hd);  k, v: (b, sk, kv, hd)  with h = kv * group.
    Returns (b, sq, h, hd).
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    block_k = min(block_k, sk)
    n_blocks = -(-sk // block_k)
    pad = n_blocks * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, block_k, kv, hd)
    vb = v.reshape(b, n_blocks, block_k, kv, hd)

    qg = (q.reshape(b, sq, kv, g, hd) * scale).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        m, l, acc = carry
        k_j, v_j, j = blk
        k_pos = j * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_j.astype(jnp.float32))
        mask = _block_mask(q_pos, k_pos, causal, window,
                           k_valid=k_pos < sk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.maximum(m_new, -0.5e30)          # avoid inf-inf -> nan
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(m - m_safe)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc[...] * corr[..., None] + jnp.einsum(
            "bkgqt,btkd->bkgqd", p, v_j.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(n_blocks)),
        unroll=n_blocks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def flash_attention_windowed(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             window: int, block: int = 512) -> jax.Array:
    """Sliding-window attention with q-blocking that SKIPS kv blocks entirely
    outside the window (beyond-paper §Perf optimization: the masked-but-
    computed blocks of the generic chunked path are pure waste when
    window << seq).

    q block i only touches kv span [max(0,(i-wb)*block), i*block + block) of
    length (wb+1)*block where wb = ceil(window/block) — compute drops from
    O(s^2) to O(s * (window + block)).
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if s <= block or window >= s:
        return flash_attention(q, k, v, causal=True, window=window,
                               block_k=block)
    blk = block
    nqb = -(-s // blk)
    padq = nqb * blk - s
    if padq:
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, padq), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padq), (0, 0), (0, 0)))
    sp = nqb * blk
    wb = -(-window // blk)
    span = (wb + 1) * blk
    scale = hd ** -0.5

    outs = []
    for i in range(nqb):
        q_i = (q[:, i * blk:(i + 1) * blk].reshape(b, blk, kvh, g, hd)
               * scale).astype(jnp.float32)
        start = min(max(0, (i - wb) * blk), max(0, sp - span))
        kspan = k[:, start:start + min(span, sp)]
        vspan = v[:, start:start + min(span, sp)]
        q_pos = i * blk + jnp.arange(blk)
        k_pos = start + jnp.arange(kspan.shape[1])
        mask = _block_mask(q_pos, k_pos, True, window, k_valid=k_pos < s)
        sc = jnp.einsum("bqkgd,btkd->bkgqt", q_i,
                        kspan.astype(jnp.float32))
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bkgqd", p, vspan.astype(jnp.float32))
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(b, blk, h, hd))
    out = jnp.concatenate(outs, axis=1)[:, :s]
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: Optional[int] = None
                     ) -> jax.Array:
    """One-token attention: q (b, 1, h, hd) vs cache (b, S, kv, hd).

    ``cache_len`` is the number of valid cache entries (the new token's K/V
    must already be written at position cache_len-1). Pure-jnp reference of
    the ``decode_attn`` Pallas kernel.
    """
    b, _, h, hd = q.shape
    S, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = (q.reshape(b, kv, g, hd) * hd ** -0.5).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(S)
    valid = k_pos < cache_len
    if window is not None:
        valid &= (cache_len - 1 - k_pos) < window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# -- full self-attention layer ----------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array                 # (b, S, kv, hd)
    v: jax.Array


def self_attention(params, x: jax.Array, positions: jax.Array, *,
                   rope_theta: float, window: Optional[int],
                   compute_dtype, cache: Optional[KVCache] = None,
                   cache_index: Optional[jax.Array] = None,
                   use_pallas_decode: bool = False, unroll: bool = False,
                   windowed_qblock: bool = False
                   ) -> Tuple[jax.Array, Optional[KVCache]]:
    """x: (b, s, d). Training/prefill when cache is None or s>1 fills it;
    decode when s == 1 and cache is given."""
    wq, wk, wv, wo = (params[n].astype(compute_dtype)
                      for n in ("wq", "wk", "wv", "wo"))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    if cache is None:
        if windowed_qblock and window is not None:
            out = flash_attention_windowed(q, k, v, window=window)
        else:
            out = flash_attention(q, k, v, causal=True, window=window,
                                  unroll=unroll)
        new_cache = None
    elif x.shape[1] == 1:
        # decode: write new K/V at cache_index, attend over the cache
        idx = cache_index
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k, idx, axis=1) \
            if k.shape[1] == 1 else cache.k
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v, idx, axis=1)
        if use_pallas_decode:
            from repro.kernels.decode_attn import ops as dops
            out = dops.decode_attention(q, k_cache, v_cache, idx + 1,
                                        window=window)
        else:
            out = decode_attention(q, k_cache, v_cache, idx + 1, window=window)
        new_cache = KVCache(k_cache, v_cache)
    else:
        # prefill: run chunked attention and emit the filled cache
        out = flash_attention(q, k, v, causal=True, window=window,
                              unroll=unroll)
        S = cache.k.shape[1]
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), 0, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), 0, axis=1)
        new_cache = KVCache(k_cache, v_cache)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return y, new_cache


# -- cross-attention (VLM) ----------------------------------------------------------

def cross_attention_init(b: ParamBuilder, name: str, d_model: int, n_heads: int,
                         n_kv_heads: int, head_dim: int):
    attention_init(b, name, d_model, n_heads, n_kv_heads, head_dim)


def cross_attention(params, x: jax.Array, media: jax.Array, *,
                    compute_dtype, unroll: bool = False) -> jax.Array:
    """x: (b, s, d) attends over media embeddings (b, M, d). No mask, no rope."""
    wq, wk, wv, wo = (params[n].astype(compute_dtype)
                      for n in ("wq", "wk", "wv", "wo"))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bmd,dhk->bmhk", media, wk)
    v = jnp.einsum("bmd,dhk->bmhk", media, wv)
    out = flash_attention(q, k, v, causal=False, window=None, unroll=unroll)
    return jnp.einsum("bshk,hkd->bsd", out, wo)
