"""The paper's experiment models (Section 5.2.1), for the faithful-repro
benchmarks: the 6-layer MNIST/Fashion-MNIST CNN
``(1,28)C(16,24)M(16,12)C(32,8)M(32,4)`` + linear head, and a small MLP used
for fast CPU sweeps. Pure ``jax.lax`` convolutions — no external NN library.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import ParamBuilder, build


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


def cnn6_init(b: ParamBuilder, n_classes: int = 10, in_ch: int = 1):
    """(1,28)C(16,24)M(16,12)C(32,8)M(32,4) + FC head (paper Sec. 5.2.1)."""
    b.param("conv1_w", (5, 5, in_ch, 16), (None, None, None, None), scale=0.1)
    b.param("conv1_b", (16,), (None,), init="zeros")
    b.param("conv2_w", (5, 5, 16, 32), (None, None, None, None), scale=0.05)
    b.param("conv2_b", (32,), (None,), init="zeros")
    b.param("fc_w", (32 * 4 * 4, n_classes), (None, None), scale=0.05)
    b.param("fc_b", (n_classes,), (None,), init="zeros")


def cnn6_apply(params: Dict, images: jax.Array) -> jax.Array:
    """images: (b, 28, 28, in_ch) -> logits (b, n_classes)."""
    x = jax.nn.relu(_conv(images, params["conv1_w"], params["conv1_b"]))
    x = _maxpool(x)                                   # (b, 12, 12, 16)
    x = jax.nn.relu(_conv(x, params["conv2_w"], params["conv2_b"]))
    x = _maxpool(x)                                   # (b, 4, 4, 32)
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc_w"] + params["fc_b"]


def mlp_init(b: ParamBuilder, d_in: int, d_hidden: int, n_classes: int,
             n_hidden_layers: int = 2):
    b.param("w_in", (d_in, d_hidden), (None, None))
    b.param("b_in", (d_hidden,), (None,), init="zeros")
    for i in range(n_hidden_layers - 1):
        b.param(f"w_{i}", (d_hidden, d_hidden), (None, None))
        b.param(f"b_{i}", (d_hidden,), (None,), init="zeros")
    b.param("w_out", (d_hidden, n_classes), (None, None))
    b.param("b_out", (n_classes,), (None,), init="zeros")


def mlp_apply(params: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ params["w_in"] + params["b_in"])
    i = 0
    while f"w_{i}" in params:
        h = jax.nn.relu(h @ params[f"w_{i}"] + params[f"b_{i}"])
        i += 1
    return h @ params["w_out"] + params["b_out"]


def init_cnn6(key, n_classes: int = 10, in_ch: int = 1):
    params, _ = build(functools.partial(cnn6_init, n_classes=n_classes,
                                        in_ch=in_ch), key)
    return params


def init_mlp(key, d_in: int, d_hidden: int, n_classes: int,
             n_hidden_layers: int = 2):
    params, _ = build(functools.partial(
        mlp_init, d_in=d_in, d_hidden=d_hidden, n_classes=n_classes,
        n_hidden_layers=n_hidden_layers), key)
    return params


def classification_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
