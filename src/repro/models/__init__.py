from repro.models.transformer import (
    abstract_params,
    cache_axes,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "abstract_params",
    "cache_axes",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
]
