from repro.models.transformer import (
    PagedKV,
    abstract_params,
    cache_axes,
    cache_layout,
    decode_step,
    decode_step_paged,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "PagedKV",
    "abstract_params",
    "cache_axes",
    "cache_layout",
    "decode_step",
    "decode_step_paged",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
]
