"""Mamba2 mixer — SSD (state-space duality) chunked algorithm [arXiv:2405.21060].

Training/prefill runs the chunked form: quadratic attention-like blocks within
chunks of length L plus a linear recurrence over chunk states — O(s·L) instead
of O(s²), MXU-friendly einsums. Decode carries an O(1) recurrent state, which
is what makes the ``long_500k`` shape native for SSM/hybrid architectures.

``ssd_reference`` is the naive per-step recurrence used as the correctness
oracle in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.param import ParamBuilder


class SSMState(NamedTuple):
    """Decode-time recurrent state."""
    s: jax.Array            # (b, nh, ds, hd)
    conv: jax.Array         # (b, conv_width-1, di + 2*ds)


def ssm_init(b: ParamBuilder, name: str, d_model: int, cfg: SSMConfig):
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    ds = cfg.d_state
    s = b.scope(name)
    s.param("in_proj", (d_model, 2 * di + 2 * ds + nh), ("embed", "ssm_heads"))
    s.param("conv_w", (cfg.conv_width, di + 2 * ds), ("conv", "ssm_heads"))
    s.param("conv_b", (di + 2 * ds,), ("ssm_heads",), init="zeros")
    s.param("A_log", (nh,), ("ssm_heads",), init="uniform", scale=1.0)
    s.param("D", (nh,), ("ssm_heads",), init="ones")
    s.param("dt_bias", (nh,), ("ssm_heads",), init="zeros")
    s.param("norm_scale", (di,), ("ssm_heads",), init="ones")
    s.param("out_proj", (di, d_model), ("ssm_heads", "embed"))


def _split_proj(proj: jax.Array, di: int, ds: int, nh: int):
    z = proj[..., :di]
    xBC = proj[..., di:2 * di + 2 * ds]
    dt = proj[..., 2 * di + 2 * ds:]
    assert dt.shape[-1] == nh
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, bias: jax.Array,
                 history: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d. xBC: (b, s, ch); w: (width, ch)."""
    width = w.shape[0]
    if history is None:
        pad = jnp.zeros((xBC.shape[0], width - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = history.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)                    # (b, s+w-1, ch)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + bias)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float = 1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)


def ssd_chunked(xs: jax.Array, dt: jax.Array, a: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xs: (b, s, nh, hd); dt: (b, s, nh); a: (nh,) negative;
    B, C: (b, s, ds).  Returns (y (b, s, nh, hd), final_state (b, nh, ds, hd)).
    """
    b, s, nh, hd = xs.shape
    ds = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xs = xs.reshape(b, nc, chunk, nh, hd).astype(jnp.float32)
    dt = dt.reshape(b, nc, chunk, nh).astype(jnp.float32)
    B = B.reshape(b, nc, chunk, ds).astype(jnp.float32)
    C = C.reshape(b, nc, chunk, ds).astype(jnp.float32)

    ll = dt * a                                              # (b, nc, L, nh) log-decay
    cum = jnp.cumsum(ll, axis=2)                             # inclusive
    total = cum[:, :, -1]                                    # (b, nc, nh)

    # within-chunk (diagonal blocks)
    cb = jnp.einsum("bnls,bnms->bnlm", C, B)                 # (b, nc, L, L)
    dmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (b, nc, L, L, nh) i,j
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    att = jnp.where(mask[None, None, :, :, None],
                    jnp.exp(dmat), 0.0) * cb[..., None] * dt[:, :, None, :, :]
    y_diag = jnp.einsum("bnlmh,bnmhd->bnlhd", att, xs)

    # chunk end-states
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)       # (b, nc, L, nh)
    states = jnp.einsum("bnlh,bnls,bnlhd->bnhsd",
                        decay_to_end * dt, B, xs)            # (b, nc, nh, ds, hd)

    # inter-chunk recurrence
    s0 = jnp.zeros((b, nh, ds, hd), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(carry, inp):
        st, tot = inp                                        # (b,nh,ds,hd), (b,nh)
        prev = carry
        new = jnp.exp(tot)[:, :, None, None] * prev + st
        return new, prev

    final, prevs = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    prevs = prevs.transpose(1, 0, 2, 3, 4)                   # (b, nc, nh, ds, hd)

    # off-diagonal: contribution of previous chunks' state
    y_off = jnp.einsum("bnls,bnhsd,bnlh->bnlhd", C, prevs, jnp.exp(cum))
    y = (y_diag + y_off).reshape(b, s, nh, hd)
    return y, final


def ssd_reference(xs, dt, a, B, C, init_state=None):
    """Naive per-step recurrence (oracle)."""
    b, s, nh, hd = xs.shape
    ds = B.shape[-1]
    st = jnp.zeros((b, nh, ds, hd), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)
    xs, dt, B, C = (t.astype(jnp.float32) for t in (xs, dt, B, C))

    def step(st, inp):
        x_t, dt_t, b_t, c_t = inp                            # (b,nh,hd),(b,nh),(b,ds),(b,ds)
        da = jnp.exp(dt_t * a)                               # (b, nh)
        st = da[:, :, None, None] * st + jnp.einsum(
            "bh,bs,bhd->bhsd", dt_t, b_t, x_t)
        y = jnp.einsum("bs,bhsd->bhd", c_t, st)
        return st, y

    st, ys = jax.lax.scan(step, st,
                          (xs.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                           B.transpose(1, 0, 2), C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), st


def ssm_layer(params, x: jax.Array, cfg: SSMConfig, d_model: int, compute_dtype,
              state: Optional[SSMState] = None
              ) -> Tuple[jax.Array, Optional[SSMState]]:
    """Full Mamba2 mixer. x: (b, s, d). state given => decode (s == 1)."""
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    ds = cfg.d_state
    hd = cfg.head_dim

    proj = jnp.einsum("bsd,dk->bsk", x, params["in_proj"].astype(compute_dtype))
    z, xBC, dt_raw = _split_proj(proj, di, ds, nh)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))        # (nh,) < 0
    D = params["D"].astype(jnp.float32)

    if state is None:
        xBC = _causal_conv(xBC, params["conv_w"].astype(compute_dtype),
                           params["conv_b"].astype(compute_dtype))
        xin, B, C = xBC[..., :di], xBC[..., di:di + ds], xBC[..., di + ds:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + params["dt_bias"].astype(jnp.float32))
        xs = xin.reshape(*xin.shape[:2], nh, hd)
        s_len = xs.shape[1]
        pad = (-s_len) % cfg.chunk_size
        if pad:
            # dt is padded AFTER softplus: dt=0 => decay 1, contribution 0
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B_p = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
            C_p = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
            y, _ = ssd_chunked(xs_p, dt_p, a, B_p, C_p, cfg.chunk_size)
            y = y[:, :s_len]
        else:
            y, _ = ssd_chunked(xs, dt, a, B, C, cfg.chunk_size)
        y = y + D[:, None] * xs.astype(jnp.float32)
        y = y.reshape(*x.shape[:2], di)
        out = _gated_norm(y, z, params["norm_scale"])
        new_state = None
    else:
        # decode: O(1) recurrent update
        hist = state.conv
        xBC_t = _causal_conv(xBC, params["conv_w"].astype(compute_dtype),
                             params["conv_b"].astype(compute_dtype),
                             history=hist)
        new_conv = jnp.concatenate([hist[:, 1:], xBC.astype(hist.dtype)], axis=1)
        xin, B, C = xBC_t[..., :di], xBC_t[..., di:di + ds], xBC_t[..., di + ds:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + params["dt_bias"].astype(jnp.float32))
        xs = xin.reshape(x.shape[0], nh, hd).astype(jnp.float32)
        dt1 = dt[:, 0]                                       # (b, nh)
        b1 = B[:, 0].astype(jnp.float32)
        c1 = C[:, 0].astype(jnp.float32)
        da = jnp.exp(dt1 * a)
        s_new = da[:, :, None, None] * state.s.astype(jnp.float32) + \
            jnp.einsum("bh,bs,bhd->bhsd", dt1, b1, xs)
        y = jnp.einsum("bs,bhsd->bhd", c1, s_new) + D[:, None] * xs
        y = y.reshape(x.shape[0], 1, di)
        out = _gated_norm(y, z, params["norm_scale"])
        new_state = SSMState(s_new.astype(state.s.dtype), new_conv)

    y_out = jnp.einsum("bsk,kd->bsd", out.astype(compute_dtype),
                       params["out_proj"].astype(compute_dtype))
    return y_out.astype(x.dtype), new_state


def init_ssm_state(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32
                   ) -> SSMState:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    return SSMState(
        s=jnp.zeros((batch, nh, cfg.d_state, cfg.head_dim), dtype),
        conv=jnp.zeros((batch, cfg.conv_width - 1, di + 2 * cfg.d_state), dtype),
    )
