"""Top-k mixture-of-experts FFN with capacity-based scatter dispatch.

Expert weights live under the ``experts`` scope and are the one part of the
parameter tree that does NOT get a WASGD worker dimension: they are a single
expert-parallel copy sharded over the worker ("data") axis (DESIGN.md §4.1).
Token dispatch across that axis is what produces the all-to-all traffic in
the dry-run HLO.

Dispatch is sort-based: tokens are ranked within their expert via an argsort
over expert ids, dropped beyond capacity, scattered into an (E, C, d) buffer,
processed by a gated MLP einsum over all experts, and combined back with
router gates. This is the standard capacity-factor formulation (Switch/GShard
lineage) expressed in pure ``jax.lax`` ops so it lowers on any backend.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.param import ParamBuilder


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array


def moe_init(b: ParamBuilder, name: str, d_model: int, m: MoEConfig):
    s = b.scope(name)
    s.param("router", (d_model, m.n_experts), ("embed", None), scale=0.02)
    e = s.scope("experts")
    e.param("w_gate", (m.n_experts, d_model, m.d_ff_expert),
            ("experts", "embed", "expert_ffn"))
    e.param("w_up", (m.n_experts, d_model, m.d_ff_expert),
            ("experts", "embed", "expert_ffn"))
    e.param("w_down", (m.n_experts, m.d_ff_expert, d_model),
            ("experts", "expert_ffn", "embed"))


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)          # round up to a multiple of 8


def moe_ffn(params, x: jax.Array, m: MoEConfig, compute_dtype
            ) -> Tuple[jax.Array, MoEAux]:
    """x: (b, s, d) -> (b, s, d) plus auxiliary losses."""
    b, s, d = x.shape
    T = b * s
    E, K = m.n_experts, m.top_k
    C = _capacity(T, m)
    xf = x.reshape(T, d)

    router = params["router"].astype(jnp.float32)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                  # renormalize

    # -- aux losses (Switch-style) ---------------------------------------------
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros(E).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    load_balance = E * jnp.sum(me * ce) * m.load_balance_loss
    z_loss = m.router_z_loss * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # -- rank tokens within their expert (sort-based) ----------------------------
    flat_e = expert_idx.reshape(-1)                              # (T*K,)
    order = jnp.argsort(flat_e, stable=True)                     # slots sorted by expert
    sorted_e = flat_e[order]
    # position within the expert segment:
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(T * K) - seg_start[sorted_e]
    rank = jnp.zeros(T * K, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)             # E*C = drop bin

    # -- dispatch: scatter tokens into (E*C+1, d) ----------------------------------
    tok_of_slotk = jnp.repeat(jnp.arange(T), K)                  # (T*K,)
    buf = jnp.zeros((E * C + 1, d), compute_dtype)
    buf = buf.at[slot].add(xf.astype(compute_dtype)[tok_of_slotk])
    buf = buf[: E * C].reshape(E, C, d)

    # -- expert computation (gated MLP over all experts) -----------------------------
    ep = params["experts"]
    g = jnp.einsum("ecd,edf->ecf", buf, ep["w_gate"].astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, ep["w_up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, ep["w_down"].astype(compute_dtype))

    # -- combine: gather back and weight by gates --------------------------------------
    out_flat = out_buf.reshape(E * C, d)
    safe_slot = jnp.minimum(slot, E * C - 1)
    gathered = jnp.where(keep[:, None], out_flat[safe_slot], 0.0)  # (T*K, d)
    combined = (gathered.reshape(T, K, d)
                * gate_vals[..., None].astype(compute_dtype)).sum(axis=1)

    aux = MoEAux(load_balance, z_loss,
                 1.0 - keep.astype(jnp.float32).mean())
    return combined.reshape(b, s, d).astype(x.dtype), aux
