"""Composable decoder assembly covering all six assigned families.

One ``ModelConfig`` drives which sub-layers each block gets:

* dense / audio / vlm   — GQA self-attention (+ gated cross-attention for
  VLM layers) + gated MLP
* moe                   — GQA self-attention + top-k MoE FFN
                          (+ dense residual MLP for arctic)
* ssm                   — Mamba2 SSD mixer only
* hybrid (jamba)        — 1:7 attention:mamba interleave, MoE every other
                          layer, dense FFN otherwise

``init_params`` / ``abstract_params`` produce the parameter tree plus a
parallel logical-axes tree (see models/param.py). ``forward`` is the training
path, ``prefill``/``decode_step`` the serving path with per-layer caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.attention import (KVCache, cross_attention,
                                    cross_attention_init, decode_attention,
                                    self_attention, attention_init)
from repro.models.param import ParamBuilder, build, build_abstract


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(b: ParamBuilder, cfg: ModelConfig, i: int):
    s = b.scope(f"L{i}")
    d = cfg.d_model
    if cfg.layer_is_attn(i):
        L.rmsnorm_init(s, "attn_norm", d)
        attention_init(s, "attn", d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
        if cfg.layer_is_cross_attn(i):
            L.rmsnorm_init(s, "cross_norm", d)
            cross_attention_init(s, "cross", d, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim)
            s.param("cross_gate", (1,), (None,), init="zeros")
    if cfg.layer_is_ssm(i):
        L.rmsnorm_init(s, "ssm_norm", d)
        SSM.ssm_init(s, "ssm", d, cfg.ssm)
    if cfg.layer_is_moe(i):
        L.rmsnorm_init(s, "ffn_norm", d)
        MOE.moe_init(s, "moe", d, cfg.moe)
        if cfg.moe.dense_residual and cfg.d_ff > 0:
            L.mlp_init(s, "dense_mlp", d, cfg.d_ff)
    elif cfg.d_ff > 0 and cfg.layer_is_attn(i):
        L.rmsnorm_init(s, "ffn_norm", d)
        L.mlp_init(s, "mlp", d, cfg.d_ff)
    elif cfg.d_ff > 0 and cfg.layer_is_ssm(i) and cfg.family == "hybrid":
        L.rmsnorm_init(s, "ffn_norm", d)
        L.mlp_init(s, "mlp", d, cfg.d_ff)


def _init_model(b: ParamBuilder, cfg: ModelConfig):
    L.embed_init(b, "embed", cfg.padded_vocab, cfg.d_model, cfg.n_codebooks)
    lb = b.scope("layers")
    for i in range(cfg.n_layers):
        _init_layer(lb, cfg, i)
    L.rmsnorm_init(b, "final_norm", cfg.d_model)
    if not cfg.tie_embeddings:
        L.head_init(b, "head", cfg.d_model, cfg.padded_vocab, cfg.n_codebooks)


def init_params(cfg: ModelConfig, key: jax.Array, param_dtype=None):
    dtype = jnp.dtype(param_dtype or cfg.param_dtype)
    return build(functools.partial(_init_model, cfg=cfg), key, dtype)


def abstract_params(cfg: ModelConfig, param_dtype=None):
    dtype = jnp.dtype(param_dtype or cfg.param_dtype)
    return build_abstract(functools.partial(_init_model, cfg=cfg), dtype)


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

class LayerAux(NamedTuple):
    moe_loss: jax.Array


def _apply_layer(cfg: ModelConfig, lp: Dict, x: jax.Array, positions: jax.Array,
                 media: Optional[jax.Array], i: int, compute_dtype,
                 cache: Optional[Dict] = None,
                 cache_index: Optional[jax.Array] = None):
    new_cache: Dict[str, Any] = {}
    moe_loss = jnp.zeros((), jnp.float32)

    if cfg.layer_is_attn(i):
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        kv = cache.get("kv") if cache is not None else None
        y, kv_new = self_attention(
            lp["attn"], h, positions,
            rope_theta=cfg.rope_theta,
            window=cfg.window_for_layer(i),
            compute_dtype=compute_dtype,
            cache=kv, cache_index=cache_index,
            unroll=cfg.unroll_attn_scan,
            windowed_qblock=cfg.windowed_qblock)
        x = x + y
        if kv_new is not None:
            new_cache["kv"] = kv_new
        if cfg.layer_is_cross_attn(i) and media is not None:
            h = L.rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
            y = cross_attention(lp["cross"], h, media,
                                compute_dtype=compute_dtype,
                                unroll=cfg.unroll_attn_scan)
            x = x + jnp.tanh(lp["cross_gate"].astype(x.dtype)) * y

    if cfg.layer_is_ssm(i):
        h = L.rmsnorm(lp["ssm_norm"], x, cfg.norm_eps)
        st = cache.get("ssm") if cache is not None else None
        y, st_new = SSM.ssm_layer(lp["ssm"], h, cfg.ssm, cfg.d_model,
                                  compute_dtype, state=st)
        x = x + y
        if st_new is not None:
            new_cache["ssm"] = st_new

    if cfg.layer_is_moe(i):
        h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        y, aux = MOE.moe_ffn(lp["moe"], h, cfg.moe, compute_dtype)
        if cfg.moe.dense_residual and "dense_mlp" in lp:
            y = y + L.mlp(lp["dense_mlp"], h, compute_dtype)
        x = x + y
        moe_loss = aux.load_balance_loss + aux.router_z_loss
    elif "mlp" in lp:
        h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h, compute_dtype)

    return x, new_cache, LayerAux(moe_loss)


# ---------------------------------------------------------------------------
# Training / scoring forward
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            media: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """tokens: (b, s) int32 — or (b, s, n_codebooks) for audio.
    Returns (logits, total_moe_aux_loss)."""
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], tokens, compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    moe_loss = jnp.zeros((), jnp.float32)

    for i in range(cfg.n_layers):
        lp = params["layers"][f"L{i}"]

        def run(lp, x, media, i=i):
            return _apply_layer(cfg, lp, x, positions, media, i, compute_dtype)

        if cfg.remat:
            run = jax.checkpoint(run, static_argnums=())
        x, _, aux = run(lp, x, media)
        moe_loss = moe_loss + aux.moe_loss

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.tied_head(params["embed"], x, compute_dtype,
                             cfg.logits_softcap)
    else:
        logits = L.head(params["head"], x, compute_dtype, cfg.logits_softcap)
    return logits, moe_loss


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
    """Next-token cross-entropy (+ MoE aux). batch: tokens, labels[, media]."""
    logits, moe_loss = forward(cfg, params, batch["tokens"],
                               batch.get("media"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if cfg.sharded_ce:
        # vocab-sharded friendly CE: logsumexp + one-hot contraction keep the
        # vocab dim a reduction (partial-sum + tiny all-reduce) instead of a
        # gather that forces a full-logits all-gather under SPMD (§Perf).
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
        label_logit = jnp.einsum("...v,...v->...", logits, onehot)
        nll = lse - label_logit
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = nll.mean()
    loss = ce + moe_loss
    return loss, {"ce": ce, "moe_loss": moe_loss}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    cache: Dict[str, Dict] = {}
    for i in range(cfg.n_layers):
        entry: Dict[str, Any] = {}
        if cfg.layer_is_attn(i):
            w = cfg.window_for_layer(i)
            size = min(w, max_len) if w is not None else max_len
            entry["kv"] = KVCache(
                k=jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
                v=jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype))
            if cfg.layer_is_cross_attn(i):
                entry["cross"] = KVCache(
                    k=jnp.zeros((batch, cfg.n_media_tokens, cfg.n_kv_heads,
                                 cfg.head_dim), dtype),
                    v=jnp.zeros((batch, cfg.n_media_tokens, cfg.n_kv_heads,
                                 cfg.head_dim), dtype))
        if cfg.layer_is_ssm(i):
            entry["ssm"] = SSM.init_ssm_state(batch, cfg.d_model, cfg.ssm,
                                              jnp.float32)
        cache[f"L{i}"] = entry
    return cache


def cache_axes(cfg: ModelConfig, long_context: bool = False) -> Dict:
    """Logical axes tree matching ``init_cache`` output."""
    kv_seq = "kv_seq"
    ax: Dict[str, Dict] = {}
    for i in range(cfg.n_layers):
        entry: Dict[str, Any] = {}
        if cfg.layer_is_attn(i):
            spec = ("batch", kv_seq, "kv_heads", "head_dim")
            entry["kv"] = KVCache(k=spec, v=spec)
            if cfg.layer_is_cross_attn(i):
                mspec = ("batch", "media", "kv_heads", "head_dim")
                entry["cross"] = KVCache(k=mspec, v=mspec)
        if cfg.layer_is_ssm(i):
            entry["ssm"] = SSM.SSMState(
                s=("batch", "ssm_heads", "ssm_state", None),
                conv=("batch", None, "ssm_heads"))
        ax[f"L{i}"] = entry
    return ax


def _ring_slot(i_cfg_window: Optional[int], index: jax.Array) -> jax.Array:
    if i_cfg_window is None:
        return index
    return jnp.mod(index, i_cfg_window)


def decode_step(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                cache: Dict, index: jax.Array,
                media: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict]:
    """One new token per sequence. tokens: (b, 1) (or (b, 1, n_q) audio);
    ``index`` is the number of tokens already in the cache."""
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], tokens, compute_dtype)
    positions = jnp.full(x.shape[:2], index, jnp.int32)
    new_cache: Dict[str, Dict] = {}

    for i in range(cfg.n_layers):
        lp = params["layers"][f"L{i}"]
        entry = cache[f"L{i}"]
        out_entry: Dict[str, Any] = dict(entry)
        if cfg.layer_is_attn(i):
            h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
            w = cfg.window_for_layer(i)
            kv = entry["kv"]
            size = kv.k.shape[1]
            wq = lp["attn"]["wq"].astype(compute_dtype)
            wk = lp["attn"]["wk"].astype(compute_dtype)
            wv = lp["attn"]["wv"].astype(compute_dtype)
            wo = lp["attn"]["wo"].astype(compute_dtype)
            q = L.apply_rope(jnp.einsum("bsd,dhk->bshk", h, wq), positions,
                             cfg.rope_theta)
            k = L.apply_rope(jnp.einsum("bsd,dhk->bshk", h, wk), positions,
                             cfg.rope_theta)
            v = jnp.einsum("bsd,dhk->bshk", h, wv)
            slot = jnp.mod(index, size) if w is not None else index
            k_c = jax.lax.dynamic_update_slice_in_dim(
                kv.k, k.astype(kv.k.dtype), slot, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(
                kv.v, v.astype(kv.v.dtype), slot, axis=1)
            eff_len = jnp.minimum(index + 1, size)
            att = decode_attention(q, k_c, v_c, eff_len, window=None)
            x = x + jnp.einsum("bshk,hkd->bsd", att, wo)
            out_entry["kv"] = KVCache(k_c, v_c)
            if cfg.layer_is_cross_attn(i) and "cross" in entry:
                h = L.rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
                ck = entry["cross"]
                cq = jnp.einsum("bsd,dhk->bshk", h,
                                lp["cross"]["wq"].astype(compute_dtype))
                catt = decode_attention(cq, ck.k, ck.v,
                                        jnp.int32(ck.k.shape[1]), window=None)
                y = jnp.einsum("bshk,hkd->bsd", catt,
                               lp["cross"]["wo"].astype(compute_dtype))
                x = x + jnp.tanh(lp["cross_gate"].astype(x.dtype)) * y
        if cfg.layer_is_ssm(i):
            h = L.rmsnorm(lp["ssm_norm"], x, cfg.norm_eps)
            y, st = SSM.ssm_layer(lp["ssm"], h, cfg.ssm, cfg.d_model,
                                  compute_dtype, state=entry["ssm"])
            x = x + y
            out_entry["ssm"] = st
        if cfg.layer_is_moe(i):
            h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            y, _ = MOE.moe_ffn(lp["moe"], h, cfg.moe, compute_dtype)
            if cfg.moe.dense_residual and "dense_mlp" in lp:
                y = y + L.mlp(lp["dense_mlp"], h, compute_dtype)
            x = x + y
        elif "mlp" in lp:
            h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            x = x + L.mlp(lp["mlp"], h, compute_dtype)
        new_cache[f"L{i}"] = out_entry

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.tied_head(params["embed"], x, compute_dtype,
                             cfg.logits_softcap)
    else:
        logits = L.head(params["head"], x, compute_dtype, cfg.logits_softcap)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array, cache: Dict,
            media: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Fill the cache from a full prompt; returns (last-position logits, cache)."""
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], tokens, compute_dtype)
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), x.shape[:2])
    new_cache: Dict[str, Dict] = {}

    for i in range(cfg.n_layers):
        lp = params["layers"][f"L{i}"]
        entry = cache[f"L{i}"]
        out_entry: Dict[str, Any] = dict(entry)
        if cfg.layer_is_attn(i):
            h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
            w = cfg.window_for_layer(i)
            kv = entry["kv"]
            size = kv.k.shape[1]
            wq = lp["attn"]["wq"].astype(compute_dtype)
            wk = lp["attn"]["wk"].astype(compute_dtype)
            wv = lp["attn"]["wv"].astype(compute_dtype)
            wo = lp["attn"]["wo"].astype(compute_dtype)
            q = L.apply_rope(jnp.einsum("bsd,dhk->bshk", h, wq), positions,
                             cfg.rope_theta)
            k = L.apply_rope(jnp.einsum("bsd,dhk->bshk", h, wk), positions,
                             cfg.rope_theta)
            v = jnp.einsum("bsd,dhk->bshk", h, wv)
            from repro.models.attention import (flash_attention,
                                                flash_attention_windowed)
            if cfg.windowed_qblock and w is not None:
                att = flash_attention_windowed(q, k, v, window=w)
            else:
                att = flash_attention(q, k, v, causal=True, window=w,
                                      unroll=cfg.unroll_attn_scan)
            x = x + jnp.einsum("bshk,hkd->bsd", att, wo)
            if w is not None and s >= size:
                # ring layout: slot of token p is p % size
                k_tail = jnp.roll(k[:, -size:], s % size, axis=1)
                v_tail = jnp.roll(v[:, -size:], s % size, axis=1)
                out_entry["kv"] = KVCache(k_tail.astype(kv.k.dtype),
                                          v_tail.astype(kv.v.dtype))
            else:
                k_c = jax.lax.dynamic_update_slice_in_dim(
                    kv.k, k.astype(kv.k.dtype), 0, axis=1)
                v_c = jax.lax.dynamic_update_slice_in_dim(
                    kv.v, v.astype(kv.v.dtype), 0, axis=1)
                out_entry["kv"] = KVCache(k_c, v_c)
            if cfg.layer_is_cross_attn(i) and media is not None:
                h = L.rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
                y = cross_attention(lp["cross"], h, media,
                                    compute_dtype=compute_dtype,
                                    unroll=cfg.unroll_attn_scan)
                x = x + jnp.tanh(lp["cross_gate"].astype(x.dtype)) * y
                ck = jnp.einsum("bmd,dhk->bmhk", media,
                                lp["cross"]["wk"].astype(compute_dtype))
                cv = jnp.einsum("bmd,dhk->bmhk", media,
                                lp["cross"]["wv"].astype(compute_dtype))
                old = entry["cross"]
                out_entry["cross"] = KVCache(ck.astype(old.k.dtype),
                                             cv.astype(old.v.dtype))
        if cfg.layer_is_ssm(i):
            h = L.rmsnorm(lp["ssm_norm"], x, cfg.norm_eps)
            di = cfg.ssm.d_inner(cfg.d_model)
            nh = cfg.ssm.n_heads(cfg.d_model)
            ds = cfg.ssm.d_state
            proj = jnp.einsum("bsd,dk->bsk", h,
                              lp["ssm"]["in_proj"].astype(compute_dtype))
            z, xBC, dt_raw = SSM._split_proj(proj, di, ds, nh)
            xBC_c = SSM._causal_conv(xBC, lp["ssm"]["conv_w"].astype(compute_dtype),
                                     lp["ssm"]["conv_b"].astype(compute_dtype))
            xin, B, C = xBC_c[..., :di], xBC_c[..., di:di + ds], xBC_c[..., di + ds:]
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                                 + lp["ssm"]["dt_bias"].astype(jnp.float32))
            a = -jnp.exp(lp["ssm"]["A_log"].astype(jnp.float32))
            xs = xin.reshape(*xin.shape[:2], nh, cfg.ssm.head_dim)
            pad = (-s) % cfg.ssm.chunk_size
            if pad:
                xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
                dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
                B_p = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
                C_p = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
            else:
                xs_p, dt_p, B_p, C_p = xs, dt, B, C
            y, final_state = SSM.ssd_chunked(xs_p, dt_p, a, B_p, C_p,
                                             cfg.ssm.chunk_size)
            y = y[:, :s] + \
                lp["ssm"]["D"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
            y = y.reshape(x.shape[0], s, di)
            out = SSM._gated_norm(y, z, lp["ssm"]["norm_scale"])
            x = x + jnp.einsum("bsk,kd->bsd", out.astype(compute_dtype),
                               lp["ssm"]["out_proj"].astype(compute_dtype)
                               ).astype(x.dtype)
            conv_hist = jnp.concatenate(
                [jnp.zeros((x.shape[0], max(0, cfg.ssm.conv_width - 1 - s),
                            di + 2 * ds), jnp.float32),
                 xBC[:, -(cfg.ssm.conv_width - 1):].astype(jnp.float32)], axis=1)
            out_entry["ssm"] = SSM.SSMState(final_state.astype(jnp.float32),
                                            conv_hist)
        if cfg.layer_is_moe(i):
            h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            y, _ = MOE.moe_ffn(lp["moe"], h, cfg.moe, compute_dtype)
            if cfg.moe.dense_residual and "dense_mlp" in lp:
                y = y + L.mlp(lp["dense_mlp"], h, compute_dtype)
            x = x + y
        elif "mlp" in lp:
            h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            x = x + L.mlp(lp["mlp"], h, compute_dtype)
        new_cache[f"L{i}"] = out_entry

    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.tied_head(params["embed"], x, compute_dtype,
                             cfg.logits_softcap)
    else:
        logits = L.head(params["head"], x, compute_dtype, cfg.logits_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Serving: paged cache layout + decode
# ---------------------------------------------------------------------------

class PagedKV(NamedTuple):
    """Per-layer K/V block pools, shape (n_pool, block_size, kv_heads,
    head_dim). The last pool row is the trash block inactive slots write
    into; every other row is addressed through a per-request block table."""
    k: jax.Array
    v: jax.Array


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def cache_layout(cfg: ModelConfig, max_len: int, block_size: int = 16) -> Dict:
    """Static paged-cache geometry for ``cfg``.

    Layers fall into *layout groups* that share one block table per request:

    * ``"full"`` — full-attention layers; token position ``p`` is logical
      slot ``p``, the table has ``ceil(max_len / block_size)`` entries and
      is populated by a free-list allocator at admission.
    * ``"ring{R}"`` — sliding-window layers with the window's ring capacity
      padded to a block multiple ``R``; position ``p`` lives at slot
      ``p % R``. Every slot is always live, so ring tables are static
      (each batch slot permanently owns its ``R / block_size`` blocks).

    Block ids are valid across all layers of a group: each layer has its own
    K/V pool, indexed by the same table. Cross-attention (media) layers have
    no paged form — serve those archs with the legacy ``ServeEngine``.
    """
    layers: Dict[str, Dict] = {}
    groups: Dict[str, Dict] = {}
    for i in range(cfg.n_layers):
        ent: Dict[str, Any] = {}
        if cfg.layer_is_cross_attn(i):
            raise NotImplementedError(
                "paged cache does not cover cross-attention (media) layers; "
                "use the legacy ServeEngine for media archs")
        if cfg.layer_is_attn(i):
            w = cfg.window_for_layer(i)
            size = min(w, max_len) if w is not None else max_len
            if w is not None:
                ring = _ceil_to(size, block_size)
                group = f"ring{ring}"
                groups.setdefault(group,
                                  {"ring": ring,
                                   "n_blk": ring // block_size})
            else:
                ring = None
                group = "full"
                groups.setdefault(group,
                                  {"ring": None,
                                   "n_blk": _ceil_to(max_len, block_size)
                                   // block_size})
            ent["attn"] = {"group": group, "ring": ring, "window": size}
        if cfg.layer_is_ssm(i):
            ent["ssm"] = True
        layers[f"L{i}"] = ent
    return {"layers": layers, "groups": groups, "block_size": block_size,
            "max_len": max_len}


def decode_step_paged(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                      pools: Dict, tables: Dict, index: jax.Array,
                      active: Optional[jax.Array] = None, *,
                      max_len: int, block_size: int = 16
                      ) -> Tuple[jax.Array, Dict]:
    """One decode step against the paged cache; batch rows are independent
    requests at independent positions.

    tokens (n, 1); ``index`` (n,) int32 is the position each row's token is
    written at (== tokens already cached); ``tables`` maps layout-group name
    to (n, n_blk) int32 physical block ids; ``pools`` maps ``L{i}`` to
    ``{"attn": PagedKV}`` / ``{"ssm": SSMState}`` with leading pool / slot
    dims. ``active`` (n,) bool, when given, redirects inactive rows' KV
    writes to the trash block (last pool row) and freezes their SSM state,
    so finished requests can ride in the batch without corrupting anything.
    """
    layout = cache_layout(cfg, max_len, block_size)
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embed"], tokens, compute_dtype)
    n = x.shape[0]
    index = jnp.asarray(index, jnp.int32)
    positions = index[:, None]
    rows = jnp.arange(n)
    from repro.kernels.decode_attn.ops import paged_decode_attention
    new_pools: Dict[str, Dict] = {}

    for i in range(cfg.n_layers):
        lp = params["layers"][f"L{i}"]
        entry = pools[f"L{i}"]
        out_entry: Dict[str, Any] = dict(entry)
        if cfg.layer_is_attn(i):
            al = layout["layers"][f"L{i}"]["attn"]
            h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
            wq = lp["attn"]["wq"].astype(compute_dtype)
            wk = lp["attn"]["wk"].astype(compute_dtype)
            wv = lp["attn"]["wv"].astype(compute_dtype)
            wo = lp["attn"]["wo"].astype(compute_dtype)
            q = L.apply_rope(jnp.einsum("bsd,dhk->bshk", h, wq), positions,
                             cfg.rope_theta)
            k = L.apply_rope(jnp.einsum("bsd,dhk->bshk", h, wk), positions,
                             cfg.rope_theta)
            v = jnp.einsum("bsd,dhk->bshk", h, wv)
            table = tables[al["group"]]
            ring = al["ring"]
            slot = jnp.mod(index, ring) if ring is not None else index
            pb = table[rows, slot // block_size]
            off = jnp.mod(slot, block_size)
            kv = entry["attn"]
            if active is not None:
                pb = jnp.where(active, pb, kv.k.shape[0] - 1)
            k_pool = kv.k.at[pb, off].set(k[:, 0].astype(kv.k.dtype))
            v_pool = kv.v.at[pb, off].set(v[:, 0].astype(kv.v.dtype))
            att = paged_decode_attention(q, k_pool, v_pool, table, index,
                                         ring=ring, window=al["window"])
            x = x + jnp.einsum("bshk,hkd->bsd", att, wo)
            out_entry["attn"] = PagedKV(k_pool, v_pool)
        if cfg.layer_is_ssm(i):
            h = L.rmsnorm(lp["ssm_norm"], x, cfg.norm_eps)
            y, st = SSM.ssm_layer(lp["ssm"], h, cfg.ssm, cfg.d_model,
                                  compute_dtype, state=entry["ssm"])
            x = x + y
            if active is not None:
                st = jax.tree.map(
                    lambda new, old: jnp.where(
                        active.reshape((-1,) + (1,) * (new.ndim - 1)),
                        new, old),
                    st, entry["ssm"])
            out_entry["ssm"] = st
        if cfg.layer_is_moe(i):
            h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            y, _ = MOE.moe_ffn(lp["moe"], h, cfg.moe, compute_dtype)
            if cfg.moe.dense_residual and "dense_mlp" in lp:
                y = y + L.mlp(lp["dense_mlp"], h, compute_dtype)
            x = x + y
        elif "mlp" in lp:
            h = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            x = x + L.mlp(lp["mlp"], h, compute_dtype)
        new_pools[f"L{i}"] = out_entry

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.tied_head(params["embed"], x, compute_dtype,
                             cfg.logits_softcap)
    else:
        logits = L.head(params["head"], x, compute_dtype, cfg.logits_softcap)
    return logits, new_pools
