"""Parameter construction with logical-axis bookkeeping.

``ParamBuilder`` creates parameter leaves and records, in a parallel pytree of
the same structure, the tuple of *logical axis names* for every leaf. The
launcher resolves those names against a mesh + rules table to produce
``NamedSharding``s for ``jax.jit(in_shardings=...)`` — no hand-written
PartitionSpecs anywhere in the model code.

Running an ``init_fn(builder)`` under ``jax.eval_shape`` yields the abstract
parameter tree (ShapeDtypeStructs) *and*, by side effect, the axes tree —
which is how the multi-pod dry-run gets full-size parameter specs without
allocating 480B parameters.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Axes = Tuple[Optional[str], ...]


class ParamBuilder:
    def __init__(self, key: jax.Array, param_dtype=jnp.float32, path: str = "",
                 params: Optional[Dict] = None, axes: Optional[Dict] = None):
        self._key = key
        self.param_dtype = param_dtype
        self._path = path
        self.params: Dict = {} if params is None else params
        self.axes: Dict = {} if axes is None else axes

    # -- scoping --------------------------------------------------------------
    def scope(self, name: str) -> "ParamBuilder":
        sub_p = self.params.setdefault(name, {})
        sub_a = self.axes.setdefault(name, {})
        child = ParamBuilder(self._next_key(), self.param_dtype,
                             f"{self._path}/{name}", sub_p, sub_a)
        return child

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- leaf creation ----------------------------------------------------------
    def param(self, name: str, shape: Tuple[int, ...], axes: Axes,
              init: str = "normal", scale: Optional[float] = None) -> jax.Array:
        assert len(shape) == len(axes), (self._path, name, shape, axes)
        if name in self.params:
            raise ValueError(f"duplicate param {self._path}/{name}")
        if init == "normal":
            std = scale if scale is not None else shape[0] ** -0.5
            v = jax.random.normal(self._next_key(), shape, self.param_dtype) * std
        elif init == "zeros":
            v = jnp.zeros(shape, self.param_dtype)
        elif init == "ones":
            v = jnp.ones(shape, self.param_dtype)
        elif init == "uniform":
            lim = scale if scale is not None else 1.0
            v = jax.random.uniform(self._next_key(), shape, self.param_dtype,
                                   minval=-lim, maxval=lim)
        else:
            raise ValueError(f"unknown init {init!r}")
        self.params[name] = v
        self.axes[name] = axes
        return v


def build(init_fn: Callable[[ParamBuilder], None], key: jax.Array,
          param_dtype=jnp.float32):
    """Run ``init_fn`` concretely; returns (params, axes)."""
    b = ParamBuilder(key, param_dtype)
    init_fn(b)
    return b.params, b.axes


def build_abstract(init_fn: Callable[[ParamBuilder], None], param_dtype=jnp.float32):
    """Shape-only init: returns (ShapeDtypeStruct tree, axes tree). No allocation."""
    axes_box: Dict = {}

    def run(key):
        b = ParamBuilder(key, param_dtype)
        init_fn(b)
        axes_box.update(b.axes)
        return b.params

    shapes = jax.eval_shape(run, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return shapes, axes_box


def add_worker_axis(shapes, axes, n_workers: int, skip: Callable[[str], bool] = None):
    """Prefix every parameter leaf with the WASGD worker dimension.

    ``skip(path)`` selects leaves that stay single-copy (e.g. expert weights
    under expert parallelism — see DESIGN.md §4.1).
    """
    def _walk(s, a, path):
        if isinstance(s, dict):
            return (
                {k: _walk(s[k], a[k], f"{path}/{k}")[0] for k in s},
                {k: _walk(s[k], a[k], f"{path}/{k}")[1] for k in s},
            )
        if skip is not None and skip(path):
            return s, a
        new_s = jax.ShapeDtypeStruct((n_workers,) + tuple(s.shape), s.dtype) \
            if isinstance(s, jax.ShapeDtypeStruct) else \
            jnp.broadcast_to(s, (n_workers,) + s.shape)
        return new_s, ("worker",) + tuple(a)

    return _walk(shapes, axes, "")


def is_expert_path(path: str) -> bool:
    """Leaves that are expert-parallel single copies (no worker dim)."""
    return "/experts/" in path or path.endswith("/experts")
