"""Finalize EXPERIMENTS.md: render the dry-run/roofline/perf tables from the
JSONL artifacts into the placeholder sections.

    PYTHONPATH=src python results/finalize_experiments.py
"""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.report import dryrun_table, fmt_bytes, load, roofline_table  # noqa: E402


def perf_table():
    try:
        perf = [json.loads(l) for l in open("results/dryrun_perf.jsonl")]
    except FileNotFoundError:
        return "(perf runs pending)"
    base = {}
    for line in open("results/dryrun_single.jsonl"):
        r = json.loads(line)
        if r["ok"]:
            base[(r["arch"], r["shape"])] = r
    rows = ["| pair | variant | compute (ms) | memory (ms) | collective (ms) | worker-coll | vs baseline |",
            "|---|---|---|---|---|---|---|"]
    for key in sorted({(r["arch"], r["shape"]) for r in perf}):
        b = base.get(key)
        if b:
            rf = b["roofline"]
            ax = b["collective_by_axis"]
            rows.append(
                f"| {key[0]} x {key[1]} | **baseline** "
                f"| {rf['compute_s']*1e3:.1f} | {rf['memory_s']*1e3:.1f} "
                f"| {rf['collective_s']*1e3:.1f} "
                f"| {fmt_bytes(ax['worker']+ax['unknown'])} | — |")
        for r in perf:
            if (r["arch"], r["shape"]) != key or not r.get("ok"):
                continue
            rf = r["roofline"]
            ax = r["collective_by_axis"]
            delta = ""
            if b:
                dom = b["roofline"]["dominant"]
                before = b["roofline"][dom]
                after = rf[dom]
                delta = f"{dom.replace('_s','')}: {before*1e3:.1f}->{after*1e3:.1f}ms ({(1-after/before)*100:+.0f}%)"
            rows.append(
                f"| | {r['variant']} | {rf['compute_s']*1e3:.1f} "
                f"| {rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.1f} "
                f"| {fmt_bytes(ax['worker']+ax['unknown'])} | {delta} |")
    return "\n".join(rows)


def main():
    recs_single = load(["results/dryrun_single.jsonl"])
    recs_multi = load(["results/dryrun_multi.jsonl"])
    all_recs = {**recs_single, **recs_multi}

    with open("results/dryrun_tables.md", "w") as f:
        ok = sum(r["ok"] for r in all_recs.values())
        f.write(f"## Dry-run matrix ({ok}/{len(all_recs)} OK)\n\n")
        f.write(dryrun_table(all_recs))
        f.write("\n\n## Roofline (single-pod 16x16)\n\n")
        f.write(roofline_table(recs_single))
        f.write("\n\n## Perf variants\n\n")
        f.write(perf_table())
        f.write("\n")

    text = open("EXPERIMENTS.md").read()
    text = text.replace(
        "(table inserted at finalization — see `results/dryrun_tables.md`)",
        dryrun_table(all_recs))
    text = text.replace("(table inserted at finalization)",
                        roofline_table(recs_single))
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md tables written;",
          f"{sum(r['ok'] for r in all_recs.values())}/{len(all_recs)} combos OK")
    print(perf_table())


if __name__ == "__main__":
    main()
