"""Mamba2 SSD: chunked algorithm == naive recurrence; decode == prefill."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SSMConfig
from repro.models.param import build
from repro.models.ssm import (SSMState, init_ssm_state, ssd_chunked,
                              ssd_reference, ssm_init, ssm_layer)
import functools


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_reference(chunk):
    b, s, nh, hd, ds = 2, 32, 3, 8, 5
    key = jax.random.key(0)
    xs = jax.random.normal(key, (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, nh)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (nh,)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, ds))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, ds))

    y_ref, st_ref = ssd_reference(xs, dt, a, B, C)
    y, st = ssd_chunked(xs, dt, a, B, C, chunk)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, st_ref, rtol=1e-4, atol=1e-4)


def test_ssd_chunked_with_init_state():
    b, s, nh, hd, ds, chunk = 1, 16, 2, 4, 3, 4
    key = jax.random.key(5)
    xs = jax.random.normal(key, (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, nh)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (nh,)))
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, ds))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, ds))
    s0 = jax.random.normal(jax.random.fold_in(key, 6), (b, nh, ds, hd))
    y_ref, st_ref = ssd_reference(xs, dt, a, B, C, init_state=s0)
    y, st = ssd_chunked(xs, dt, a, B, C, chunk, init_state=s0)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, st_ref, rtol=1e-4, atol=1e-4)


def test_ssm_layer_decode_matches_train():
    """Running the mixer token-by-token with recurrent state reproduces the
    full (chunked) forward."""
    cfg = SSMConfig(d_state=8, expand=2, head_dim=8, chunk_size=4, conv_width=4)
    d_model, b, s = 16, 2, 12
    params, _ = build(functools.partial(ssm_init, name="ssm", d_model=d_model,
                                        cfg=cfg), jax.random.key(0))
    params = params["ssm"]
    x = jax.random.normal(jax.random.key(1), (b, s, d_model), jnp.float32)

    y_full, _ = ssm_layer(params, x, cfg, d_model, jnp.float32, state=None)

    st = init_ssm_state(b, d_model, cfg, jnp.float32)
    ys = []
    for t in range(s):
        y_t, st = ssm_layer(params, x[:, t:t + 1], cfg, d_model, jnp.float32,
                            state=st)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=5e-3, atol=5e-3)
