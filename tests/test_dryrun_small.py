"""Dry-run machinery on a small placeholder mesh (subprocess so the forced
device count never leaks into other tests). Exercises the same
input_specs -> tree_shardings -> jit(in_shardings).lower().compile() path as
the production dry-run, on reduced configs and a (2, 2) [+ (2, 2, 2)] mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import dataclasses
    import jax
    from repro.configs import SHAPES_BY_NAME, TrainConfig, WASGDConfig, get_smoke_config
    from repro.configs.base import InputShape
    from repro.launch.specs import input_specs
    from repro.launch.hlo import collective_bytes, normalize_cost_analysis
    from repro.parallel.sharding import num_workers, tree_shardings

    arch, shape_kind, multi = json.loads(os.environ["CASE"])
    cfg = get_smoke_config(arch)
    shape = {
        "train": InputShape("t", 32, 16, "train"),
        "prefill": InputShape("p", 32, 4, "prefill"),
        "decode": InputShape("d", 64, 4, "decode"),
    }[shape_kind]

    if multi:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((2, 2), ("data", "model"))
    w = num_workers(mesh)
    tcfg = TrainConfig(wasgd=WASGDConfig(tau=2))
    wl = input_specs(cfg, shape, w, tcfg)
    in_sh = tuple(tree_shardings(mesh, s, a, wl.rules)
                  for s, a in zip(wl.arg_shapes, wl.arg_axes))
    with mesh:
        lowered = jax.jit(wl.fn, in_shardings=in_sh).lower(*wl.arg_shapes)
        compiled = lowered.compile()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    assert cost.get("flops", 0) > 0
    print("RESULT", json.dumps({"ok": True, "coll_total": coll["total"],
                                "workers": w}))
""")


def _run(arch, kind, multi=False):
    env = dict(os.environ, PYTHONPATH=SRC, CASE=json.dumps([arch, kind, multi]))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("arch,kind", [
    ("stablelm-1.6b", "train"),
    ("olmoe-1b-7b", "train"),
    ("mamba2-370m", "train"),
    ("gemma3-1b", "decode"),
    ("yi-6b", "prefill"),
])
def test_small_mesh_dryrun(arch, kind):
    res = _run(arch, kind)
    assert res["ok"] and res["workers"] == 2


def test_small_mesh_multipod_has_worker_collectives():
    res = _run("stablelm-1.6b", "train", multi=True)
    assert res["ok"] and res["workers"] == 4
    # the WASGD aggregation must produce cross-worker traffic
    assert res["coll_total"] > 0


SHARDMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.aggregate import weighted_aggregate
    from repro.core.shardmap_agg import weighted_aggregate_shard_map

    mesh = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))
    w = 8
    params = {"a": jax.random.normal(jax.random.key(0), (w, 16, 8)),
              "experts": {"w_up": jnp.ones((4, 3))}}
    axes = {"a": ("worker", None, None), "experts": {"w_up": ("experts", None)}}
    theta = jax.nn.softmax(jax.random.normal(jax.random.key(1), (w,)))

    sh = NamedSharding(mesh, P(("pod", "data"), None, None))
    params["a"] = jax.device_put(params["a"], sh)
    theta_sh = jax.device_put(theta, NamedSharding(mesh, P(("pod", "data"))))

    with mesh:
        ref = weighted_aggregate(params, axes, theta, 0.8)
        out = jax.jit(lambda p, t: weighted_aggregate_shard_map(
            p, axes, t, 0.8, mesh))(params, theta_sh)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(ref["a"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["experts"]["w_up"]),
                               np.asarray(ref["experts"]["w_up"]))
    print("RESULT ok")
""")


def test_shard_map_aggregation_matches_pjit():
    """Explicit lax.psum shard_map path == the XLA-derived pjit path."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SHARDMAP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT ok" in out.stdout


RSAG_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.aggregate import weighted_aggregate
    from repro.core.shardmap_agg import weighted_aggregate_shard_map
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    params = {"a": jax.random.normal(jax.random.key(0), (8, 13, 7))}
    axes = {"a": ("worker", None, None)}
    theta = jax.nn.softmax(jax.random.normal(jax.random.key(1), (8,)))
    params["a"] = jax.device_put(params["a"],
                                 NamedSharding(mesh, P(("data",), None, None)))
    theta_sh = jax.device_put(theta, NamedSharding(mesh, P(("data",))))
    with mesh:
        ref = weighted_aggregate(params, axes, theta, 0.85)
        f = jax.jit(lambda p, t: weighted_aggregate_shard_map(
            p, axes, t, 0.85, mesh, schedule="rs_ag",
            comm_dtype=jnp.bfloat16))
        out = f(params, theta_sh)
        txt = f.lower(params, theta).compile().as_text()
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(ref["a"]),
                               rtol=2e-2, atol=2e-2)
    assert "reduce-scatter(" in txt and "all-gather(" in txt

    # w/p > 1: 16 worker copies over 8 shards — the local copies must be
    # theta-reduced before the scatter (regression: they used to be
    # concatenated into the scatter dimension, corrupting the aggregate).
    w2 = 16
    params2 = {"a": jax.random.normal(jax.random.key(2), (w2, 13, 7))}
    theta2 = jax.nn.softmax(jax.random.normal(jax.random.key(3), (w2,)))
    params2["a"] = jax.device_put(params2["a"],
                                  NamedSharding(mesh, P(("data",), None, None)))
    theta2_sh = jax.device_put(theta2, NamedSharding(mesh, P(("data",))))
    with mesh:
        ref2 = weighted_aggregate(params2, axes, theta2, 0.85)
        out2 = jax.jit(lambda p, t: weighted_aggregate_shard_map(
            p, axes, t, 0.85, mesh, schedule="rs_ag",
            comm_dtype=jnp.bfloat16))(params2, theta2_sh)
    np.testing.assert_allclose(np.asarray(out2["a"]), np.asarray(ref2["a"]),
                               rtol=2e-2, atol=2e-2)
    print("RESULT ok")
""")


def test_rs_ag_schedule_emits_real_collectives():
    """The reduce-scatter + FMA + all-gather schedule matches Eq. 10 and
    actually lowers to reduce-scatter/all-gather ops with a bf16 payload
    (the §Perf H1 remedy for XLA re-associating the pjit-level convert)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", RSAG_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT ok" in out.stdout
