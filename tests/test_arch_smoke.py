"""Per-architecture smoke tests (assignment deliverable f):

for each of the 10 assigned configs, instantiate the REDUCED variant of the
same family (2-4 layers, d_model <= 512, <= 4 experts) and run one forward +
one WASGD train round on CPU, asserting output shapes and the absence of
NaNs. The FULL configs are exercised only via the dry-run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, TrainConfig, WASGDConfig, get_config, get_smoke_config
from repro.data import lm_batch
from repro.models import forward, init_params
from repro.train import Trainer
from repro.train.lm import make_lm_loss

SEQ = 32          # divisible by every smoke ssm chunk size
P, TAU, BLOCAL = 2, 2, 2
BATCH = P * TAU * BLOCAL


def _batch(cfg, seed=0):
    b = lm_batch(seed, BATCH, SEQ, cfg.vocab_size,
                 n_codebooks=cfg.n_codebooks,
                 media_tokens=cfg.n_media_tokens, d_model=cfg.d_model)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 4
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    params, _ = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits, moe_loss = jax.jit(
        lambda p, t, m: forward(cfg, p, t, m))(
            params, batch["tokens"], batch.get("media"))
    if cfg.n_codebooks > 0:
        assert logits.shape == (BATCH, SEQ, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (BATCH, SEQ, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_round(arch):
    cfg = get_smoke_config(arch)
    params, axes = init_params(cfg, jax.random.key(0))
    tcfg = TrainConfig(learning_rate=1e-2, optimizer="sgd",
                       wasgd=WASGDConfig(tau=TAU, beta=0.9, a_tilde=1.0))
    tr = Trainer(make_lm_loss(cfg), params, axes, tcfg, P, rule="wasgd")
    losses = []
    for r in range(3):
        state, metrics = tr._step(tr.state, _batch(cfg, seed=r))
        tr.state = state
        losses.append(float(metrics["loss"]))
        theta = np.asarray(metrics["theta"])
        np.testing.assert_allclose(theta.sum(), 1.0, rtol=1e-5)
    assert all(np.isfinite(losses)), losses
    # params stay finite after aggregation rounds
    leaves = jax.tree.leaves(tr.state.params)
    assert all(bool(jnp.isfinite(l.astype(jnp.float32)).all()) for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_is_assigned_spec(arch):
    """Pin the full configs to the assigned architecture table."""
    spec = {
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "olmoe-1b-7b": (16, 2048, 16, 16, 0, 50304),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == spec, (got, spec)
    assert cfg.source, "every config must cite its source"


def test_moe_expert_counts():
    assert get_config("olmoe-1b-7b").moe.n_experts == 64
    assert get_config("olmoe-1b-7b").moe.top_k == 8
    assert get_config("arctic-480b").moe.n_experts == 128
    assert get_config("arctic-480b").moe.top_k == 2
    assert get_config("arctic-480b").moe.dense_residual
    assert get_config("jamba-v0.1-52b").moe.n_experts == 16


def test_param_counts_in_family_ballpark():
    """Analytic parameter counts should land near the nameplate sizes."""
    cases = {"yi-6b": (5e9, 8e9), "stablelm-1.6b": (1.2e9, 2.2e9),
             "stablelm-3b": (2.2e9, 4e9), "mamba2-370m": (2.5e8, 5e8),
             "arctic-480b": (3.8e11, 5.6e11), "jamba-v0.1-52b": (4e10, 6.5e10),
             "olmoe-1b-7b": (5e9, 8e9), "gemma3-1b": (0.7e9, 1.6e9)}
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
