"""The two-axis composition grid: every registered ``schedule x codec`` pair
(sync AND under an Alg. 4 straggler mask) must match the ``einsum:f32``
reference within the codec's documented ``error_bound``; ``rs_ag`` with the
``overlap=`` hook engaged must produce leaf-for-leaf IDENTICAL params to the
non-overlapped path; and ``backend="auto"`` must resolve to a runnable spec
from recorded measurements or the size heuristic.

Adapts to however many host devices exist (1 under plain tier-1; the CI
"backends or async or composition or codecs" job forces 8, which gives the
mesh schedules real collectives and w/p > 1 local copies)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs.base import WASGDConfig
from repro.core import backends as B
from repro.core import communicate
from repro.core.codecs import get_codec
from repro.core.weights import masked_compute_theta
from repro.train.step import async_wasgd_rule, wasgd_rule

BETA = 0.9


def _mesh():
    return Mesh(np.array(jax.devices()), ("data",))


def _w():
    return 4 * len(jax.devices())


def _fixture(seed=0):
    w = _w()
    k = jax.random.key(seed)
    # "head" is 33-wide: odd on purpose, to exercise the rs_ag padding path.
    params = {"blk": {"w": jax.random.normal(k, (w, 6, 5))},
              "head": jax.random.normal(jax.random.fold_in(k, 1), (w, 33)),
              "experts": {"up": jnp.ones((3, 2))}}
    axes = {"blk": {"w": ("worker", None, None)},
            "head": ("worker", None),
            "experts": {"up": ("experts", None)}}
    theta = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2), (w,)))
    return params, axes, theta


def _assert_within_bound(out, ref, params, axes, theta, codec_name,
                         beta=BETA, ctx_label=""):
    codec = get_codec(codec_name)
    for key_ in (("blk", "w"), ("head",)):
        x = params[key_[0]][key_[1]] if len(key_) == 2 else params[key_[0]]
        o = out[key_[0]][key_[1]] if len(key_) == 2 else out[key_[0]]
        r = ref[key_[0]][key_[1]] if len(key_) == 2 else ref[key_[0]]
        tol = float(codec.error_bound(x, theta, beta))
        err = float(jnp.abs(o.astype(jnp.float32)
                            - r.astype(jnp.float32)).max())
        assert err <= tol, (ctx_label, key_, err, tol)
    # non-worker leaves pass through untouched for every composition
    np.testing.assert_array_equal(np.asarray(out["experts"]["up"]),
                                  np.asarray(params["experts"]["up"]))


def test_grid_covers_required_specs():
    specs = set(B.available_specs())
    for sched in ("einsum", "hierarchical", "rs_ag", "shard_map",
                  "pallas_wagg"):
        for codec in ("f32", "bf16", "int8", "int4"):
            assert f"{sched}:{codec}" in specs


@pytest.mark.parametrize("spec", B.available_specs())
def test_sync_composition_grid(spec):
    """Every schedule x codec vs the einsum:f32 reference, within the
    codec's documented error bound."""
    params, axes, theta = _fixture()
    ctx = B.AggregationContext(mesh=_mesh(), n_pods=2)
    ref = B.aggregate_with("einsum:f32", params, axes, theta, BETA, ctx=ctx)
    out = B.aggregate_with(spec, params, axes, theta, BETA, ctx=ctx)
    _assert_within_bound(out, ref, params, axes, theta, spec.split(":")[1],
                         ctx_label=spec)


@pytest.mark.parametrize("spec", B.available_specs())
def test_async_composition_grid(spec):
    """The same grid under an Alg. 4 straggler mask: stragglers carry
    theta == 0 and late-join the aggregate, for EVERY composed spec (the
    async family is not a separate backend set anymore — and since the v2
    fused kernel that includes the pallas_wagg specs). The late-join rows
    adopt m wholesale, so the bound is taken at beta=1."""
    params, axes, _ = _fixture()
    w = _w()
    rng = np.random.default_rng(0)
    active_np = np.ones(w, bool)
    active_np[rng.choice(w, max(1, w // 4), replace=False)] = False
    active = jnp.asarray(active_np)
    h = jnp.asarray(rng.uniform(0.1, 2.0, w).astype(np.float32))
    theta = masked_compute_theta(h, active, 1.0, "boltzmann")
    ctx = B.AggregationContext(mesh=_mesh(), n_pods=2, active=active)
    ref = B.aggregate_with("einsum:f32", params, axes, theta, BETA, ctx=ctx)
    out = B.aggregate_with(spec, params, axes, theta, BETA, ctx=ctx)
    _assert_within_bound(out, ref, params, axes, theta, spec.split(":")[1],
                         beta=1.0, ctx_label=f"async:{spec}")


def test_pallas_wagg_masked_all_true_matches_unmasked():
    """Regression: pallas_wagg used to raise on ANY masked context, even a
    concretely all-True mask. The v2 kernel applies the late-join inside
    the VMEM pass, and an all-True mask selects the FMA rows everywhere —
    bitwise identical to the maskless program."""
    params, axes, theta = _fixture()
    ctx = B.AggregationContext(active=jnp.ones((_w(),), bool))
    for spec in ("pallas_wagg", "pallas_wagg:int8"):
        base = B.aggregate_with(spec, params, axes, theta, BETA)
        out = B.aggregate_with(spec, params, axes, theta, BETA, ctx=ctx)
        same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                             np.asarray(b))),
                            base, out)
        assert all(jax.tree.leaves(same)), spec


# ---------------------------------------------------------------------------
# Overlap hook: identical params, thunk ops between the collective phases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["rs_ag:f32", "rs_ag:bf16", "rs_ag:int8",
                                  "hierarchical:int8"])
def test_overlap_params_identical(spec):
    """The overlap thunk's ops straddle the reduce phases but never feed the
    aggregate: params must be leaf-for-leaf IDENTICAL (bitwise), and the
    thunk's result must come back."""
    params, axes, theta = _fixture()
    ctx = B.AggregationContext(mesh=_mesh(), n_pods=2)
    probe = jnp.arange(8.0)

    base = B.aggregate_with(spec, params, axes, theta, BETA, ctx=ctx)
    out, ov = B.aggregate_with(spec, params, axes, theta, BETA, ctx=ctx,
                               overlap=lambda: (probe * 2.0).sum())
    assert float(ov) == float((probe * 2.0).sum())
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        base, out)
    assert all(jax.tree.leaves(same)), spec


def test_overlap_identical_under_jit():
    params, axes, theta = _fixture()
    ctx = B.AggregationContext(mesh=_mesh(), n_pods=2)

    @jax.jit
    def with_overlap(p, t):
        out, ov = B.aggregate_with("rs_ag", p, axes, t, BETA, ctx=ctx,
                                   overlap=lambda: t.max())
        return out, ov

    @jax.jit
    def without(p, t):
        return B.aggregate_with("rs_ag", p, axes, t, BETA, ctx=ctx)

    out, ov = with_overlap(params, theta)
    base = without(params, theta)
    assert float(ov) == float(theta.max())
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        base, out)
    assert all(jax.tree.leaves(same))


def test_wasgd_rule_threads_overlap():
    """train/step.py: the rule built with overlap= returns identical params
    and surfaces the thunk result in metrics["overlap"]."""
    params, axes, theta = _fixture()
    h = jnp.asarray(np.linspace(0.1, 2.0, _w()).astype(np.float32))
    wcfg = WASGDConfig(backend="rs_ag")
    mesh = _mesh()
    plain = wasgd_rule(wcfg, mesh=mesh)
    hooked = wasgd_rule(wcfg, mesh=mesh, overlap=lambda: jnp.float32(7.0))
    p0, _, _, m0 = jax.jit(lambda p, e: plain(p, axes, e, ()))(params, h)
    p1, _, _, m1 = jax.jit(lambda p, e: hooked(p, axes, e, ()))(params, h)
    assert m0 == {} and float(m1["overlap"]) == 7.0
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        p0, p1)
    assert all(jax.tree.leaves(same))


def test_async_wasgd_rule_threads_overlap():
    params, axes, _ = _fixture()
    w = _w()
    h = jnp.asarray(np.linspace(0.1, 2.0, w).astype(np.float32))
    active = jnp.asarray(np.arange(w) % 4 != 1)
    wcfg = WASGDConfig(backend="rs_ag", async_mode="on_device")
    mesh = _mesh()
    plain = async_wasgd_rule(wcfg, mesh=mesh)
    hooked = async_wasgd_rule(wcfg, mesh=mesh,
                              overlap=lambda: jnp.float32(11.0))
    p0, _, _, m0 = jax.jit(lambda p, e, a: plain(p, axes, e, a))(
        params, h, active)
    p1, _, _, m1 = jax.jit(lambda p, e, a: hooked(p, axes, e, a))(
        params, h, active)
    assert float(m1["overlap"]) == 11.0
    np.testing.assert_array_equal(np.asarray(m0["active"]),
                                  np.asarray(m1["active"]))
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        p0, p1)
    assert all(jax.tree.leaves(same))


# ---------------------------------------------------------------------------
# Legacy boolean composition end-to-end + backend="auto"
# ---------------------------------------------------------------------------

def test_legacy_booleans_compose_through_communicate():
    """quantize_comm + sharded_aggregate used to silently drop the mesh
    schedule; it must now run rs_ag:int8 — int8-close to the reference and
    equal to the explicit spec."""
    params, axes, _ = _fixture()
    h = jnp.asarray(np.linspace(0.1, 2.0, _w()).astype(np.float32))
    wcfg = WASGDConfig(quantize_comm=True, sharded_aggregate=True)
    out = communicate(params, axes, h, wcfg, mesh=_mesh())
    explicit = communicate(params, axes, h,
                           WASGDConfig(backend="rs_ag:int8"), mesh=_mesh())
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        out.params, explicit.params)
    assert all(jax.tree.leaves(same))
    ref = communicate(params, axes, h, WASGDConfig())
    err = float(jnp.abs(out.params["head"] - ref.params["head"]).max())
    assert 0 < err < float(get_codec("int8").error_bound(
        params["head"], out.theta, BETA))


def test_auto_heuristic_small_tree_is_einsum_f32():
    params, axes, _ = _fixture()
    assert B.select_auto_spec(params, axes, None,
                              table_path="/nonexistent") == "einsum:f32"


def test_auto_heuristic_large_tree():
    big = {"w": jnp.zeros((4, 1 << 19), jnp.float32)}   # 8 MiB > threshold
    axes = {"w": ("worker", None)}
    assert B.select_auto_spec(big, axes, None,
                              table_path="/nonexistent") == "einsum:bf16"
    assert B.select_auto_spec(big, axes, _mesh(),
                              table_path="/nonexistent") in (
        "rs_ag:bf16", "einsum:bf16")   # rs_ag only on a real (>1 dev) mesh


def test_auto_reads_bench_table(tmp_path):
    """With a recorded BENCH_backend_matrix.json, auto picks the fastest
    non-overlap spec at the nearest (bytes, mesh) point."""
    params, axes, _ = _fixture()
    nbytes = B.worker_leaf_bytes(params, axes)
    table = {"bench": "backend_matrix", "records": [
        {"spec": "hierarchical:int8", "us_per_call": 10.0, "overlap": False,
         "total_bytes": nbytes, "mesh_devices": 1},
        {"spec": "einsum:f32", "us_per_call": 50.0, "overlap": False,
         "total_bytes": nbytes, "mesh_devices": 1},
        # overlap rows and far-away sizes must not win
        {"spec": "einsum:bf16", "us_per_call": 1.0, "overlap": True,
         "total_bytes": nbytes, "mesh_devices": 1},
        {"spec": "rs_ag:f32", "us_per_call": 1.0, "overlap": False,
         "total_bytes": nbytes * 10000, "mesh_devices": 1},
    ]}
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(table))
    assert B.select_auto_spec(params, axes, None, table_path=str(path),
                              n_pods=2) == "hierarchical:int8"
    # mesh-needing specs are skipped when no mesh is available
    table["records"][0]["spec"] = "rs_ag:bf16"
    path.write_text(json.dumps(table))
    assert B.select_auto_spec(params, axes, None, table_path=str(path),
                              n_pods=2) == "einsum:f32"


def test_auto_skips_hierarchical_without_pods(tmp_path):
    """A recorded hierarchical winner must not be selected into a config
    with n_pods=1 (it would fail the schedule's loud pod validation)."""
    params, axes, _ = _fixture()
    nbytes = B.worker_leaf_bytes(params, axes)
    table = {"records": [
        {"spec": "hierarchical:int8", "us_per_call": 1.0, "overlap": False,
         "total_bytes": nbytes, "mesh_devices": 1},
        {"spec": "einsum:int8", "us_per_call": 5.0, "overlap": False,
         "total_bytes": nbytes, "mesh_devices": 1},
    ]}
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(table))
    assert B.select_auto_spec(params, axes, None, table_path=str(path),
                              n_pods=2) == "hierarchical:int8"
    assert B.select_auto_spec(params, axes, None, table_path=str(path),
                              n_pods=1) == "einsum:int8"


def test_auto_ignores_far_off_measurements(tmp_path):
    """A recorded point ~20x away in (bytes x mesh) must not override the
    size heuristic — nearest-neighbor lookup has a distance cutoff."""
    params, axes, _ = _fixture()
    nbytes = B.worker_leaf_bytes(params, axes)
    table = {"records": [
        {"spec": "einsum:int4", "us_per_call": 1.0, "overlap": False,
         "total_bytes": nbytes * 100000, "mesh_devices": 1},
    ]}
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(table))
    # small tree, lone far-off row -> heuristic, not the recorded winner
    assert B.select_auto_spec(params, axes, None,
                              table_path=str(path)) == "einsum:f32"


def test_auto_never_picks_maskless_schedule_for_async(tmp_path, monkeypatch):
    """require_mask=True (the Alg. 4 rounds) excludes schedules registered
    without a late-join path. pallas_wagg IS mask-capable since the v2
    fused kernel — a table where it wins feeds the async rule too — so the
    exclusion is exercised by stripping its supports_mask back off."""
    params, axes, _ = _fixture()
    nbytes = B.worker_leaf_bytes(params, axes)
    table = {"records": [
        {"spec": "pallas_wagg:f32", "us_per_call": 1.0, "overlap": False,
         "total_bytes": nbytes, "mesh_devices": 1},
        {"spec": "einsum:f32", "us_per_call": 5.0, "overlap": False,
         "total_bytes": nbytes, "mesh_devices": 1},
    ]}
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(table))
    assert B.select_auto_spec(params, axes, None,
                              table_path=str(path)) == "pallas_wagg:f32"
    # v2: the fused kernel has a masked path, so async may select it
    assert B.select_auto_spec(params, axes, None, table_path=str(path),
                              require_mask=True) == "pallas_wagg:f32"
    monkeypatch.setattr(B._SCHEDULES["pallas_wagg"], "supports_mask", False)
    assert B.select_auto_spec(params, axes, None, table_path=str(path),
                              require_mask=True) == "einsum:f32"


def test_auto_skips_mesh_schedule_when_workers_dont_divide():
    """4 workers on an 8-shard mesh cannot run a shard_map/rs_ag schedule;
    the heuristic must fall back to the einsum family instead of handing
    back a spec that fails at trace time."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device to make worker count non-divisible")
    w = len(jax.devices()) // 2          # never divides the full mesh
    big = {"w": jnp.zeros((w, 1 << 21), jnp.float32)}    # > 4 MiB
    axes = {"w": ("worker", None)}
    spec = B.select_auto_spec(big, axes, _mesh(), table_path="/nonexistent")
    assert spec == "einsum:bf16"


def test_auto_backend_end_to_end_through_rule(monkeypatch):
    params, axes, _ = _fixture()
    h = jnp.asarray(np.linspace(0.1, 2.0, _w()).astype(np.float32))
    # pin the heuristic path: the committed bench table's timings must not
    # decide which spec this test exercises
    monkeypatch.setattr(B, "AUTO_BENCH_PATH", "/nonexistent")
    rule = wasgd_rule(WASGDConfig(backend="auto"))
    new_params, _, theta, _ = jax.jit(
        lambda p, e: rule(p, axes, e, ()))(params, h)
    ref = B.aggregate_with("einsum:f32", params, axes, theta, BETA)
    err = float(jnp.abs(new_params["head"] - ref["head"]).max())
    assert err < 1e-5        # small tree resolves to einsum:f32


def test_auto_backend_with_recorded_table_runs():
    """With the repo's committed BENCH_backend_matrix.json (when present),
    backend="auto" must still resolve to a runnable spec end-to-end."""
    params, axes, _ = _fixture()
    h = jnp.asarray(np.linspace(0.1, 2.0, _w()).astype(np.float32))
    rule = wasgd_rule(WASGDConfig(backend="auto"))
    new_params, _, theta, _ = rule(params, axes, h, ())
    ref = B.aggregate_with("einsum:f32", params, axes, theta, BETA)
    # whatever spec won, it stays within the loosest codec bound (int4)
    tol = float(get_codec("int4").error_bound(params["head"], theta, 1.0))
    assert float(jnp.abs(new_params["head"] - ref["head"]).max()) <= tol


def test_overlap_pytree_rides_seam():
    """The seam thunk may return any pytree (the pipelined round stages
    whole batches through it), and params stay bitwise-identical."""
    params, axes, theta = _fixture()
    ctx = B.AggregationContext(mesh=_mesh(), n_pods=2)
    probe = {"first": {"x": jnp.arange(6.0).reshape(2, 3),
                       "y": jnp.ones((4,), jnp.int32)},
             "spec_losses": jnp.linspace(0.0, 1.0, 4)}
    base = B.aggregate_with("rs_ag", params, axes, theta, BETA, ctx=ctx)
    out, ov = B.aggregate_with("rs_ag", params, axes, theta, BETA, ctx=ctx,
                               overlap=lambda: probe)
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        probe, ov)
    assert all(jax.tree.leaves(same))
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        base, out)
    assert all(jax.tree.leaves(same))


def test_rule_accepts_call_time_overlap():
    """wasgd_rule's built rule takes a per-call overlap= keyword (the
    pipelined step threads a fresh seam closure every round); the call-time
    thunk overrides the build-time one and params stay identical."""
    params, axes, _ = _fixture()
    h = jnp.asarray(np.linspace(0.1, 2.0, _w()).astype(np.float32))
    rule = wasgd_rule(WASGDConfig(backend="rs_ag"), mesh=_mesh(),
                      overlap=lambda: jnp.float32(1.0))
    p0, _, _, m0 = jax.jit(lambda p, e: rule(p, axes, e, ()))(params, h)
    p1, _, _, m1 = jax.jit(lambda p, e: rule(
        p, axes, e, (), overlap=lambda: {"probe": e.max()}))(params, h)
    assert float(m0["overlap"]) == 1.0
    assert float(m1["overlap"]["probe"]) == float(h.max())
    same = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                         np.asarray(b))),
                        p0, p1)
    assert all(jax.tree.leaves(same))


# ---------------------------------------------------------------------------
# backend="auto" table resolution (cwd-independent + env override + warn-once)
# ---------------------------------------------------------------------------

def test_auto_table_path_is_repo_anchored(tmp_path, monkeypatch):
    """Regression: AUTO_BENCH_PATH was cwd-relative, so auto silently fell
    back to the size heuristic unless the process was launched from the
    repo root. It must be absolute, point into the repo's results/, and
    resolve identically from any cwd."""
    import os
    assert os.path.isabs(B.AUTO_BENCH_PATH)
    assert B.AUTO_BENCH_PATH.endswith(
        os.path.join("results", "BENCH_backend_matrix.json"))
    assert os.path.isdir(os.path.join(B.REPO_ROOT, "src"))
    monkeypatch.chdir(tmp_path)                      # non-root cwd
    monkeypatch.delenv(B.BENCH_TABLE_ENV, raising=False)
    params, axes, _ = _fixture()
    spec = B.select_auto_spec(params, axes, None)    # default table path
    # with the committed table present this is a recorded winner; without
    # it, the heuristic — either way a resolvable, runnable spec.
    assert B.canonical_spec(spec)


def test_auto_table_env_override_from_non_root_cwd(tmp_path, monkeypatch):
    params, axes, _ = _fixture()
    nbytes = B.worker_leaf_bytes(params, axes)
    table = {"records": [
        {"spec": "einsum:int8", "us_per_call": 1.0, "overlap": False,
         "total_bytes": nbytes, "mesh_devices": 1}]}
    p = tmp_path / "table.json"
    p.write_text(json.dumps(table))
    monkeypatch.setenv(B.BENCH_TABLE_ENV, str(p))
    monkeypatch.chdir(tmp_path)
    assert B.select_auto_spec(params, axes, None) == "einsum:int8"


def test_auto_missing_table_warns_once(tmp_path):
    import warnings as W
    params, axes, _ = _fixture()
    missing = str(tmp_path / "nope.json")
    with pytest.warns(UserWarning, match="REPRO_BENCH_TABLE"):
        B.select_auto_spec(params, axes, None, table_path=missing)
    with W.catch_warnings():
        W.simplefilter("error")                      # second call: silent
        B.select_auto_spec(params, axes, None, table_path=missing)


# ---------------------------------------------------------------------------
# Acceptance: fused pallas_wagg on an 8-device host mesh (subprocess)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PALLAS_GRID_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import backends as B
    from repro.core.codecs import get_codec
    from repro.core.weights import masked_compute_theta

    assert len(jax.devices()) == 8
    BETA = 0.9
    w = 32
    k = jax.random.key(0)
    params = {"blk": {"w": jax.random.normal(k, (w, 6, 5))},
              "head": jax.random.normal(jax.random.fold_in(k, 1), (w, 33))}
    axes = {"blk": {"w": ("worker", None, None)},
            "head": ("worker", None)}
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.uniform(0.1, 2.0, w).astype(np.float32))
    active_np = np.ones(w, bool)
    active_np[rng.choice(w, w // 4, replace=False)] = False
    active = jnp.asarray(active_np)

    def check(out, ref, theta, codec_name, beta, label):
        codec = get_codec(codec_name)
        for key_ in (("blk", "w"), ("head",)):
            x = params[key_[0]][key_[1]] if len(key_) == 2 \\
                else params[key_[0]]
            o = out[key_[0]][key_[1]] if len(key_) == 2 else out[key_[0]]
            r = ref[key_[0]][key_[1]] if len(key_) == 2 else ref[key_[0]]
            tol = float(codec.error_bound(x, theta, beta))
            err = float(jnp.abs(o - r).max())
            assert err <= tol, (label, key_, err, tol)

    meshes = [("flat8", Mesh(np.array(jax.devices()), ("data",)), 1),
              ("pods", Mesh(np.array(jax.devices()).reshape(2, 4),
                            ("pod", "data")), 2)]
    specs = ["pallas_wagg:f32", "pallas_wagg:bf16",
             "pallas_wagg:int8", "pallas_wagg:int4"]
    for label, mesh, n_pods in meshes:
        # sync: unmasked theta
        theta = masked_compute_theta(h, jnp.ones(w, bool), 1.0, "boltzmann")
        ctx = B.AggregationContext(mesh=mesh, n_pods=n_pods)
        ref = B.aggregate_with("einsum:f32", params, axes, theta, BETA,
                               ctx=ctx)
        for spec in specs:
            out = B.aggregate_with(spec, params, axes, theta, BETA, ctx=ctx)
            check(out, ref, theta, spec.split(":")[1], BETA,
                  (label, "sync", spec))
        # masked Alg. 4 round: stragglers late-join, bound at beta=1
        theta_m = masked_compute_theta(h, active, 1.0, "boltzmann")
        ctx_m = B.AggregationContext(mesh=mesh, n_pods=n_pods, active=active)
        ref_m = B.aggregate_with("einsum:f32", params, axes, theta_m, BETA,
                                 ctx=ctx_m)
        for spec in specs:
            out = B.aggregate_with(spec, params, axes, theta_m, BETA,
                                   ctx=ctx_m)
            check(out, ref_m, theta_m, spec.split(":")[1], 1.0,
                  (label, "masked", spec))
        print("GRID", label, "ok")
    print("RESULT ok")
""")


def test_pallas_wagg_grid_on_8_device_mesh():
    """Acceptance: masked + unmasked ``pallas_wagg:{f32,bf16,int8,int4}``
    stay within each codec's documented error bound of ``einsum:f32`` on a
    full 8-device host mesh (flat and pod-shaped). Subprocess so the forced
    device count never leaks into other tests."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", PALLAS_GRID_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT ok" in out.stdout
