"""Continuous-batching serve stack: greedy parity under scheduling, paged
cache recycling, RNG schedule-independence, and the train-to-serve hot-swap.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig, WASGDConfig, get_smoke_config
from repro.data import lm_batch
from repro.models import init_params
from repro.serve import ContinuousEngine, HotSwapBridge, ServeEngine
from repro.train import Trainer
from repro.train.lm import make_lm_loss

# exact parity needs row-independent per-token compute: MoE capacity
# dispatch ranks tokens across the batch, so MoE archs are excluded.
PARITY_ARCHS = ["yi-6b", "gemma3-1b", "mamba2-370m"]


def _f32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


def _setup(arch, seed=0):
    cfg = _f32(get_smoke_config(arch))
    params, _ = init_params(cfg, jax.random.key(seed))
    return cfg, params


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_continuous_greedy_parity_vs_solo(arch):
    """Batched continuous decode == legacy solo generate, token for token."""
    cfg, params = _setup(arch)
    prompts = np.asarray(lm_batch(0, 3, 8, cfg.vocab_size)["tokens"])
    legacy = ServeEngine(cfg, params, max_len=64, cache_dtype=jnp.float32)
    eng = ContinuousEngine(cfg, params, n_slots=4, max_len=64, block_size=8,
                           cache_dtype=jnp.float32, chunk=16)
    out = eng.generate(prompts, n_new=12)
    for i in range(3):
        solo = np.asarray(legacy.generate(prompts[i:i + 1], n_new=12))[0]
        np.testing.assert_array_equal(out[i], solo)


def test_greedy_parity_under_insert_evict():
    """More requests than slots with staggered lengths: requests finish
    mid-flight, slots/blocks recycle, later requests are inserted next to
    running ones — and every request still matches its solo decode. The
    longest request decodes far past gemma3's window, so ring wraparound is
    exercised under scheduling too."""
    cfg, params = _setup("gemma3-1b", seed=1)
    legacy = ServeEngine(cfg, params, max_len=64, cache_dtype=jnp.float32)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64, block_size=8,
                           cache_dtype=jnp.float32, chunk=8)
    prompts = np.asarray(lm_batch(5, 5, 8, cfg.vocab_size)["tokens"])
    n_news = [3, 30, 7, 14, 1]
    rids = [eng.submit(prompts[i], n_news[i], seed=i) for i in range(5)]
    done = eng.run()
    for i, rid in enumerate(rids):
        solo = np.asarray(legacy.generate(prompts[i:i + 1], n_news[i]))[0]
        np.testing.assert_array_equal(done[rid], solo)
    # everything was recycled on the way out
    assert eng.scheduler.idle
    assert eng.cache.free_blocks() == eng.cache._group_phys["full"]
    assert eng.n_running == 0


def test_sampled_decode_is_schedule_independent():
    """temperature > 0: the token at position p is keyed by
    fold_in(fold_in(engine_key, seed), p) — a request samples identically
    whether it runs alone or shares the batch with other requests."""
    cfg, params = _setup("yi-6b", seed=2)
    prompt = np.asarray(lm_batch(2, 1, 6, cfg.vocab_size)["tokens"])[0]

    solo = ContinuousEngine(cfg, params, n_slots=2, max_len=32, block_size=8,
                            cache_dtype=jnp.float32, chunk=8, seed=7)
    rid = solo.submit(prompt, 10, temperature=0.8, seed=3)
    a = solo.run()[rid]

    busy = ContinuousEngine(cfg, params, n_slots=2, max_len=32, block_size=8,
                            cache_dtype=jnp.float32, chunk=8, seed=7)
    other = np.asarray(lm_batch(9, 3, 6, cfg.vocab_size)["tokens"])
    rids = [busy.submit(other[i], 4 + 3 * i, temperature=0.5, seed=20 + i)
            for i in range(3)]
    rid_b = busy.submit(prompt, 10, temperature=0.8, seed=3)
    b = busy.run()[rid_b]
    np.testing.assert_array_equal(a, b)


def test_moe_arch_serves_continuously():
    """MoE/hybrid archs run on the paged engine (no exact-parity guarantee,
    but decode must work: attention + SSM caches both paged)."""
    cfg, params = _setup("jamba-v0.1-52b", seed=3)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=32, block_size=8,
                           cache_dtype=jnp.float32, chunk=4)
    out = eng.generate(
        np.asarray(lm_batch(4, 2, 6, cfg.vocab_size)["tokens"]), n_new=5)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_unsupported_archs_raise():
    for arch in ["llama-3.2-vision-11b", "musicgen-large"]:
        cfg, params = _setup(arch, seed=4)
        with pytest.raises(NotImplementedError):
            ContinuousEngine(cfg, params, n_slots=1, max_len=32)


def test_continuous_eos_parity_and_recycling():
    """A stop token finishes a request early via the in-loop done-flags:
    its tokens match the legacy engine's (truncated at the first stop
    token), and its slot + blocks recycle to the waiting queue."""
    cfg, params = _setup("yi-6b", seed=9)
    legacy = ServeEngine(cfg, params, max_len=64, cache_dtype=jnp.float32)
    prompts = np.asarray(lm_batch(11, 3, 8, cfg.vocab_size)["tokens"])
    base = np.asarray(legacy.generate(prompts[0:1], 16))[0]
    eos = int(base[5])
    j = list(base).index(eos)

    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64, block_size=8,
                           cache_dtype=jnp.float32, chunk=4, eos_id=eos)
    rids = [eng.submit(p, 16, seed=i) for i, p in enumerate(prompts)]
    done = eng.run()
    got = done[rids[0]]
    assert len(got) == j + 1 and got[-1] == eos
    np.testing.assert_array_equal(got, base[:j + 1])
    for rid in rids[1:]:                 # others ran to budget or their eos
        toks = done[rid]
        assert len(toks) == 16 or toks[-1] == eos
    assert eng.scheduler.idle and eng.n_running == 0
    assert eng.cache.free_blocks() == eng.cache._group_phys["full"]


def test_budget_validation():
    cfg, params = _setup("yi-6b", seed=5)
    eng = ContinuousEngine(cfg, params, n_slots=1, max_len=32, block_size=8)
    prompt = np.zeros((30,), np.int32)
    with pytest.raises(ValueError, match="exceeds the cache budget"):
        eng.submit(prompt, n_new=3)
    small = ContinuousEngine(cfg, params, n_slots=2, max_len=32,
                             block_size=8, full_blocks=2)
    with pytest.raises(ValueError, match="cache blocks"):
        small.submit(np.zeros((20,), np.int32), n_new=4)


def test_constrained_blocks_queue_and_complete():
    """A block budget that fits only one request at a time still drains the
    queue correctly — admission waits on the free list."""
    cfg, params = _setup("yi-6b", seed=6)
    legacy = ServeEngine(cfg, params, max_len=32, cache_dtype=jnp.float32)
    eng = ContinuousEngine(cfg, params, n_slots=4, max_len=32, block_size=8,
                           cache_dtype=jnp.float32, chunk=8, full_blocks=2)
    prompts = np.asarray(lm_batch(6, 3, 8, cfg.vocab_size)["tokens"])
    rids = [eng.submit(p, 6) for p in prompts]
    done = eng.run()
    for i, rid in enumerate(rids):
        solo = np.asarray(legacy.generate(prompts[i:i + 1], 6))[0]
        np.testing.assert_array_equal(done[rid], solo)
    assert eng.cache.free_blocks() == 2


def test_hot_swap_keeps_in_flight_requests_alive():
    """Trainer.run(serve_hook=) swaps the beta=1 consensus into a live
    engine mid-generation: the in-flight request survives every swap,
    finishes its full budget, and the bridge records per-swap staleness."""
    cfg, params_axes = None, None
    cfg = _f32(get_smoke_config("stablelm-1.6b"))
    params, axes = init_params(cfg, jax.random.key(7))
    tcfg = TrainConfig(learning_rate=0.05, optimizer="sgd",
                       wasgd=WASGDConfig(tau=2, beta=0.9))
    tr = Trainer(make_lm_loss(cfg), params, axes, tcfg, 2)

    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64, block_size=8,
                           cache_dtype=jnp.float32, chunk=4)
    bridge = HotSwapBridge(eng)
    prompt = np.asarray(lm_batch(7, 1, 8, cfg.vocab_size)["tokens"])[0]
    rid = eng.submit(prompt, n_new=40)
    eng.step()
    assert eng.n_running == 1

    def hook(r, p, a):
        eng.step()                       # serve between training rounds
        bridge(r, p, a)

    def batches():
        r = 0
        while True:
            yield {k: jnp.asarray(v) for k, v in
                   lm_batch(r, 4, 16, cfg.vocab_size).items()}
            r += 1

    tr.run(batches(), 4, serve_hook=hook, serve_every=2)
    done = eng.run()
    assert len(done[rid]) == 40          # request survived both swaps
    assert eng.n_swaps == 2
    assert len(bridge.swaps) == 2
    first, second = bridge.swaps
    assert first["in_flight"] == 1 and second["in_flight"] == 1
    assert first["rounds_since_last"] is None
    assert second["rounds_since_last"] == 2
    assert second["param_drift_l2"] > 0
    assert second["tokens_under_prev"] > 0


def test_swap_params_identity_under_same_params():
    """Swapping in the same params mid-flight is a strict no-op on output:
    generate with a swap between chunks == generate without."""
    cfg, params = _setup("gemma3-1b", seed=8)
    prompt = np.asarray(lm_batch(8, 1, 8, cfg.vocab_size)["tokens"])[0]

    plain = ContinuousEngine(cfg, params, n_slots=1, max_len=64,
                             block_size=8, cache_dtype=jnp.float32, chunk=4)
    rid = plain.submit(prompt, 20)
    a = plain.run()[rid]

    swapped = ContinuousEngine(cfg, params, n_slots=1, max_len=64,
                               block_size=8, cache_dtype=jnp.float32,
                               chunk=4)
    rid = swapped.submit(prompt, 20)
    swapped.step()
    swapped.swap_params(jax.tree.map(jnp.copy, params))
    b = swapped.run()[rid]
    np.testing.assert_array_equal(a, b)
    assert swapped.n_swaps == 1
