"""Observability subsystem (src/repro/obs + tools/obs_report.py).

Three guarantees under test:

* **NullSink no-op** — the default (telemetry off) path is byte-for-byte
  the un-instrumented trainer: bitwise-identical params, zero implicit
  host transfers (``jax.transfer_guard("disallow")``), zero added
  retraces of the fused step;
* **event fidelity** — every run mode (sync, async on_device, pipelined,
  elastic with async checkpoints, serving) emits its typed events, the
  JSONL round-trip preserves them, and the phased instrumented round
  produces the same params as the fused one;
* **reporter** — ``tools/obs_report.py`` summarizes a recorded run, and
  its ``--json`` output is pinned by a golden fixture.
"""
import functools
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig, WASGDConfig
from repro.core import MembershipSchedule
from repro.data import (OrderedDataset, RoundPrefetcher, make_classification)
from repro.models import cnn
from repro.models.param import build
from repro.obs import (NULL, CheckpointSave, HotSwap, JsonlSink,
                       MembershipChange, NullSink, RingSink, RoundTrace,
                       ServeSample, Telemetry, WorkerAssessment,
                       event_from_record, read_events, to_record)
from repro.train import Trainer


def _problem(seed=0):
    X, y = make_classification(seed, 1024, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4),
        jax.random.key(seed))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    return X, y, params, axes, loss_fn


def _ds(X, y, w=2, tau=2, bl=8, **kw):
    return OrderedDataset({"x": X, "y": y}, w, tau, bl, n_segments=1, **kw)


def _trees_equal(a, b):
    same = jax.tree.map(lambda x, y: bool(np.array_equal(np.asarray(x),
                                                         np.asarray(y))),
                        a, b)
    return all(jax.tree.leaves(same))


# ---------------------------------------------------------------------------
# Events + sinks
# ---------------------------------------------------------------------------

def test_event_record_round_trip():
    events = [
        RoundTrace(round=3, total_s=0.5, host_staging_s=0.01,
                   phases={"local_steps": 0.3, "reduce": 0.1},
                   detail="phased", p=4),
        WorkerAssessment(round=3, theta=[0.25, 0.75], energies=[1.0, 0.5],
                         theta_entropy=0.56, active=[True, False],
                         policy="boltzmann",
                         policy_state={"n_leaves": 2, "l2": 1.5}),
        ServeSample(chunk_s=0.1, steps=8, tokens=16, itl_s=0.0125,
                    n_running=2, queue_depth=1, admitted=2, finished=1,
                    blocks_free=10, blocks_total=16, occupancy=0.375,
                    ttft_s=[0.2], e2e_s=[1.1]),
        MembershipChange(round=2, old_p=2, new_p=3, generation=1),
        CheckpointSave(path="/tmp/ck", round=2, duration_s=0.05,
                       nbytes=1024),
        HotSwap(round=4, rounds_since_last=2, tokens_under_prev=64,
                param_drift_l2=0.7, in_flight=3),
    ]
    for e in events:
        rec = to_record(e)
        assert rec["kind"] == e.kind
        back = event_from_record(json.loads(json.dumps(rec)))
        assert type(back) is type(e)
        for k, v in rec.items():
            if k != "kind":
                assert getattr(back, k) == pytest.approx(v) \
                    if isinstance(v, float) else getattr(back, k) == v


def test_event_from_record_rejects_unknown_kind_drops_unknown_fields():
    with pytest.raises(ValueError, match="unknown"):
        event_from_record({"kind": "nope"})
    e = event_from_record({"kind": "membership_change", "round": 1,
                           "old_p": 2, "new_p": 4, "from_the_future": 9})
    assert (e.old_p, e.new_p) == (2, 4)
    assert not hasattr(e, "from_the_future")


def test_sinks_satisfy_protocol_and_ring_caps():
    assert isinstance(NULL, Telemetry)
    assert isinstance(NullSink(), Telemetry)
    ring = RingSink(maxlen=3)
    assert isinstance(ring, Telemetry)
    for r in range(5):
        ring.emit(MembershipChange(round=r, old_p=2, new_p=2))
    assert [e.round for e in ring.events()] == [2, 3, 4]
    assert not NULL.enabled and ring.enabled


def test_jsonl_sink_round_trip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    sink = JsonlSink(path)
    sink.emit(RoundTrace(round=0, total_s=1.0, phases={"reduce": 0.5}))
    sink.emit(WorkerAssessment(round=0, theta=[1.0], energies=[2.0],
                               theta_entropy=0.0))
    sink.close()
    assert sink.n_emitted == 2
    evs = list(read_events(path))
    assert [e.kind for e in evs] == ["round_trace", "worker_assessment"]
    assert evs[0].phases == {"reduce": 0.5}
    assert evs[1].theta == [1.0]


def test_jsonl_sink_surfaces_writer_failure(tmp_path):
    path = str(tmp_path / "run.jsonl")
    sink = JsonlSink(path)
    sink._f.close()      # simulate the disk going away under the writer
    sink.emit(MembershipChange(round=0, old_p=1, new_p=2))
    with pytest.raises(RuntimeError, match="telemetry writer failed"):
        sink.close()


# ---------------------------------------------------------------------------
# NullSink no-op guarantee
# ---------------------------------------------------------------------------

def test_null_sink_path_is_bitwise_noop_and_transfer_clean():
    """telemetry=None and telemetry=NullSink() take the fused step with no
    added fences, no host transfers, no retraces — and identical params."""
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))

    tr0 = Trainer(loss_fn, params, axes, tcfg, 2)
    tr0.run(_ds(X, y).batches(), 4)

    tr1 = Trainer(loss_fn, params, axes, tcfg, 2)
    tr1.run(_ds(X, y).batches(), 4, telemetry=NullSink(),
            transfer_guard="disallow")

    assert _trees_equal(tr0.state.params, tr1.state.params)
    # one trace each: the NullSink run must not add a second signature
    assert tr0._step._cache_size() == 1
    assert tr1._step._cache_size() == 1
    # and no phased programs were built
    assert tr1._phased_cache == {}


def test_phased_instrumented_round_matches_fused_params():
    """With a real sink the round runs as separately-jitted phases; the
    result must still equal the fused step bitwise (same program split at
    phase boundaries)."""
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))

    tr0 = Trainer(loss_fn, params, axes, tcfg, 2)
    tr0.run(_ds(X, y).batches(), 4)

    sink = RingSink()
    tr1 = Trainer(loss_fn, params, axes, tcfg, 2)
    tr1.run(_ds(X, y).batches(), 4, telemetry=sink)

    assert _trees_equal(tr0.state.params, tr1.state.params)
    assert len(sink.by_kind("round_trace")) == 4


# ---------------------------------------------------------------------------
# Per-mode event emission
# ---------------------------------------------------------------------------

def test_sync_run_emits_phased_round_trace_and_assessment():
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    sink = RingSink()
    tr = Trainer(loss_fn, params, axes, tcfg, 2)
    tr.run(_ds(X, y).batches(), 3, telemetry=sink)

    traces = sink.by_kind("round_trace")
    assert len(traces) == 3
    for t in traces:
        assert t.detail == "phased" and t.p == 2
        assert set(t.phases) == {"local_steps", "judge", "reduce",
                                 "finalize"}
        assert all(v >= 0 for v in t.phases.values())
        assert t.total_s >= max(t.phases.values())
        assert t.host_staging_s >= 0
    wa = sink.by_kind("worker_assessment")
    assert len(wa) == 3
    for a in wa:
        assert len(a.theta) == 2 and len(a.energies) == 2
        assert a.theta == pytest.approx([sum(a.theta) - a.theta[1],
                                         a.theta[1]])
        assert sum(a.theta) == pytest.approx(1.0, abs=1e-5)
        assert a.policy == "boltzmann"
        assert a.active is None          # sync round: no Alg. 4 mask


def test_async_on_device_run_emits_active_mask():
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=2, async_mode="on_device"))
    sink = RingSink()
    tr = Trainer(loss_fn, params, axes, tcfg, 3)
    tr.run(_ds(X, y, w=3).batches(), 3, telemetry=sink)

    wa = sink.by_kind("worker_assessment")
    assert len(wa) == 3
    for a in wa:
        assert a.active is not None and len(a.active) == 3
        assert all(isinstance(f, bool) for f in a.active)
    assert all(t.detail == "phased" for t in sink.by_kind("round_trace"))


def test_pipelined_run_emits_coarse_round_trace():
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    sink = RingSink()
    ds = _ds(X, y, boundary_delay=RoundPrefetcher.run_ahead())
    tr = Trainer(loss_fn, params, axes, tcfg, 2, pipeline="parity")
    tr.run(ds, 3, telemetry=sink)

    traces = sink.by_kind("round_trace")
    assert len(traces) == 3
    # the pipelined step is one fused program — whole-round timing only
    assert all(t.detail == "fused" and t.phases == {} for t in traces)
    assert len(sink.by_kind("worker_assessment")) == 3


def test_elastic_run_emits_membership_and_checkpoint_events(tmp_path):
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    sink = RingSink()
    tr = Trainer(loss_fn, params, axes, tcfg, 2)
    tr.run(_ds(X, y), 4, telemetry=sink,
           membership_schedule=MembershipSchedule(2, {2: 3}),
           checkpoint_every=2, checkpoint_path=str(tmp_path / "ck"))

    mc = sink.by_kind("membership_change")
    assert [(e.round, e.old_p, e.new_p) for e in mc] == [(2, 2, 3)]
    cs = sink.by_kind("checkpoint_save")
    assert len(cs) == 2
    for e in cs:
        assert e.duration_s > 0 and e.nbytes > 0
        assert os.path.isdir(e.path)
    # worker assessments follow the live worker count across the resize
    wa = sink.by_kind("worker_assessment")
    assert [len(a.theta) for a in wa] == [2, 2, 3, 3]


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def _serve_setup(telemetry=None):
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.data import lm_batch
    from repro.models import init_params
    from repro.serve import ContinuousEngine
    cfg = dataclasses.replace(get_smoke_config("gemma3-1b"),
                              compute_dtype="float32")
    params, _ = init_params(cfg, jax.random.key(0))
    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64, block_size=8,
                           cache_dtype=jnp.float32, chunk=8,
                           telemetry=telemetry)
    prompts = np.asarray(lm_batch(0, 3, 8, cfg.vocab_size)["tokens"])
    return cfg, params, eng, prompts


def test_continuous_engine_emits_serve_samples_and_stays_bitwise():
    sink = RingSink()
    _, params, eng, prompts = _serve_setup(telemetry=sink)
    out = eng.generate(prompts, n_new=12)

    samples = sink.by_kind("serve_sample")
    assert samples, "no ServeSample emitted"
    total_tokens = sum(s.tokens for s in samples)
    assert total_tokens == eng.tokens_generated
    ttft = [t for s in samples for t in s.ttft_s]
    assert len(ttft) == 3 and all(t > 0 for t in ttft)
    e2e = [t for s in samples for t in s.e2e_s]
    assert len(e2e) == 3 and all(t > 0 for t in e2e)
    for s in samples:
        assert s.steps >= 1 and s.itl_s == pytest.approx(s.chunk_s / s.steps)
        assert 0.0 <= s.occupancy <= 1.0
        assert s.blocks_free + round(s.occupancy * s.blocks_total) \
            == s.blocks_total

    # telemetry must not perturb decoding
    _, _, eng2, _ = _serve_setup()
    np.testing.assert_array_equal(out, eng2.generate(prompts, n_new=12))


def test_hot_swap_bridge_emits_hot_swap_event():
    from repro.serve import HotSwapBridge
    sink = RingSink()
    _, params, eng, prompts = _serve_setup(telemetry=sink)
    bridge = HotSwapBridge(eng)          # inherits the engine's sink
    eng.generate(prompts, n_new=4)       # tokens served under the old params
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), params)
    axes = jax.tree.map(lambda x: ("worker",) + (None,) * x.ndim, params)
    bridge(5, stacked, axes)
    bridge(9, stacked, axes)
    hs = sink.by_kind("hot_swap")
    assert [(e.round, e.rounds_since_last) for e in hs] == [(5, None),
                                                           (9, 4)]
    assert hs[0].tokens_under_prev == eng.tokens_generated
    assert hs[1].tokens_under_prev == 0
    assert hs[1].param_drift_l2 == 0.0


# ---------------------------------------------------------------------------
# Reporter
# ---------------------------------------------------------------------------

def _report_main(argv, capsys):
    from tools.obs_report import main
    rc = main(argv)
    return rc, capsys.readouterr().out


def test_obs_report_renders_recorded_run(tmp_path, capsys):
    X, y, params, axes, loss_fn = _problem()
    path = str(tmp_path / "run.jsonl")
    sink = JsonlSink(path)
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    tr = Trainer(loss_fn, params, axes, tcfg, 2)
    tr.run(_ds(X, y).batches(), 3, telemetry=sink)
    sink.close()

    rc, out = _report_main([path], capsys)
    assert rc == 0
    for needle in ("rounds: 3", "local_steps", "judge", "reduce",
                   "finalize", "theta entropy", "policy=boltzmann"):
        assert needle in out, needle


def test_obs_report_json_golden(tmp_path, capsys):
    """A hand-written run pins the --json summary shape and arithmetic."""
    path = str(tmp_path / "golden.jsonl")
    sink = JsonlSink(path)
    for r in range(2):
        sink.emit(RoundTrace(round=r, total_s=0.4 + 0.2 * r,
                             host_staging_s=0.01,
                             phases={"local_steps": 0.2, "reduce": 0.1},
                             detail="phased", p=2))
        sink.emit(WorkerAssessment(round=r, theta=[0.5 + 0.2 * r,
                                                   0.5 - 0.2 * r],
                                   energies=[1.0, 2.0],
                                   theta_entropy=0.69 - 0.2 * r,
                                   policy="boltzmann"))
    sink.emit(ServeSample(chunk_s=0.2, steps=8, tokens=16, itl_s=0.025,
                          n_running=2, queue_depth=0, admitted=2,
                          finished=2, blocks_free=8, blocks_total=16,
                          occupancy=0.5, ttft_s=[0.1, 0.3],
                          e2e_s=[1.0, 2.0]))
    sink.emit(MembershipChange(round=1, old_p=2, new_p=4))
    sink.emit(CheckpointSave(path="/tmp/ck", round=1, duration_s=0.5,
                             nbytes=2048))
    sink.emit(HotSwap(round=1, rounds_since_last=None, tokens_under_prev=16,
                      param_drift_l2=0.25, in_flight=1))
    sink.close()

    rc, out = _report_main([path, "--json"], capsys)
    assert rc == 0
    s = json.loads(out)
    assert s["n_events"] == 8
    assert s["rounds"]["n"] == 2
    assert s["rounds"]["detail"] == ["phased"]
    assert s["rounds"]["total_s"]["mean"] == pytest.approx(0.5)
    assert s["rounds"]["phases"]["local_steps"]["p50"] == pytest.approx(0.2)
    assert s["assessment"]["theta_entropy"] == {
        "first": pytest.approx(0.69), "last": pytest.approx(0.49),
        "min": pytest.approx(0.49), "max": pytest.approx(0.69)}
    assert s["assessment"]["top_worker_share"]["mean"] == pytest.approx(0.6)
    assert s["serve"]["tokens"] == 16
    assert s["serve"]["tokens_per_s"] == pytest.approx(80.0)
    assert s["serve"]["ttft_s"]["p50"] == pytest.approx(0.2)
    assert s["membership"] == [{"round": 1, "old_p": 2, "new_p": 4}]
    assert s["checkpoints"]["total_bytes"] == 2048
    assert s["hot_swaps"]["n"] == 1
    assert s["hot_swaps"]["mean_rounds_since_last"] is None


def test_obs_report_empty_file_fails(tmp_path, capsys):
    path = str(tmp_path / "empty.jsonl")
    open(path, "w").close()
    rc, _ = _report_main([path], capsys)
    assert rc == 1
