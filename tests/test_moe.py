"""MoE dispatch correctness: capacity dispatch == dense expert mixture when
nothing is dropped; aux losses; drop accounting."""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.moe import _capacity, moe_ffn, moe_init
from repro.models.param import build


def _params(d, m, seed=0):
    p, _ = build(functools.partial(moe_init, name="moe", d_model=d, m=m),
                 jax.random.key(seed))
    return p["moe"]


def dense_reference(params, x, m):
    """Explicit per-token top-k mixture over all experts (no capacity)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    ep = params["experts"]

    def expert(e, t):
        g = jax.nn.silu(t @ ep["w_gate"][e]) * (t @ ep["w_up"][e])
        return g @ ep["w_down"][e]

    out = jnp.zeros_like(xf)
    for k in range(m.top_k):
        all_out = jnp.stack([expert(e, xf) for e in range(m.n_experts)], 0)
        sel = all_out[idx[:, k], jnp.arange(xf.shape[0])]
        out = out + gates[:, k:k + 1] * sel
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference():
    m = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    d, b, s = 8, 2, 16
    params = _params(d, m)
    x = jax.random.normal(jax.random.key(1), (b, s, d), jnp.float32)
    out, aux = moe_ffn(params, x, m, jnp.float32)
    ref = dense_reference(params, x, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux.dropped_fraction) == 0.0


def test_moe_drops_beyond_capacity():
    m = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, capacity_factor=0.1)
    d, b, s = 8, 2, 64
    params = _params(d, m)
    x = jax.random.normal(jax.random.key(2), (b, s, d))
    out, aux = moe_ffn(params, x, m, jnp.float32)
    assert out.shape == x.shape
    assert float(aux.dropped_fraction) > 0.0
    assert not bool(jnp.isnan(out).any())


def test_moe_aux_losses_positive():
    m = MoEConfig(n_experts=8, top_k=2, d_ff_expert=8)
    params = _params(16, m)
    x = jax.random.normal(jax.random.key(3), (2, 32, 16))
    _, aux = moe_ffn(params, x, m, jnp.float32)
    assert float(aux.load_balance_loss) > 0.0
    assert float(aux.router_z_loss) >= 0.0


def test_capacity_rounding():
    m = MoEConfig(n_experts=64, top_k=8, d_ff_expert=8, capacity_factor=1.25)
    c = _capacity(16384, m)
    assert c % 8 == 0 and c >= 16384 * 8 * 1.25 / 64


def test_moe_gradients_flow():
    m = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=4.0)
    params = _params(8, m)
    x = jax.random.normal(jax.random.key(4), (1, 16, 8))

    def loss(p):
        out, aux = moe_ffn(p, x, m, jnp.float32)
        return jnp.sum(out ** 2) + aux.load_balance_loss + aux.router_z_loss

    g = jax.grad(loss)(params)
    gn = jax.tree.map(lambda t: float(jnp.abs(t).sum()), g)
    assert gn["router"] > 0
    assert gn["experts"]["w_gate"] > 0
