"""reprolint: each rule against paired good/bad fixtures (the bad ones
reproduce the repo's actual bug history), pragma semantics, SPEC001
registry drift, and the Trainer's transfer_guard debug flag."""
import textwrap

import pytest

from tools.reprolint import ALL_RULES, Bridge, lint_text
from tools.reprolint.cli import main as cli_main


def _rules(src, path="src/repro/x.py", bridge=None):
    return sorted({f.rule for f in
                   lint_text(textwrap.dedent(src), path, bridge=bridge)})


def _mini_bridge():
    scheds = frozenset({"einsum", "rs_ag"})
    codecs = frozenset({"f32", "int8"})
    policies = frozenset({"boltzmann", "anneal"})

    def resolve(s):
        if ":" in s:
            a, b = s.split(":", 1)
            if a not in scheds:
                raise KeyError(f"unknown aggregation schedule {a!r}")
            if b not in codecs:
                raise KeyError(f"unknown payload codec {b!r}")
            return a, b
        if s in scheds:
            return s, None
        raise KeyError(f"unknown aggregation backend {s!r}")

    def parse(s):
        for seg in s.split("|"):
            if seg.split("(")[0] not in policies:
                raise ValueError(f"unknown weight policy {seg!r}")
        return object()

    return Bridge(scheds, codecs, scheds, policies, resolve, parse)


# ---------------------------------------------------------------------------
# RNG001
# ---------------------------------------------------------------------------

def test_rng001_flags_double_sample():
    assert "RNG001" in _rules("""
        import jax
        def f():
            key = jax.random.key(0)
            a = jax.random.normal(key, (4,))
            b = jax.random.uniform(key, (4,))
            return a, b
    """)


def test_rng001_flags_consume_then_split():
    # PR 8: the legacy serve engine sampled from a key and THEN split it,
    # correlating the first token with the rest of the stream.
    assert "RNG001" in _rules("""
        import jax
        def sample(logits, key):
            tok = jax.random.categorical(key, logits)
            k1, k2 = jax.random.split(key)
            return tok, k1, k2
    """)


def test_rng001_clean_split_before_sample_and_fold_in():
    assert _rules("""
        import jax
        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (4,))
            b = jax.random.uniform(k2, (4,))
            c = jax.random.normal(jax.random.fold_in(key, 1), (4,))
            return a, b, c
    """) == []


def test_rng001_clean_rebind_in_loop():
    assert _rules("""
        import jax
        def f(key, n):
            outs = []
            for i in range(n):
                key, sub = jax.random.split(key)
                outs.append(jax.random.normal(sub, (4,)))
            return outs
    """) == []


def test_rng001_flags_loop_reuse_without_rebind():
    assert "RNG001" in _rules("""
        import jax
        def f(key, xs):
            outs = []
            for x in xs:
                outs.append(jax.random.normal(key, (4,)))
            return outs
    """)


def test_rng001_ignores_stdlib_random_param():
    # a random.Random parameter named rng is not a JAX key: reuse across
    # helper calls is its normal stateful API
    assert _rules("""
        def draw(rng, elements):
            n = rng.randint(0, 3)
            return [e.example(rng) for e in elements[:n]]
    """) == []


# ---------------------------------------------------------------------------
# JIT001
# ---------------------------------------------------------------------------

def test_jit001_flags_host_sync_in_jitted_def():
    src = """
        import jax
        import numpy as np
        @jax.jit
        def step(x):
            y = np.asarray(x)
            print(y)
            return x.sum().item()
    """
    findings = lint_text(textwrap.dedent(src), "src/repro/x.py")
    assert sum(f.rule == "JIT001" for f in findings) == 3


def test_jit001_follows_local_call_graph():
    assert "JIT001" in _rules("""
        import jax
        def helper(x):
            return float(x.mean())
        def round_fn(x):
            return helper(x) + 1
        step = jax.jit(round_fn)
    """)


def test_jit001_clean_outside_trace_and_static_args():
    assert _rules("""
        import functools
        import jax
        import numpy as np
        def host_metrics(x):
            return float(np.asarray(x).mean())
        @functools.partial(jax.jit, static_argnames=("beta",))
        def step(x, beta):
            return x * float(beta)
    """) == []


def test_jit001_marks_lax_control_flow_bodies():
    assert "JIT001" in _rules("""
        import jax
        def body(c, x):
            print(x)
            return c, x
        def run(xs):
            return jax.lax.scan(body, 0, xs)
    """)


# ---------------------------------------------------------------------------
# PAL001
# ---------------------------------------------------------------------------

def test_pal001_flags_hardcoded_default_and_call():
    # PR 7: wagg's interpret=True default silently ran interpret mode on TPU
    src = """
        from jax.experimental import pallas as pl
        def kern(x, interpret: bool = True):
            return pl.pallas_call(lambda r, o: None, interpret=False)(x)
    """
    findings = lint_text(textwrap.dedent(src), "src/repro/x.py")
    assert sum(f.rule == "PAL001" for f in findings) == 2


def test_pal001_clean_backend_derived():
    assert _rules("""
        from typing import Optional
        import jax
        from jax.experimental import pallas as pl
        def kern(x, interpret: Optional[bool] = None):
            interpret = (jax.default_backend() != "tpu"
                         if interpret is None else interpret)
            return pl.pallas_call(lambda r, o: None, interpret=interpret)(x)
    """) == []


def test_pal001_silent_without_pallas_import():
    assert _rules("""
        def simulate(x, interpret: bool = True):
            return x if interpret else -x
    """) == []


# ---------------------------------------------------------------------------
# SPEC001
# ---------------------------------------------------------------------------

def test_spec001_flags_unregistered_codec():
    assert "SPEC001" in _rules('SPEC = "rs_ag:int9"\n',
                               bridge=_mini_bridge())


def test_spec001_flags_unknown_policy_stage():
    assert "SPEC001" in _rules('POLICY = "boltzmann|nope"\n',
                               bridge=_mini_bridge())


def test_spec001_clean_valid_and_unanchored():
    assert _rules("""
        SPEC = "einsum:f32"
        POLICY = "boltzmann(a=8)|anneal(cosine)"
        NOT_A_SPEC = "file:line"
        PROSE = "einsum:f32 beats rs_ag:int8 at small sizes in most runs"
    """, bridge=_mini_bridge()) == []


def test_spec001_skipped_without_bridge():
    assert _rules('SPEC = "rs_ag:int9"\n', bridge=None) == []


def test_spec001_registry_drift_live():
    """A spec string is valid exactly while its schedule is registered."""
    from tools.reprolint.registry import load_bridge
    from repro.core import backends as B

    class _DriftSched:
        name = "_lintdrift"
        needs_mesh = False

    src = 'SPEC = "_lintdrift:f32"\n'
    B.register_schedule(_DriftSched())
    try:
        assert _rules(src, bridge=load_bridge()) == []
    finally:
        B._SCHEDULES.pop("_lintdrift", None)
        B._COMPOSED.clear()
    assert _rules(src, bridge=load_bridge()) == ["SPEC001"]


# ---------------------------------------------------------------------------
# DT001
# ---------------------------------------------------------------------------

def test_dt001_flags_narrowing_cast():
    # PR 6: restore() silently cast every leaf through a narrow dtype
    assert "DT001" in _rules("""
        import jax.numpy as jnp
        def pack(x):
            return x.astype(jnp.bfloat16)
    """)


def test_dt001_exempts_codec_and_checkpoint_layers():
    src = """
        import jax.numpy as jnp
        def pack(x):
            return x.astype(jnp.int8)
    """
    assert _rules(src, path="src/repro/core/codecs.py") == []
    assert _rules(src, path="src/repro/checkpoint/io.py") == []
    assert "DT001" in _rules(src, path="src/repro/train/step.py")


def test_dt001_widening_clean():
    assert _rules("""
        import jax.numpy as jnp
        def up(x):
            return x.astype(jnp.float32)
    """) == []


# ---------------------------------------------------------------------------
# THR001
# ---------------------------------------------------------------------------

_THR_BAD = """
    import threading
    class Prefetcher:
        def start(self):
            self._t = threading.Thread(target=self._worker, daemon=True)
            self._t.start()
        def _worker(self):
            self._result = 42
        def get(self):
            return self._result
"""


def test_thr001_flags_unsynchronized_cross_thread_attr():
    assert "THR001" in _rules(_THR_BAD)


def test_thr001_lock_in_class_suppresses():
    assert _rules("""
        import threading
        class Prefetcher:
            def __init__(self):
                self._lock = threading.Lock()
            def start(self):
                self._t = threading.Thread(target=self._worker, daemon=True)
                self._t.start()
            def _worker(self):
                with self._lock:
                    self._result = 42
            def get(self):
                with self._lock:
                    return self._result
    """) == []


def test_thr001_worker_private_attrs_clean():
    assert _rules("""
        import threading
        class Prefetcher:
            def start(self):
                self._t = threading.Thread(target=self._worker, daemon=True)
                self._t.start()
            def _worker(self):
                self._scratch = 42
                return self._scratch
            def get(self):
                return 7
    """) == []


def test_thr001_flags_executor_submit_target():
    # pool.submit(self.m, ...) runs self.m on a pool thread — same hazard
    # class as Thread(target=self.m)
    assert "THR001" in _rules("""
        from concurrent.futures import ThreadPoolExecutor
        class Writer:
            def __init__(self):
                self._pool = ThreadPoolExecutor(max_workers=1)
            def emit(self, line):
                self._pool.submit(self._write, line)
            def _write(self, line):
                self._err = line
            def status(self):
                return self._err
    """)


def test_thr001_executor_with_lock_clean():
    assert _rules("""
        import threading
        from concurrent.futures import ThreadPoolExecutor
        class Writer:
            def __init__(self):
                self._lock = threading.Lock()
                self._pool = ThreadPoolExecutor(max_workers=1)
            def emit(self, line):
                self._pool.submit(self._write, line)
            def _write(self, line):
                with self._lock:
                    self._err = line
            def status(self):
                with self._lock:
                    return self._err
    """) == []


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------

def test_pragma_with_reason_suppresses():
    assert _rules("""
        import jax.numpy as jnp
        def pack(x):
            return x.astype(jnp.bfloat16)  # reprolint: allow=DT001 -- wire fmt
    """) == []


def test_pragma_without_reason_is_its_own_finding():
    rules = _rules("""
        import jax.numpy as jnp
        def pack(x):
            return x.astype(jnp.bfloat16)  # reprolint: allow=DT001
    """)
    assert rules == ["DT001", "PRAGMA001"]   # no reason: nothing suppressed


def test_pragma_standalone_comment_covers_next_line():
    assert _rules("""
        import jax.numpy as jnp
        def pack(x):
            # reprolint: allow=DT001 -- the justification rides above the
            # statement so long lines stay readable
            return x.astype(jnp.bfloat16)
    """) == []


def test_pragma_inside_string_literal_is_inert():
    assert "DT001" in _rules("""
        import jax.numpy as jnp
        FIXTURE = "x.astype(jnp.bfloat16)  # reprolint: allow=DT001 -- hi"
        def pack(x):
            return x.astype(jnp.bfloat16)
    """)


def test_pragma001_not_suppressible():
    assert "PRAGMA001" in _rules("""
        X = 1  # reprolint: allow=PRAGMA001
    """)


def test_pragma_wrong_rule_does_not_suppress():
    assert "DT001" in _rules("""
        import jax.numpy as jnp
        def pack(x):
            return x.astype(jnp.bfloat16)  # reprolint: allow=RNG001 -- nope
    """)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exit_codes_and_rule_filter(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import jax
        def f():
            key = jax.random.key(0)
            return jax.random.normal(key, (2,)), jax.random.normal(key, (2,))
    """))
    good = tmp_path / "good.py"
    good.write_text("X = 1\n")

    assert cli_main([str(bad), "--no-registry"]) == 1
    assert "RNG001" in capsys.readouterr().out
    assert cli_main([str(good), "--no-registry"]) == 0
    # filtered to an unrelated rule, the bad file passes
    assert cli_main([str(bad), "--no-registry", "--rules", "DT001"]) == 0
    assert cli_main([str(bad), "--no-registry", "--rules", "NOPE1"]) == 2


def test_repo_tree_is_clean():
    """The gate CI enforces: src/tests/benchmarks lint clean against the
    live registries."""
    from tools.reprolint import lint_paths, load_bridge
    from tools.reprolint.registry import REPO_ROOT
    import os
    paths = [os.path.join(REPO_ROOT, p)
             for p in ("src", "tests", "benchmarks")]
    findings = lint_paths(paths, bridge=load_bridge())
    assert findings == [], "\n".join(f.format() for f in findings)


def test_all_rules_listed():
    assert set(ALL_RULES) == {"RNG001", "JIT001", "PAL001", "SPEC001",
                              "DT001", "THR001", "PRAGMA001"}


# ---------------------------------------------------------------------------
# Trainer transfer_guard
# ---------------------------------------------------------------------------

def test_trainer_run_under_transfer_guard():
    import functools
    import jax
    from repro.configs import TrainConfig, WASGDConfig
    from repro.data import OrderedDataset, make_classification
    from repro.models import cnn
    from repro.models.param import build
    from repro.train import Trainer

    X, y = make_classification(0, 256, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4), jax.random.key(0))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=4))
    ds = OrderedDataset({"x": X, "y": y}, 2, 4, 8, n_segments=1)
    tr = Trainer(loss_fn, params, axes, tcfg, 2)
    # "disallow" raises on any implicit transfer inside the jitted round —
    # completing 4 rounds IS the assertion
    tr.run(ds.batches(), 4, transfer_guard="disallow")
    assert len(tr.history) == 4
