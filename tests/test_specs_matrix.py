"""Fast (no-compile) consistency checks over the FULL 10x4 assignment
matrix: input_specs must produce structurally matched (shapes, axes) trees
and shape-correct batch/cache stand-ins for every combination — catching
spec bugs without paying the dry-run's compile cost."""
import jax
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, TrainConfig, get_config
from repro.configs.base import WASGDConfig
from repro.launch.specs import effective_config, input_specs


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", INPUT_SHAPES, ids=lambda s: s.name)
def test_specs_consistent(arch, shape):
    cfg = get_config(arch)
    tcfg = TrainConfig(wasgd=WASGDConfig(tau=1))
    wl = input_specs(cfg, shape, n_workers=16, tcfg=tcfg)
    assert len(wl.arg_shapes) == len(wl.arg_axes)
    for shapes, axes in zip(wl.arg_shapes, wl.arg_axes):
        s_leaves, s_def = jax.tree.flatten(shapes)
        a_leaves = s_def.flatten_up_to(axes)
        assert len(s_leaves) == len(a_leaves)
        for s, a in zip(s_leaves, a_leaves):
            assert isinstance(a, tuple), (arch, shape.name, s, a)
            assert len(a) == len(s.shape), (arch, shape.name, s.shape, a)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long500k_subquadratic_policy(arch):
    """DESIGN.md §4.2: every arch must be sub-quadratic at 500k decode —
    natively (SSM/hybrid/sliding-window) or via the flagged override."""
    cfg = get_config(arch)
    shape = [s for s in INPUT_SHAPES if s.name == "long_500k"][0]
    eff = effective_config(cfg, shape)
    native = cfg.ssm is not None or cfg.attn_window is not None
    if native:
        assert eff.attn_window == cfg.attn_window     # untouched
    else:
        assert eff.attn_window == shape.window_override
        assert eff.global_attn_every == 0             # all layers windowed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_cache_bounded(arch):
    """No decode cache leaf may be quadratic in context: at long_500k every
    per-layer KV buffer is either the (sharded) full cache for native-global
    layers or window-sized for sliding-window layers."""
    cfg = get_config(arch)
    shape = [s for s in INPUT_SHAPES if s.name == "long_500k"][0]
    wl = input_specs(cfg, shape, n_workers=16)
    cache = wl.arg_shapes[2]
    eff = wl.cfg
    for lname, entry in cache.items():
        if "kv" in entry:
            size = entry["kv"].k.shape[1]
            i = int(lname[1:])
            w = eff.window_for_layer(i)
            if w is not None:
                assert size <= w, (arch, lname, size)
            else:
                assert size == shape.seq_len


def test_train_batch_divisible_all_archs():
    tcfg = TrainConfig(wasgd=WASGDConfig(tau=1))
    shape = [s for s in INPUT_SHAPES if s.kind == "train"][0]
    for arch in ARCH_IDS:
        wl = input_specs(get_config(arch), shape, 32, tcfg)  # multi-pod w
        toks = wl.arg_shapes[1]["tokens"]
        assert toks.shape[0] % 32 == 0
