"""Sharded, topology-aware checkpoints + the hardened legacy restore
(dtype verification, split structure-mismatch diagnostics, async saver)."""
import functools
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, restore, restore_sharded,
                              save, save_sharded, saved_topology)
from repro.configs import WASGDConfig
from repro.core import replicate_workers
from repro.models import cnn
from repro.models.param import build
from repro.optim import make_optimizer
from repro.train.state import init_state
from repro.train.step import init_comm_state


def _full_state(p=4, opt_name="adamw"):
    """A worker-stacked TrainState with the PR 5 stateful on_device comm
    state ({"active", "policy"}) and real optimizer state."""
    params0, axes0 = build(functools.partial(
        cnn.mlp_init, d_in=8, d_hidden=16, n_classes=4), jax.random.key(0))
    params, axes = replicate_workers(params0, axes0, p)
    opt = make_optimizer(opt_name, 1e-3, 0.9, 0.01)
    wcfg = WASGDConfig(tau=2, policy="ema|boltzmann", async_mode="on_device")
    cs = init_comm_state("wasgd+", params, axes, p, wcfg=wcfg)
    assert set(cs) == {"active", "policy"}
    return init_state(params, opt.init(params), p, cs), axes


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype


# -- full-state round trips --------------------------------------------------

@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adamw"])
def test_full_train_state_roundtrip_flat(tmp_path, opt_name):
    state, _ = _full_state(opt_name=opt_name)
    save(str(tmp_path / "ck"), state, meta={"round": 3})
    like = jax.tree.map(jnp.zeros_like, state)
    restored, meta = restore(str(tmp_path / "ck"), like)
    assert meta["round"] == 3
    _assert_trees_equal(restored, state)


def test_full_train_state_roundtrip_sharded(tmp_path):
    state, _ = _full_state()
    path = str(tmp_path / "ck")
    save_sharded(path, state, meta={"round": 5},
                 topology={"p": 4, "round": 5, "rule": "wasgd+"}, n_shards=3)
    files = sorted(os.listdir(path))
    assert files == ["manifest.json", "shard_00000.npz", "shard_00001.npz",
                     "shard_00002.npz"]
    # keys really spread over the shards (byte-balanced bin packing)
    man = json.load(open(os.path.join(path, "manifest.json")))
    shards_used = {e["shard"] for e in man["keys"].values()}
    assert shards_used == {0, 1, 2}
    like = jax.tree.map(jnp.zeros_like, state)
    restored, meta = restore_sharded(path, like)
    assert meta["round"] == 5
    _assert_trees_equal(restored, state)
    # the generic restore() detects the sharded format and delegates
    restored2, _ = restore(path, like)
    _assert_trees_equal(restored2, state)


def test_saved_topology(tmp_path):
    state, _ = _full_state()
    path = str(tmp_path / "ck")
    save_sharded(path, state, topology={"p": 4, "round": 7})
    info = saved_topology(path)
    assert info["format"] == "wasgd-sharded-v1"
    assert info["topology"] == {"p": 4, "round": 7}
    save(str(tmp_path / "legacy"), {"w": jnp.ones(3)})
    assert saved_topology(str(tmp_path / "legacy"))["format"] == "flat"


def test_restore_sharded_rejects_flat(tmp_path):
    save(str(tmp_path / "ck"), {"w": jnp.ones(3)})
    with pytest.raises(ValueError, match="not a sharded checkpoint"):
        restore_sharded(str(tmp_path / "ck"), {"w": jnp.ones(3)})


# -- satellite bugfixes: dtype verification, split structure errors ----------

def test_restore_dtype_mismatch_raises(tmp_path):
    save(str(tmp_path / "ck"), {"w": jnp.arange(4, dtype=jnp.float32)})
    with pytest.raises(ValueError, match="dtype mismatch for w"):
        restore(str(tmp_path / "ck"),
                {"w": jnp.zeros(4, jnp.bfloat16)})


def test_restore_allow_cast_escape_hatch(tmp_path):
    save(str(tmp_path / "ck"), {"w": jnp.arange(4, dtype=jnp.float32)})
    restored, _ = restore(str(tmp_path / "ck"),
                          {"w": jnp.zeros(4, jnp.bfloat16)}, allow_cast=True)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(restored["w"], np.float32),
                               np.arange(4.0))


def test_restore_manifest_corruption_raises(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"w": jnp.arange(4, dtype=jnp.float32)})
    man = json.load(open(os.path.join(path, "manifest.json")))
    man["keys"]["w"]["dtype"] = "int32"        # lie about the stored array
    json.dump(man, open(os.path.join(path, "manifest.json"), "w"))
    with pytest.raises(ValueError, match="corruption"):
        restore(path, {"w": jnp.zeros(4, jnp.float32)})


def test_structure_mismatch_split_messages(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"a": jnp.ones(2), "b": jnp.ones(2)})
    with pytest.raises(ValueError, match="missing from checkpoint: \\['c'\\]"):
        restore(path, {"a": jnp.ones(2), "b": jnp.ones(2), "c": jnp.ones(2)})
    with pytest.raises(ValueError, match="unexpected in checkpoint: \\['b'\\]"):
        restore(path, {"a": jnp.ones(2)})
    # both directions at once name both sides
    with pytest.raises(ValueError, match="missing.*unexpected"):
        restore(path, {"a": jnp.ones(2), "c": jnp.ones(2)})


def test_restore_pairs_unsorted_dict_keys(tmp_path):
    """Insertion order != sorted order: each key restores its OWN array
    (the old flat restore zipped _flatten keys with jax's sorted-leaf
    order and could mis-pair same-shaped leaves)."""
    tree = {"z": jnp.full(3, 1.0), "a": jnp.full(3, 2.0)}
    save(str(tmp_path / "ck"), tree)
    restored, _ = restore(str(tmp_path / "ck"),
                          {"z": jnp.zeros(3), "a": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(restored["z"]), np.full(3, 1.0))
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.full(3, 2.0))


# -- async saver -------------------------------------------------------------

def test_async_checkpointer_matches_sync(tmp_path):
    state, _ = _full_state()
    ac = AsyncCheckpointer()
    ac.save(str(tmp_path / "async"), state, meta={"round": 1},
            topology={"p": 4})
    ac.wait()
    ac.close()
    like = jax.tree.map(jnp.zeros_like, state)
    restored, meta = restore(str(tmp_path / "async"), like)
    assert meta["round"] == 1
    _assert_trees_equal(restored, state)
    assert saved_topology(str(tmp_path / "async"))["topology"]["p"] == 4


def test_async_checkpointer_surfaces_write_errors(tmp_path):
    bad = str(tmp_path / "a-file")
    open(bad, "w").write("not a directory")
    ac = AsyncCheckpointer()
    ac.save(os.path.join(bad, "nested"), {"w": jnp.ones(2)})
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ac.wait()
