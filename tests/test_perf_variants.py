"""§Perf optimization variants must be numerically equivalent to (or within
quantization tolerance of) the faithful baseline."""
import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.aggregate import aggregate_leaf
from repro.data import lm_batch
from repro.models import init_params, loss_fn
from repro.models.attention import flash_attention, flash_attention_windowed


def test_sharded_ce_equals_baseline():
    cfg = dataclasses.replace(get_smoke_config("yi-6b"),
                              compute_dtype="float32")
    cfg_ce = dataclasses.replace(cfg, sharded_ce=True)
    params, _ = init_params(cfg, jax.random.key(0))
    batch = {k: jnp.asarray(v)
             for k, v in lm_batch(0, 2, 16, cfg.vocab_size).items()}
    l0, _ = loss_fn(cfg, params, batch)
    l1, _ = loss_fn(cfg_ce, params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_windowed_qblock_equals_baseline_model():
    cfg = dataclasses.replace(get_smoke_config("gemma3-1b"),
                              compute_dtype="float32")
    cfg_q = dataclasses.replace(cfg, windowed_qblock=True)
    params, _ = init_params(cfg, jax.random.key(1))
    batch = {k: jnp.asarray(v)
             for k, v in lm_batch(1, 2, 32, cfg.vocab_size).items()}
    l0, _ = loss_fn(cfg, params, batch)
    l1, _ = loss_fn(cfg_q, params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)


def test_bf16_comm_dtype_close():
    x = jax.random.normal(jax.random.key(0), (8, 512))
    th = jax.nn.softmax(jnp.arange(8.0))
    exact = aggregate_leaf(x, th, 0.9)
    bf16 = aggregate_leaf(x, th, 0.9, comm_dtype=jnp.bfloat16)
    assert float(jnp.abs(exact - bf16).max()) < 0.02


def test_hierarchical_aggregation_exact():
    """2-hop pod-local reduction is mathematically identical."""
    x = jax.random.normal(jax.random.key(1), (8, 256))
    th = jax.nn.softmax(jax.random.normal(jax.random.key(2), (8,)))
    flat = aggregate_leaf(x, th, 0.7)
    hier = aggregate_leaf(x, th, 0.7, n_pods=2)
    np.testing.assert_allclose(np.asarray(flat), np.asarray(hier),
                               rtol=1e-5, atol=1e-6)
