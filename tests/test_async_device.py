"""On-device async WASGD+ (Alg. 4) via the backend registry — parity harness.

``core/async_sim.py`` (host-side numpy event simulation) is the semantic
oracle; ``core/async_device.py`` must reproduce its parameters leaf-for-leaf
when the SAME straggler schedule is injected into both paths, across all
weight strategies and both mesh schedules. The in-process tests adapt to
however many host devices exist (1 under plain tier-1; the CI "backends or
async" job forces 8); the subprocess test always runs the acceptance grid on
an 8-device host mesh, including the w/p>1 and pod-mesh (n_pods>1) cases.
"""
import functools
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import backends as B
from repro.core.async_device import (ASYNC_BACKENDS, async_backend_name,
                                     build_async_round,
                                     run_parallel_sgd_on_device,
                                     weighted_aggregate_async)
from repro.core.async_sim import (StepTimeModel, StragglerSchedule,
                                  make_schedule, masked_theta,
                                  run_parallel_sgd)
from repro.core.weights import STRATEGIES, compute_theta, masked_compute_theta

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
MESH_BACKENDS = ("async_shard_map", "async_rs_ag")


def _mesh():
    """Worker mesh over every available host device."""
    devs = np.array(jax.devices())
    return Mesh(devs, ("data",))


def _setup(seed=0):
    from repro.data import make_classification
    from repro.models import cnn
    from repro.models.param import build

    X, y = make_classification(seed, 256, d=8, n_classes=3)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=8, d_hidden=16, n_classes=3), jax.random.key(seed))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    def grad_fn(ps, batch):
        one = lambda p, b: loss_fn(p, b)[0]
        losses = jax.vmap(one)(ps, batch)
        grads = jax.grad(lambda q: jax.vmap(one)(q, batch).sum())(ps)
        return losses, grads

    def batches(w, n):
        rng = np.random.default_rng(seed)
        while True:
            idx = rng.integers(0, len(X), size=(w, n))
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, axes, loss_fn, jax.jit(grad_fn), batches


def _max_leaf_err(a, b):
    errs = jax.tree.map(
        lambda x, y: float(jnp.abs(x.astype(jnp.float32)
                                   - y.astype(jnp.float32)).max()), a, b)
    return max(jax.tree.leaves(errs))


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------

def test_async_backends_registered():
    assert set(B.available_backends()) >= set(ASYNC_BACKENDS)


def test_async_backend_name_mapping():
    assert async_backend_name("einsum") == "async_einsum"
    assert async_backend_name("shard_map") == "async_shard_map"
    assert async_backend_name("rs_ag") == "async_rs_ag"
    for name in ASYNC_BACKENDS:                  # idempotent on async names
        assert async_backend_name(name) == name
    # under the two-axis API every composed spec is mask-capable, so the
    # async regime composes with the payload axis —
    assert async_backend_name("quantized") == "einsum:int8"
    assert async_backend_name("hierarchical:int8") == "hierarchical:int8"
    # — including, since the v2 fused kernel applies the Alg. 4 late-join
    # inside the VMEM pass, the pallas specs.
    assert async_backend_name("pallas_wagg") == "pallas_wagg:f32"
    assert async_backend_name("pallas_wagg:int8") == "pallas_wagg:int8"
    with pytest.raises(ValueError, match="no async"):
        async_backend_name("does_not_exist")


def test_async_mesh_backends_raise_without_mesh():
    params, axes, *_ = _setup()
    w = 4
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (w,) + x.shape), params)
    w_axes = jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes,
                          is_leaf=lambda n: isinstance(n, tuple))
    theta = jnp.full((w,), 0.25)
    for name in MESH_BACKENDS:
        with pytest.raises(ValueError, match="needs ctx.mesh"):
            B.aggregate_with(name, params, w_axes, theta, 0.9)


def test_build_async_round_raises_without_mesh():
    _, axes, _, grad_fn, _ = _setup()
    with pytest.raises(ValueError, match="needs ctx.mesh"):
        build_async_round(grad_fn, axes, lr=0.1, backend="async_shard_map")


def test_run_parallel_sgd_requires_time_source():
    params, axes, loss_fn, grad_fn, batches = _setup()
    with pytest.raises(ValueError, match="time_model"):
        run_parallel_sgd(loss_fn, grad_fn, params, axes, batches(4, 4),
                         n_workers=3, backups=1, tau=2, rounds=2, lr=0.1)
    with pytest.raises(ValueError, match="time_model"):
        run_parallel_sgd_on_device(grad_fn, params, axes, batches(4, 4),
                                   n_workers=3, backups=1, tau=2, rounds=2,
                                   lr=0.1, backend="async_einsum")


# ---------------------------------------------------------------------------
# masked_compute_theta (traced) vs masked_theta (host oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_masked_compute_theta_matches_host_oracle(strategy):
    rng = np.random.default_rng(0)
    for trial in range(8):
        w = int(rng.integers(2, 9))
        losses = rng.uniform(0.05, 5.0, w).astype(np.float32)
        n_active = int(rng.integers(1, w + 1))
        active = np.zeros(w, bool)
        active[rng.choice(w, n_active, replace=False)] = True
        host = masked_theta(losses, active, 2.0, strategy)
        dev = np.asarray(jax.jit(
            functools.partial(masked_compute_theta, strategy=strategy,
                              a_tilde=2.0))(jnp.asarray(losses),
                                            jnp.asarray(active)))
        np.testing.assert_allclose(dev, host, atol=1e-6,
                                   err_msg=f"{strategy} trial {trial}")
        assert (dev[~active] == 0.0).all()
        np.testing.assert_allclose(dev.sum(), 1.0, rtol=1e-5)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_masked_theta_all_but_one_inactive(strategy):
    """Degenerate p=1 round: the lone active worker takes all the weight and
    nothing divides by zero (host and traced paths alike)."""
    losses = np.array([3.0, 0.5, 2.0, 1.0], np.float32)
    active = np.array([False, False, True, False])
    host = masked_theta(losses, active, 1.0, strategy)
    dev = np.asarray(masked_compute_theta(jnp.asarray(losses),
                                          jnp.asarray(active), 1.0, strategy))
    for theta in (host, dev):
        assert np.isfinite(theta).all()
        np.testing.assert_allclose(theta, [0.0, 0.0, 1.0, 0.0], atol=1e-6)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_masked_theta_duplicate_losses(strategy):
    """Ties must not divide by zero, and 'best' must break them identically
    in both paths (first active minimum)."""
    losses = np.array([2.0, 0.5, 0.5, 0.5, 2.0], np.float32)
    active = np.array([True, False, True, True, True])
    host = masked_theta(losses, active, 1.0, strategy)
    dev = np.asarray(masked_compute_theta(jnp.asarray(losses),
                                          jnp.asarray(active), 1.0, strategy))
    np.testing.assert_allclose(dev, host, atol=1e-6)
    assert np.isfinite(host).all() and np.isfinite(dev).all()
    np.testing.assert_allclose(host.sum(), 1.0, rtol=1e-5)
    if strategy == "best":                  # tie-break: first active minimum
        assert dev.argmax() == 2


def test_masked_compute_theta_all_active_equals_compute_theta():
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    active = jnp.ones((4,), bool)
    for strategy in STRATEGIES:
        np.testing.assert_allclose(
            np.asarray(masked_compute_theta(h, active, 1.7, strategy)),
            np.asarray(compute_theta(h, strategy, 1.7)), atol=1e-6)


# ---------------------------------------------------------------------------
# Aggregate-level: async backends vs manual late-join / sync degeneration
# ---------------------------------------------------------------------------

def _stacked_fixture(w, seed=0):
    k = jax.random.key(seed)
    params = {"blk": {"w": jax.random.normal(k, (w, 6, 5))},
              "head": jax.random.normal(jax.random.fold_in(k, 1), (w, 33)),
              "experts": {"up": jnp.ones((3, 2))}}
    axes = {"blk": {"w": ("worker", None, None)},
            "head": ("worker", None),
            "experts": {"up": ("experts", None)}}
    return params, axes


def test_async_einsum_matches_manual_late_join():
    w, beta = 4, 0.9
    params, axes = _stacked_fixture(w)
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    active = jnp.array([True, False, True, True])
    theta = masked_compute_theta(h, active, 2.0, "boltzmann")
    out = B.aggregate_with("async_einsum", params, axes, theta, beta,
                           ctx=B.AggregationContext(active=active))
    # manual: Eq. 10 FMA for active workers, aggregate m for stragglers
    for key_ in ("head",):
        x = params[key_].astype(jnp.float32)
        m = jnp.tensordot(theta, x, axes=1)
        fma = (1 - beta) * x + beta * m[None]
        ref = jnp.where(active[:, None], fma, m[None])
        np.testing.assert_allclose(np.asarray(out[key_]), np.asarray(ref),
                                   atol=1e-6)
    # non-worker leaves pass through untouched
    np.testing.assert_array_equal(np.asarray(out["experts"]["up"]),
                                  np.asarray(params["experts"]["up"]))


@pytest.mark.parametrize("name,sync_name", [
    ("async_einsum", "einsum"),
    ("async_shard_map", "shard_map"),
    ("async_rs_ag", "rs_ag"),
])
def test_ctx_active_none_degenerates_to_sync(name, sync_name):
    """With no mask (ctx.active=None) the async family must equal its
    synchronous counterpart: everyone aggregates, nobody late-joins."""
    w = 4 * len(jax.devices())
    params, axes = _stacked_fixture(w)
    theta = jax.nn.softmax(jnp.arange(w, dtype=jnp.float32) / w)
    ctx = B.AggregationContext(mesh=_mesh())
    out = B.aggregate_with(name, params, axes, theta, 0.9, ctx=ctx)
    ref = B.aggregate_with(sync_name, params, axes, theta, 0.9, ctx=ctx)
    assert _max_leaf_err(out, ref) < 1e-5


def test_weighted_aggregate_async_unknown_schedule():
    params, axes = _stacked_fixture(2)
    with pytest.raises(ValueError, match="unknown async schedule"):
        weighted_aggregate_async(params, axes, jnp.array([0.5, 0.5]), None,
                                 0.9, schedule="nope")


# ---------------------------------------------------------------------------
# The parity harness: same schedule into both paths, leaf-for-leaf params
# ---------------------------------------------------------------------------

def _parity_case(strategy, backend, mesh, n_workers, backups, rounds=4,
                 tau=2, seed=0, atol=1e-5):
    params, axes, loss_fn, grad_fn, batches = _setup(seed)
    w = n_workers + backups
    tm = StepTimeModel(w, sigma=0.3, straggle_p=0.2, straggle_mult=10,
                       seed=3)
    sched = make_schedule(tm, rounds=rounds, tau=tau, n_workers=n_workers,
                          backups=backups)
    assert not sched.active.all(), "schedule must actually drop stragglers"
    host = run_parallel_sgd(loss_fn, grad_fn, params, axes,
                            batches(w, tau * 4), n_workers=n_workers,
                            backups=backups, tau=tau, rounds=rounds, lr=0.05,
                            schedule=sched, strategy=strategy)
    dev = run_parallel_sgd_on_device(
        grad_fn, params, axes, batches(w, tau * 4), n_workers=n_workers,
        backups=backups, tau=tau, rounds=rounds, lr=0.05, schedule=sched,
        strategy=strategy, backend=backend,
        ctx=B.AggregationContext(mesh=mesh))
    assert dev.wall == host.wall
    assert dev.dropped_rounds == host.dropped_rounds
    np.testing.assert_allclose(dev.losses, host.losses, atol=atol)
    err = _max_leaf_err(host.params, dev.params)
    assert err < atol, (strategy, backend, err)


@pytest.mark.parametrize("backend", ("async_einsum",) + MESH_BACKENDS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_on_device_matches_host_sim(strategy, backend):
    """The headline parity: same injected straggler schedule, every strategy,
    every async backend — parameters match the host oracle leaf-for-leaf.
    Worker width is 4x the device count, so the mesh backends also exercise
    w/p > 1 local copies whenever this runs (1 device or 8)."""
    d = len(jax.devices())
    _parity_case(strategy, backend, _mesh(), n_workers=3 * d, backups=d)


@pytest.mark.parametrize("strategy", ("boltzmann", "best"))
def test_on_device_pallas_wagg_matches_host_sim(strategy):
    """Satellite regression: pallas_wagg used to raise on ANY masked
    context, so the async driver could never run it. The v2 fused kernel
    applies the late-join in-pass — masked pallas_wagg must now match the
    host-simulation oracle leaf-for-leaf (f32 codec, so 1e-5 parity)."""
    d = len(jax.devices())
    _parity_case(strategy, "pallas_wagg", _mesh(), n_workers=3 * d,
                 backups=d)


def test_on_device_matches_host_sim_pod_mesh():
    """n_pods > 1: the worker axis spans ("pod", "data") and the collectives
    reduce over both mesh axes."""
    d = len(jax.devices())
    if d < 2:
        pytest.skip("needs >= 2 devices for a pod mesh (CI async job / "
                    "subprocess grid cover it)")
    mesh = jax.make_mesh((2, d // 2), ("pod", "data"))
    for strategy in ("boltzmann", "best"):
        _parity_case(strategy, "async_shard_map", mesh,
                     n_workers=3 * d, backups=d)
        _parity_case(strategy, "async_rs_ag", mesh,
                     n_workers=3 * d, backups=d)


def test_synchronous_schedule_all_active():
    tm = StepTimeModel(6, sigma=0.3, straggle_p=0.3, seed=0)
    sched = make_schedule(tm, rounds=5, tau=3, n_workers=4, backups=2,
                          synchronous=True)
    assert sched.active.all()
    async_sched = make_schedule(StepTimeModel(6, sigma=0.3, straggle_p=0.3,
                                              seed=0),
                                rounds=5, tau=3, n_workers=4, backups=2)
    # same sampled times: the p-th arrival can never gate later than the max
    assert (async_sched.round_wall <= sched.round_wall + 1e-12).all()
    assert (async_sched.active.sum(axis=1) == 4).all()


# ---------------------------------------------------------------------------
# Train-step / Trainer integration (async_mode="on_device")
# ---------------------------------------------------------------------------

def _trainer_setup(w, tau, async_mode="on_device", backend="", rule="wasgd"):
    from repro.configs import TrainConfig, WASGDConfig
    from repro.data import make_classification
    from repro.models import cnn
    from repro.models.param import build
    from repro.train import Trainer

    X, y = make_classification(0, 512, d=8, n_classes=3)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=8, d_hidden=16, n_classes=3), jax.random.key(0))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(
        tau=tau, async_mode=async_mode, backend=backend))
    tr = Trainer(loss_fn, params, axes, tcfg, w, rule=rule,
                 mesh=_mesh() if backend in ("shard_map", "rs_ag",
                                             *MESH_BACKENDS) else None)

    def batches():
        rng = np.random.default_rng(0)
        n = tau * w * 4
        while True:
            idx = rng.integers(0, len(X), size=n)
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return tr, batches


def test_trainer_on_device_async_masks_stragglers():
    d = len(jax.devices())
    p, b, tau = 3 * d, d, 2
    w = p + b
    tr, batches = _trainer_setup(w, tau)
    sched = make_schedule(StepTimeModel(w, sigma=0.3, straggle_p=0.3,
                                        seed=1),
                          rounds=5, tau=tau, n_workers=p, backups=b)
    out = tr.run(batches(), 5, straggler_schedule=sched)
    assert np.isfinite(out["final_loss"])
    for r, rec in enumerate(tr.history):
        theta = np.asarray(rec["theta"])
        active = sched.active[r]
        np.testing.assert_array_equal(np.asarray(rec["active"]),
                                      active.astype(np.float32))
        assert (theta[~active] == 0.0).all()     # stragglers: exactly 0
        np.testing.assert_allclose(theta.sum(), 1.0, rtol=1e-5)


def test_trainer_on_device_async_mesh_backend():
    d = len(jax.devices())
    w = 4 * d
    tr, batches = _trainer_setup(w, tau=2, backend="shard_map")
    sched = make_schedule(StepTimeModel(w, sigma=0.3, straggle_p=0.3,
                                        seed=2),
                          rounds=3, tau=2, n_workers=3 * d, backups=d)
    out = tr.run(batches(), 3, straggler_schedule=sched)
    assert np.isfinite(out["final_loss"])


def test_trainer_rejects_schedule_without_on_device_mode():
    tr, batches = _trainer_setup(4, tau=2, async_mode="host_sim")
    with pytest.raises(ValueError, match="async_mode"):
        tr.run(batches(), 2, straggler_schedule=np.ones((2, 4), bool))


def test_trainer_rejects_schedule_for_non_wasgd_rule():
    """A baseline rule never reads the mask out of comm_state — injecting a
    schedule there must fail loud, not run a synchronous baseline silently
    labeled as a straggler experiment."""
    tr, batches = _trainer_setup(4, tau=2, rule="spsgd")
    with pytest.raises(ValueError, match="only consumed by the wasgd"):
        tr.run(batches(), 2, straggler_schedule=np.ones((2, 4), bool))


def test_trainer_rejects_schedule_shorter_than_run():
    tr, batches = _trainer_setup(4, tau=2)
    with pytest.raises(ValueError, match="covers 2 rounds"):
        tr.run(batches(), 5, straggler_schedule=np.ones((2, 4), bool))


def test_async_rule_all_active_equals_sync_rule():
    """With everyone active the Alg. 4 rule degenerates to the synchronous
    Eq. 10 rule: masked theta == compute_theta and the late-join is a no-op."""
    from repro.configs.base import WASGDConfig
    from repro.train.step import async_wasgd_rule, wasgd_rule

    w = 4
    params, axes = _stacked_fixture(w)
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    sync = wasgd_rule(WASGDConfig())(params, axes, h, ())[0]
    active = jnp.ones((w,), bool)
    wcfg = WASGDConfig(async_mode="on_device")
    asy = async_wasgd_rule(wcfg)(params, axes, h, active)[0]
    assert _max_leaf_err(sync, asy) < 1e-6


def test_async_rule_anneal_rides_comm_state_with_mask():
    """The anneal schedule used to be REJECTED on-device (comm_state was
    single-purpose: the activity mask). Under the policy axis the stateful
    policy's state rides comm_state alongside the mask — so annealing and
    Alg. 4 straggler rounds now compose, and each round's theta matches the
    annealed Boltzmann weights at the round's counter value."""
    from repro.configs.base import WASGDConfig
    from repro.train.step import async_wasgd_rule, init_comm_state
    from repro.core.weights import boltzmann_weights

    w, rate, a = 4, 0.5, 2.0
    params, axes = _stacked_fixture(w)
    wcfg = WASGDConfig(async_mode="on_device", a_schedule="anneal",
                       anneal_rate=rate, a_tilde=a)
    rule = async_wasgd_rule(wcfg)
    comm = init_comm_state("wasgd", params, axes, w, wcfg=wcfg)
    assert set(comm) == {"active", "policy"}
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    active = jnp.ones((w,), bool)
    for t in range(3):
        params, comm, theta, _ = rule(params, axes, h, comm)
        a_eff = a * (1.0 + rate * t)
        expect = masked_compute_theta(h, active, a_eff, "boltzmann")
        np.testing.assert_array_equal(np.asarray(theta), np.asarray(expect))
    assert float(comm["policy"]["t"]) == 3.0


# ---------------------------------------------------------------------------
# Acceptance grid: 8-device host mesh (subprocess, like test_dryrun_small)
# ---------------------------------------------------------------------------

GRID_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import backends as B
    from repro.core.async_sim import StepTimeModel, make_schedule, run_parallel_sgd
    from repro.core.async_device import run_parallel_sgd_on_device
    from repro.data import make_classification
    from repro.models import cnn
    from repro.models.param import build

    assert len(jax.devices()) == 8

    X, y = make_classification(0, 256, d=8, n_classes=3)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=8, d_hidden=16, n_classes=3), jax.random.key(0))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    def grad_fn(ps, batch):
        one = lambda p, b: loss_fn(p, b)[0]
        losses = jax.vmap(one)(ps, batch)
        grads = jax.grad(lambda q: jax.vmap(one)(q, batch).sum())(ps)
        return losses, grads
    grad_fn = jax.jit(grad_fn)

    def batches(w, n):
        rng = np.random.default_rng(0)
        while True:
            idx = rng.integers(0, len(X), size=(w, n))
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    def leaf_err(a, b):
        errs = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
        return max(jax.tree.leaves(errs))

    grids = [
        # (label, mesh, p, b): 8-way worker mesh, w/p>1 copies, pod mesh
        ("flat8",  jax.make_mesh((8,), ("data",)),          6, 2),
        ("copies", jax.make_mesh((8,), ("data",)),         12, 4),
        ("pods",   jax.make_mesh((2, 4), ("pod", "data")),  6, 2),
    ]
    for label, mesh, p, b in grids:
        w = p + b
        tm = StepTimeModel(w, sigma=0.3, straggle_p=0.2, straggle_mult=10,
                           seed=3)
        sched = make_schedule(tm, rounds=4, tau=2, n_workers=p, backups=b)
        assert not sched.active.all()
        for strategy in ("boltzmann", "inverse", "equal", "best"):
            host = run_parallel_sgd(
                loss_fn, grad_fn, params, axes, batches(w, 8), n_workers=p,
                backups=b, tau=2, rounds=4, lr=0.05, schedule=sched,
                strategy=strategy)
            for backend in ("async_shard_map", "async_rs_ag"):
                dev = run_parallel_sgd_on_device(
                    grad_fn, params, axes, batches(w, 8), n_workers=p,
                    backups=b, tau=2, rounds=4, lr=0.05, schedule=sched,
                    strategy=strategy, backend=backend,
                    ctx=B.AggregationContext(mesh=mesh))
                err = leaf_err(host.params, dev.params)
                assert err < 1e-5, (label, strategy, backend, err)
                np.testing.assert_allclose(dev.losses, host.losses,
                                           atol=1e-5)
        print("GRID", label, "ok")
    print("RESULT ok")
""")


def test_parity_grid_on_8_device_mesh():
    """Acceptance grid: on-device Alg. 4 == host simulation leaf-for-leaf
    (atol 1e-5) for {boltzmann, inverse, equal, best} x {shard_map, rs_ag}
    on an 8-device host mesh, incl. w/p>1 and pod-mesh cases. Subprocess so
    the forced device count never leaks into other tests."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", GRID_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT ok" in out.stdout


# ---------------------------------------------------------------------------
# All-straggler rounds: rejected loudly at schedule-injection time
# ---------------------------------------------------------------------------

def test_all_straggler_round_rejected_by_driver():
    """Regression: an all-False round used to flow through to
    ``losses_np[active].mean()`` — the mean of an empty slice — and quietly
    poison ``AsyncResult.losses`` with NaN. The driver must reject the
    schedule at injection time instead (masked_compute_theta's documented
    NaN contract makes such a round meaningless on-device too)."""
    from repro.core.async_device import validate_active_rounds

    params, axes, _, grad_fn, batches = _setup()
    w, rounds = 3, 4
    active = np.ones((rounds, w), bool)
    active[2] = False                                # one empty round
    sched = StragglerSchedule(active=active,
                              round_wall=np.ones(rounds))
    with pytest.raises(ValueError, match="no active worker in round"):
        run_parallel_sgd_on_device(
            grad_fn, params, axes, batches(w, 4), n_workers=w, backups=0,
            tau=2, rounds=rounds, lr=0.05, schedule=sched,
            backend="async_einsum")
    with pytest.raises(ValueError, match=r"round\(s\) \[2\]"):
        validate_active_rounds(active)
    # rounds beyond the driven range must not trip the check
    validate_active_rounds(active, rounds=2)


def test_trainer_rejects_all_straggler_round():
    """Trainer.run(straggler_schedule=) is the other injection point."""
    tr, batches = _trainer_setup(w=3, tau=2)
    bad = np.ones((4, 3), bool)
    bad[1] = False
    with pytest.raises(ValueError, match="no active worker in round"):
        tr.run(batches(), 4, straggler_schedule=bad)
