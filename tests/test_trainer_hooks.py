"""Trainer host-loop hooks: metrics JSONL, periodic checkpoints, resume."""
import functools
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import restore
from repro.configs import TrainConfig, WASGDConfig
from repro.data import OrderedDataset, make_classification
from repro.models import cnn
from repro.models.param import build
from repro.train import Trainer


def _setup(seed=0):
    X, y = make_classification(seed, 1024, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4), jax.random.key(seed))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    return X, y, params, axes, loss_fn


def test_metrics_jsonl_and_checkpoints(tmp_path):
    X, y, params, axes, loss_fn = _setup()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=4))
    ds = OrderedDataset({"x": X, "y": y}, 2, 4, 8, n_segments=1)
    tr = Trainer(loss_fn, params, axes, tcfg, 2)
    mpath = str(tmp_path / "metrics.jsonl")
    cpath = str(tmp_path / "ckpts")
    tr.run(ds.batches(), 6, metrics_path=mpath,
           checkpoint_every=3, checkpoint_path=cpath)

    lines = [json.loads(l) for l in open(mpath)]
    assert len(lines) == 6
    assert all("loss" in l and "theta" in l for l in lines)
    assert lines[-1]["round"] == 5

    assert os.path.isdir(os.path.join(cpath, "round_3"))
    assert os.path.isdir(os.path.join(cpath, "round_6"))
    # periodic checkpoints are now the FULL train state in the sharded,
    # topology-aware format — restore the whole thing and check the params
    from repro.checkpoint import saved_topology
    topo = saved_topology(os.path.join(cpath, "round_6"))
    assert topo["format"] == "wasgd-sharded-v1"
    assert topo["topology"]["p"] == 2
    assert topo["topology"]["round"] == 6
    like = jax.tree.map(jnp.zeros_like, tr.state)
    restored, meta = restore(os.path.join(cpath, "round_6"), like)
    assert meta["round"] == 6
    for a, b in zip(jax.tree.leaves(restored.params),
                    jax.tree.leaves(tr.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_trainer_deterministic_given_seeds():
    X, y, params, axes, loss_fn = _setup(seed=5)
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=4))

    def run_once():
        ds = OrderedDataset({"x": X, "y": y}, 2, 4, 8, n_segments=1, seed=42)
        tr = Trainer(loss_fn, params, axes, tcfg, 2)
        tr.run(ds.batches(), 5)
        return tr.losses()

    a, b = run_once(), run_once()
    np.testing.assert_allclose(a, b, rtol=1e-6)
