"""Checkpointing, optimizers, sharding rules, HLO parsing, baselines."""
import functools
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.core import baselines as bl
from repro.core import equal_weights
from repro.launch.hlo import collective_bytes
from repro.models import cnn
from repro.models.param import add_worker_axis, build, build_abstract, is_expert_path
from repro.optim import make_optimizer
from repro.parallel.sharding import SERVE_RULES, TRAIN_RULES, spec_for


# -- checkpoint --------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": (jnp.ones(4), {"mu": jnp.zeros((2, 2))})}
    save(str(tmp_path / "ck"), tree, meta={"step": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = restore(str(tmp_path / "ck"), like)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(str(tmp_path / "ck"), {"w": jnp.ones((2, 2))})
    import pytest
    with pytest.raises(ValueError):
        restore(str(tmp_path / "ck"), {"w": jnp.ones((3, 2))})


# -- optimizers --------------------------------------------------------------------

def test_sgd_matches_manual():
    opt = make_optimizer("sgd", 0.1)
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.full(3, 2.0)}
    new_p, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(new_p["w"], 1.0 - 0.2)


def test_momentum_accumulates():
    opt = make_optimizer("momentum", 0.1, momentum=0.9)
    p = {"w": jnp.zeros(2)}
    s = opt.init(p)
    g = {"w": jnp.ones(2)}
    p, s = opt.update(g, s, p)
    p, s = opt.update(g, s, p)
    np.testing.assert_allclose(p["w"], -(0.1 + 0.19), rtol=1e-6)


def test_adamw_first_step_unit():
    opt = make_optimizer("adamw", 0.01)
    p = {"w": jnp.zeros(2)}
    g = {"w": jnp.full(2, 3.0)}
    new_p, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(new_p["w"], -0.01, rtol=1e-4)


# -- sharding rules -----------------------------------------------------------------

def _fake_mesh_shape():
    class M:
        shape = {"data": 16, "model": 16}
    return M()


def test_spec_divisibility_fallback():
    m = _fake_mesh_shape()
    # 4 kv heads cannot shard over model=16 -> replicated
    s = spec_for(m, ("embed", "kv_heads", "head_dim"), (4096, 4, 128),
                 TRAIN_RULES)
    assert s[1] is None
    # q heads 32 shard fine
    s = spec_for(m, ("embed", "heads", "head_dim"), (4096, 32, 128),
                 TRAIN_RULES)
    assert s[1] == "model"


def test_serve_rules_head_dim_pickup():
    m = _fake_mesh_shape()
    # serve: kv=4 falls back, head_dim picks up the model axis
    s = spec_for(m, ("batch", "kv_seq", "kv_heads", "head_dim"),
                 (128, 32768, 4, 128), SERVE_RULES)
    assert s[2] is None and s[3] == "model"
    # kv=32 divides: kv_heads takes model, head_dim must NOT duplicate it
    s = spec_for(m, ("batch", "kv_seq", "kv_heads", "head_dim"),
                 (128, 32768, 32, 128), SERVE_RULES)
    assert s[2] == "model" and s[3] is None


def test_worker_axis_skips_experts():
    def init(b):
        b.param("w", (4, 2), (None, None))
        e = b.scope("moe").scope("experts")
        e.param("w_up", (8, 4, 2), ("experts", "embed", "expert_ffn"))

    shapes, axes = build_abstract(init)
    s2, a2 = add_worker_axis(shapes, axes, 16, skip=is_expert_path)
    assert s2["w"].shape == (16, 4, 2)
    assert a2["w"][0] == "worker"
    assert s2["moe"]["experts"]["w_up"].shape == (8, 4, 2)
    assert a2["moe"]["experts"]["w_up"][0] == "experts"


# -- HLO collective parsing ------------------------------------------------------------

def test_collective_bytes_parses_real_hlo():
    """Compile a tiny all-reduce-containing program and account its bytes."""
    fn = jax.jit(lambda x: x.sum())  # no collective on 1 device
    txt = """
  %param.1 = f32[1024]{0} parameter(0)
  %all-reduce.3 = f32[1024]{0} all-reduce(%param.1), replica_groups={{0,16},{1,17}}, to_apply=%add
  %all-gather.2 = f32[2048]{0} all-gather(f32[1024]{0} %param.1), replica_groups={{0,1}}, dimensions={0}
"""
    out = collective_bytes(txt)
    assert out["all-reduce"] == 4096
    assert out["all-gather"] == 4096
    assert out["total"] == 8192
    assert out["by_axis"]["worker"] == 4096     # stride-16 groups
    assert out["by_axis"]["model"] == 4096      # contiguous groups


# -- baselines ----------------------------------------------------------------------

def _worker_tree(p=3):
    params = {"w": jnp.arange(p * 4, dtype=jnp.float32).reshape(p, 4)}
    axes = {"w": ("worker", None)}
    return params, axes


def test_easgd_center_moves_toward_workers():
    params, axes = _worker_tree()
    st = bl.easgd_init(params, axes)
    new_p, new_st = bl.easgd_communicate(params, axes, st, alpha=0.1)
    # center moves toward mean of workers; workers move toward center
    assert float(jnp.abs(new_st.center["w"] - params["w"].mean(0)).sum()) < \
        float(jnp.abs(st.center["w"] - params["w"].mean(0)).sum())
    spread = lambda x: float(jnp.abs(x - x.mean(0)).sum())
    assert spread(new_p["w"]) < spread(params["w"])


def test_mwu_adopts_best_worker():
    params, axes = _worker_tree()
    st = bl.mwu_init(3)
    h = jnp.array([5.0, 1.0, 3.0])
    new_p, new_st = bl.mwu_communicate(params, axes, st, h)
    for i in range(3):
        np.testing.assert_allclose(new_p["w"][i], params["w"][1])


def test_spsgd_is_plain_average():
    params, axes = _worker_tree()
    out = bl.spsgd_communicate(params, axes)
    for i in range(3):
        np.testing.assert_allclose(out["w"][i], params["w"].mean(0),
                                   rtol=1e-6)


# -- optimizer extras -----------------------------------------------------------------

def test_clip_by_global_norm():
    from repro.optim import clip_by_global_norm, global_norm
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((2, 2), -3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) > 8.0
    same, _ = clip_by_global_norm({"a": jnp.full((2,), 0.1)}, 10.0)
    np.testing.assert_allclose(same["a"], 0.1)


def test_lr_schedules():
    from repro.optim import lr_schedule
    cos = lr_schedule("cosine", 1e-2, warmup_steps=5, total_steps=50)
    vals = [float(cos(jnp.int32(s))) for s in (0, 5, 25, 49)]
    assert vals[0] < vals[1]            # warmup rises
    assert vals[2] < vals[1]            # cosine decays
    assert vals[3] < vals[2]
    const = lr_schedule("constant", 1e-3)
    np.testing.assert_allclose(float(const(jnp.int32(7))), 1e-3)


# -- consensus / eval ------------------------------------------------------------------

def test_consensus_params_collapses_workers():
    from repro.core import replicate_workers
    from repro.train.evaluate import consensus_params
    single = {"w": jnp.arange(6.0).reshape(2, 3)}
    axes = {"w": (None, None)}
    stacked, st_axes = replicate_workers(single, axes, 4)
    stacked = {"w": stacked["w"] + jnp.arange(4.0)[:, None, None]}
    out = consensus_params(stacked, st_axes)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(stacked["w"].mean(0)), rtol=1e-6)
