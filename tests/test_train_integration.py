"""End-to-end training behaviour: losses drop, rules differ as the paper
predicts, energy recording feeds theta, the pipeline honors sample orders."""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, WASGDConfig, get_smoke_config
from repro.data import OrderedDataset, lm_batch, make_classification
from repro.models import cnn
from repro.models.param import build
from repro.train import Trainer
from repro.train.lm import make_lm_loss


def _mlp_problem(seed=0, d=32, n=2048):
    X, y = make_classification(seed, n, d=d, n_classes=10)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=d, d_hidden=64, n_classes=10), jax.random.key(seed))

    def loss_fn(p, batch):
        return cnn.classification_loss(cnn.mlp_apply(p, batch["x"]),
                                       batch["y"]), {}

    return X, y, params, axes, loss_fn


def test_wasgd_loss_decreases():
    X, y, params, axes, loss_fn = _mlp_problem()
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=8, beta=0.9, a_tilde=1.0))
    ds = OrderedDataset({"x": X, "y": y}, 4, 8, 16, n_segments=2)
    tr = Trainer(loss_fn, params, axes, tcfg, 4)
    tr.run(ds.batches(), 20, order_state=ds.order,
           segment_fn=ds.segment_of_round)
    losses = tr.losses()
    assert losses[-1] < 0.5 * losses[0]


def test_all_rules_train():
    X, y, params, axes, loss_fn = _mlp_problem(seed=1)
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=4))
    finals = {}
    for rule in ["wasgd", "spsgd", "easgd", "omwu", "seq"]:
        ds = OrderedDataset({"x": X, "y": y}, 4, 4, 16, n_segments=1,
                            seed=123)
        tr = Trainer(loss_fn, params, axes, tcfg, 4, rule=rule)
        tr.run(ds.batches(), 15)
        finals[rule] = tr.losses()[-1]
        assert np.isfinite(finals[rule])
    # parallel communication should beat no-communication on this problem
    assert finals["wasgd"] < finals["seq"] * 1.1


def test_theta_reflects_energy():
    """Worker with artificially inflated loss gets down-weighted."""
    X, y, params, axes, loss_fn = _mlp_problem(seed=2)

    def skewed_loss(p, batch):
        loss, m = loss_fn(p, batch)
        # worker identity is implicit in the data; corrupt nothing here —
        # instead feed one worker garbage labels via the batch below.
        return loss, m

    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=4, a_tilde=5.0))
    tr = Trainer(skewed_loss, params, axes, tcfg, 4)
    batch = {"x": jnp.asarray(X[:256]), "y": jnp.asarray(y[:256])}
    # corrupt worker 3's labels (worker-major batch layout)
    yb = np.asarray(batch["y"]).copy()
    yb[192:256] = (yb[192:256] + 5) % 10
    batch["y"] = jnp.asarray(yb)
    for _ in range(5):
        tr.state, metrics = tr._step(tr.state, batch)
    theta = np.asarray(metrics["theta"])
    assert theta[3] == theta.min()
    h = np.asarray(metrics["h"])
    assert h[3] == h.max()


def test_momentum_and_adamw_optimizers():
    X, y, params, axes, loss_fn = _mlp_problem(seed=3)
    for opt, lr in [("momentum", 0.01), ("adamw", 0.003)]:
        tcfg = TrainConfig(learning_rate=lr, optimizer=opt,
                           wasgd=WASGDConfig(tau=4))
        ds = OrderedDataset({"x": X, "y": y}, 2, 4, 16, n_segments=1)
        tr = Trainer(loss_fn, params, axes, tcfg, 2)
        tr.run(ds.batches(), 10)
        assert tr.losses()[-1] < tr.losses()[0]


def test_lm_smoke_training_loss_drops():
    from repro.models import init_params
    cfg = get_smoke_config("stablelm-1.6b")
    params, axes = init_params(cfg, jax.random.key(0))
    tcfg = TrainConfig(learning_rate=0.05, optimizer="sgd",
                       wasgd=WASGDConfig(tau=2, beta=0.9))
    tr = Trainer(make_lm_loss(cfg), params, axes, tcfg, 2)
    losses = []
    for r in range(8):
        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch(0, 8, 32, cfg.vocab_size).items()}  # same batch
        tr.state, m = tr._step(tr.state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_pipeline_worker_major_layout():
    n, p, tau, bl = 64, 2, 2, 4
    X = np.arange(n, dtype=np.float32)[:, None]
    ds = OrderedDataset({"x": X}, p, tau, bl, n_segments=1, seed=0)
    batch = next(ds.batches())
    assert batch["x"].shape == (p * tau * bl, 1)
    flat = batch["x"].reshape(p, tau * bl)
    # each worker's samples come from its own permutation (disjoint draws
    # of the same segment); layout must be worker-major
    o0 = ds.order.order_for(0, 0, n)[: tau * bl]
    np.testing.assert_allclose(flat[0], X[o0, 0])
