"""Payload codecs (core/codecs.py): registry mechanics, round-trip error
bounds (int8 deterministic, int4 stochastic rounding), and unbiasedness of
the stochastic rounding."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codecs as C


def _leaf(seed=0, shape=(4, 257)):
    return jax.random.normal(jax.random.key(seed), shape, jnp.float32)


def test_expected_codecs_registered():
    assert set(C.available_codecs()) >= {"f32", "bf16", "int8", "int4"}


def test_get_codec_unknown_raises():
    with pytest.raises(KeyError, match="unknown payload codec"):
        C.get_codec("fp7")


def test_register_codec_duplicate_raises():
    class Dup:
        name = "f32"
    with pytest.raises(ValueError, match="already registered"):
        C.register_codec(Dup())


def test_codec_for_dtype():
    assert C.codec_for_dtype(jnp.float32).name == "f32"
    assert C.codec_for_dtype(jnp.bfloat16).name == "bf16"
    assert C.codec_for_dtype("float32").name == "f32"


def test_f32_codec_is_identity():
    x = _leaf()
    codec = C.get_codec("f32")
    payload, aux = codec.encode(x)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(x))
    assert aux is None


def test_int8_round_trip_bound():
    """Deterministic symmetric quantization: per-element round-trip error is
    at most scale/2 = max|x| / 254."""
    x = _leaf(1)
    codec = C.get_codec("int8")
    payload, scale = codec.encode(x)
    assert payload.dtype == jnp.int8
    rt = payload.astype(jnp.float32) * scale
    step = float(jnp.abs(x).max()) / 127.0
    assert float(jnp.abs(rt - x).max()) <= step / 2 + 1e-6
    np.testing.assert_allclose(float(scale), step, rtol=1e-6)


def test_int4_round_trip_bound():
    """Stochastic rounding stays strictly within one quantization step
    (scale = max|x|/7), for any key."""
    x = _leaf(2)
    codec = C.get_codec("int4")
    step = float(jnp.abs(x).max()) / 7.0
    for seed in range(4):
        class Ctx:
            key = jax.random.key(seed)
        payload, scale = codec.encode(x, Ctx())
        assert payload.dtype == jnp.int8
        q = np.asarray(payload)
        assert q.min() >= -7 and q.max() <= 7
        rt = q.astype(np.float32) * float(scale)
        assert np.abs(rt - np.asarray(x)).max() < step + 1e-6


def test_int4_encode_deterministic_without_key():
    x = _leaf(3)
    codec = C.get_codec("int4")
    p1, _ = codec.encode(x)
    p2, _ = codec.encode(x)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_int4_noise_decorrelates_with_leaf_content():
    """The draw is keyed on the payload bits: as the parameters change round
    over round the noise pattern must change too (a frozen pattern would
    turn the zero-mean error into correlated drift), and two same-shaped
    leaves with different values must not share noise."""
    codec = C.get_codec("int4")
    x1 = _leaf(6, shape=(4, 64))
    x2 = x1 + 0.01 * _leaf(7, shape=(4, 64))
    q1, s1 = codec.encode(x1)
    q2, s2 = codec.encode(x2)
    # residual-vs-grid position of the noise: if the uniform draws were the
    # same, q*scale - x would be (near-)identical; require them to differ
    # in a nontrivial fraction of elements
    r1 = np.asarray(q1, np.float32) * float(s1) - np.asarray(x1)
    r2 = np.asarray(q2, np.float32) * float(s2) - np.asarray(x2)
    assert np.abs(r1 - r2).max() > float(s1) / 4


def test_int4_noise_decorrelates_across_identical_leaves():
    """Regression: the RNG key folded only (leaf size, content-xor), so two
    IDENTICAL-content leaves (zero-inits, tied embeddings) drew the SAME
    stochastic-rounding noise and correlated their quantization error
    across the tree. ``ctx.leaf_index`` (set per-leaf by
    ``ComposedBackend.aggregate``) must break the tie — and stay
    deterministic for a fixed index."""
    from repro.core import backends as B
    codec = C.get_codec("int4")
    x = _leaf(8, shape=(4, 64))
    q0, _ = codec.encode(x, B.AggregationContext(leaf_index=0))
    q1, _ = codec.encode(x, B.AggregationContext(leaf_index=1))
    assert not np.array_equal(np.asarray(q0), np.asarray(q1))
    q0b, _ = codec.encode(x, B.AggregationContext(leaf_index=0))
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q0b))


def test_int4_identical_leaves_decorrelate_end_to_end():
    """Two equal leaves through a real ``einsum:int4`` aggregate must come
    out different: ComposedBackend stamps each leaf's flattened-tree index
    into the context before encode."""
    from repro.core import backends as B
    w = 4
    x = _leaf(9, shape=(w, 33))
    params = {"a": x, "b": x}
    axes = {"a": ("worker", None), "b": ("worker", None)}
    theta = jax.nn.softmax(jnp.arange(w, dtype=jnp.float32))
    out = B.aggregate_with("einsum:int4", params, axes, theta, 0.9)
    assert not np.array_equal(np.asarray(out["a"]), np.asarray(out["b"]))


def test_int4_stochastic_rounding_is_unbiased():
    """E[floor(x/scale + u)] = x/scale: averaging the round-trip over many
    independent keys must converge to x (the bias of deterministic int4
    rounding would not)."""
    x = _leaf(4, shape=(2, 64))
    codec = C.get_codec("int4")
    n_draws = 512
    acc = np.zeros(x.shape, np.float64)

    class Ctx:
        key = None

    for seed in range(n_draws):
        Ctx.key = jax.random.key(seed)
        payload, scale = codec.encode(x, Ctx())
        acc += np.asarray(payload, np.float64) * float(scale)
    mean = acc / n_draws
    step = float(jnp.abs(x).max()) / 7.0
    # u ~ U[0,1): per-draw variance <= step^2/4; 6-sigma statistical margin
    tol = 6 * (step / 2) / np.sqrt(n_draws)
    assert np.abs(mean - np.asarray(x)).max() < tol


@pytest.mark.parametrize("name", ["f32", "bf16", "int8", "int4"])
def test_error_bound_holds_for_einsum_aggregate(name):
    """The documented per-codec bound must cover one Eq. 10 application —
    the same contract the composition-grid test holds every schedule to."""
    from repro.core import backends as B
    w, beta = 4, 0.9
    x = _leaf(5, shape=(w, 6, 5))
    params, axes = {"w": x}, {"w": ("worker", None, None)}
    theta = jax.nn.softmax(jnp.arange(w, dtype=jnp.float32))
    codec = C.get_codec(name)
    ref = B.aggregate_with("einsum:f32", params, axes, theta, beta)["w"]
    out = B.aggregate_with(f"einsum:{name}", params, axes, theta, beta)["w"]
    err = float(jnp.abs(out - ref).max())
    assert err <= float(codec.error_bound(x, theta, beta)), (name, err)
