"""Serving path: prefill + decode == training forward, per family."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.serve import ServeEngine

ARCHS = ["yi-6b", "gemma3-1b", "mamba2-370m", "jamba-v0.1-52b",
         "llama-3.2-vision-11b", "musicgen-large"]


def _f32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _f32(get_smoke_config(arch))
    b, s = 2, 24
    params, _ = init_params(cfg, jax.random.key(0))
    batch = lm_batch(0, b, s, cfg.vocab_size, n_codebooks=cfg.n_codebooks,
                     media_tokens=cfg.n_media_tokens, d_model=cfg.d_model)
    tokens = jnp.asarray(batch["tokens"])
    media = jnp.asarray(batch["media"]).astype(jnp.float32) \
        if "media" in batch else None

    full_logits, _ = forward(cfg, params, tokens, media)

    cache = init_cache(cfg, b, max_len=64, dtype=jnp.float32)
    pre_logits, cache = prefill(cfg, params, tokens[:, :-1], cache, media)
    # prefill returns logits for position s-2 (predicting token s-1)
    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full_logits[:, -2]),
                               rtol=5e-3, atol=5e-3)

    dec_logits, cache = decode_step(cfg, params, tokens[:, -1:], cache,
                                    jnp.int32(s - 1), media)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_sliding_window_ring_cache_decode():
    """gemma3 smoke: decode far past the window size stays consistent with
    the training forward on the same sequence."""
    cfg = _f32(get_smoke_config("gemma3-1b"))   # window 16, global every 2
    b, s = 1, 28                                # > window
    params, _ = init_params(cfg, jax.random.key(1))
    tokens = jnp.asarray(lm_batch(1, b, s, cfg.vocab_size)["tokens"])

    full_logits, _ = forward(cfg, params, tokens)

    cache = init_cache(cfg, b, max_len=64, dtype=jnp.float32)
    logits, cache = prefill(cfg, params, tokens[:, :8], cache)
    for t in range(8, s):
        logits, cache = decode_step(cfg, params, tokens[:, t:t + 1], cache,
                                    jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_serve_engine_generate():
    cfg = _f32(get_smoke_config("yi-6b"))
    params, _ = init_params(cfg, jax.random.key(2))
    eng = ServeEngine(cfg, params, max_len=64, cache_dtype=jnp.float32)
    prompt = np.asarray(lm_batch(2, 2, 8, cfg.vocab_size)["tokens"])
    out = eng.generate(prompt, n_new=5)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_serve_engine_greedy_deterministic():
    cfg = _f32(get_smoke_config("stablelm-1.6b"))
    params, _ = init_params(cfg, jax.random.key(3))
    eng = ServeEngine(cfg, params, max_len=32, cache_dtype=jnp.float32)
    prompt = np.asarray(lm_batch(3, 1, 6, cfg.vocab_size)["tokens"])
    a = eng.generate(prompt, n_new=4)
    b = eng.generate(prompt, n_new=4)
    assert (a == b).all()
