"""Serving path: prefill + decode == training forward, per family."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import (decode_step, decode_step_paged, forward, init_cache,
                          init_params, prefill)
from repro.serve import PagedCache, ServeEngine

ARCHS = ["yi-6b", "gemma3-1b", "mamba2-370m", "jamba-v0.1-52b",
         "llama-3.2-vision-11b", "musicgen-large"]

# archs with sliding-window (ring-buffer) attention layers
WINDOWED_ARCHS = [a for a in ARCHS
                  if any(get_smoke_config(a).window_for_layer(i) is not None
                         for i in range(get_smoke_config(a).n_layers))]


def _f32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _f32(get_smoke_config(arch))
    b, s = 2, 24
    params, _ = init_params(cfg, jax.random.key(0))
    batch = lm_batch(0, b, s, cfg.vocab_size, n_codebooks=cfg.n_codebooks,
                     media_tokens=cfg.n_media_tokens, d_model=cfg.d_model)
    tokens = jnp.asarray(batch["tokens"])
    media = jnp.asarray(batch["media"]).astype(jnp.float32) \
        if "media" in batch else None

    full_logits, _ = forward(cfg, params, tokens, media)

    cache = init_cache(cfg, b, max_len=64, dtype=jnp.float32)
    pre_logits, cache = prefill(cfg, params, tokens[:, :-1], cache, media)
    # prefill returns logits for position s-2 (predicting token s-1)
    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full_logits[:, -2]),
                               rtol=5e-3, atol=5e-3)

    dec_logits, cache = decode_step(cfg, params, tokens[:, -1:], cache,
                                    jnp.int32(s - 1), media)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_sliding_window_ring_cache_decode():
    """gemma3 smoke: decode far past the window size stays consistent with
    the training forward on the same sequence."""
    cfg = _f32(get_smoke_config("gemma3-1b"))   # window 16, global every 2
    b, s = 1, 28                                # > window
    params, _ = init_params(cfg, jax.random.key(1))
    tokens = jnp.asarray(lm_batch(1, b, s, cfg.vocab_size)["tokens"])

    full_logits, _ = forward(cfg, params, tokens)

    cache = init_cache(cfg, b, max_len=64, dtype=jnp.float32)
    logits, cache = prefill(cfg, params, tokens[:, :8], cache)
    for t in range(8, s):
        logits, cache = decode_step(cfg, params, tokens[:, t:t + 1], cache,
                                    jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", WINDOWED_ARCHS)
def test_ring_wraparound_monolithic(arch):
    """Monolithic layout at cache_len > window: prefill LONGER than the
    window (ring-roll path), then decode multiple wraps past it; the final
    logits must match the training forward."""
    cfg = _f32(get_smoke_config(arch))
    w = min(cfg.window_for_layer(i) for i in range(cfg.n_layers)
            if cfg.window_for_layer(i) is not None)
    b, s = 1, 3 * w + 5                          # several wraps
    n_pre = w + 4                                # prefill already wrapped
    params, _ = init_params(cfg, jax.random.key(10))
    tokens = jnp.asarray(lm_batch(10, b, s, cfg.vocab_size)["tokens"])

    full_logits, _ = forward(cfg, params, tokens)

    cache = init_cache(cfg, b, max_len=s + 8, dtype=jnp.float32)
    logits, cache = prefill(cfg, params, tokens[:, :n_pre], cache)
    for t in range(n_pre, s):
        logits, cache = decode_step(cfg, params, tokens[:, t:t + 1], cache,
                                    jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", WINDOWED_ARCHS)
@pytest.mark.parametrize("block_size", [4, 8])
def test_ring_wraparound_paged(arch, block_size):
    """Paged layout at cache_len > window: decode_step_paged tracks the
    monolithic decode step-for-step through several ring wraps (the padded
    ring R = ceil(window / block_size) * block_size re-places slots)."""
    cfg = _f32(get_smoke_config(arch))
    w = min(cfg.window_for_layer(i) for i in range(cfg.n_layers)
            if cfg.window_for_layer(i) is not None)
    s, n_pre, max_len = 3 * w + 3, 6, 4 * w
    params, _ = init_params(cfg, jax.random.key(11))
    tokens = jnp.asarray(lm_batch(11, 1, s, cfg.vocab_size)["tokens"])

    mono = init_cache(cfg, 1, max_len, dtype=jnp.float32)
    logits_m, mono = prefill(cfg, params, tokens[:, :n_pre], mono)

    paged = PagedCache(cfg, n_slots=1, max_len=max_len,
                       block_size=block_size, dtype=jnp.float32)
    paged.reserve(0, s)
    paged.write_prefill(0, mono, n_pre)

    for t in range(n_pre, s):
        logits_m, mono = decode_step(cfg, params, tokens[:, t:t + 1], mono,
                                     jnp.int32(t))
        logits_p, paged.pools = decode_step_paged(
            cfg, params, tokens[:, t:t + 1], paged.pools, paged.tables,
            jnp.full((1,), t, jnp.int32), max_len=max_len,
            block_size=block_size)
        np.testing.assert_allclose(np.asarray(logits_p),
                                   np.asarray(logits_m),
                                   rtol=2e-4, atol=2e-4)


def test_serve_engine_generate():
    cfg = _f32(get_smoke_config("yi-6b"))
    params, _ = init_params(cfg, jax.random.key(2))
    eng = ServeEngine(cfg, params, max_len=64, cache_dtype=jnp.float32)
    prompt = np.asarray(lm_batch(2, 2, 8, cfg.vocab_size)["tokens"])
    out = eng.generate(prompt, n_new=5)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_serve_engine_greedy_deterministic():
    cfg = _f32(get_smoke_config("stablelm-1.6b"))
    params, _ = init_params(cfg, jax.random.key(3))
    eng = ServeEngine(cfg, params, max_len=32, cache_dtype=jnp.float32)
    prompt = np.asarray(lm_batch(3, 1, 6, cfg.vocab_size)["tokens"])
    a = eng.generate(prompt, n_new=4)
    b = eng.generate(prompt, n_new=4)
    assert (a == b).all()


def test_serve_engine_sampled_rng_discipline():
    """Sampled decode: reproducible per seed, and the first token's key is
    split from the parent before use (no key is both consumed and split)."""
    cfg = _f32(get_smoke_config("stablelm-1.6b"))
    params, _ = init_params(cfg, jax.random.key(4))
    eng = ServeEngine(cfg, params, max_len=32, cache_dtype=jnp.float32)
    prompt = np.asarray(lm_batch(4, 2, 6, cfg.vocab_size)["tokens"])
    a = eng.generate(prompt, n_new=6, temperature=1.0, seed=0)
    b = eng.generate(prompt, n_new=6, temperature=1.0, seed=0)
    np.testing.assert_array_equal(a, b)
    c = eng.generate(prompt, n_new=6, temperature=1.0, seed=1)
    assert (a != c).any()


def test_serve_engine_eos_stops_early():
    """Greedy decode with the stop token set to a token the model actually
    emits: decoding halts once every row is done, and positions after a
    row's first stop token are padded with it."""
    cfg = _f32(get_smoke_config("yi-6b"))
    params, _ = init_params(cfg, jax.random.key(6))
    eng = ServeEngine(cfg, params, max_len=32, cache_dtype=jnp.float32)
    prompt = np.asarray(lm_batch(6, 1, 6, cfg.vocab_size)["tokens"])
    base = eng.generate(prompt, n_new=10)
    eos = int(base[0, 3])
    out = eng.generate(prompt, n_new=10, eos_id=eos)
    j = list(base[0]).index(eos)                 # first natural occurrence
    assert out.shape[1] == j + 1                 # stopped right after it
    np.testing.assert_array_equal(out[0, :j + 1], base[0, :j + 1])

    # an eos that never fires changes nothing but the per-token check
    np.testing.assert_array_equal(eng.generate(prompt, n_new=10, eos_id=-1),
                                  base)


def test_serve_engine_overflow_raises():
    """prompt + n_new past max_len must fail loudly up front, not silently
    corrupt the tail of the cache."""
    cfg = _f32(get_smoke_config("yi-6b"))
    params, _ = init_params(cfg, jax.random.key(5))
    eng = ServeEngine(cfg, params, max_len=16, cache_dtype=jnp.float32)
    prompt = np.asarray(lm_batch(5, 1, 12, cfg.vocab_size)["tokens"])
    with pytest.raises(ValueError, match="exceeds the cache budget"):
        eng.generate(prompt, n_new=8)
    # at the budget exactly is fine
    out = eng.generate(prompt, n_new=4)
    assert out.shape == (1, 4)
