"""Pipelined WASGD rounds (train/step.py + data/pipeline.py).

Three guarantees under test:

* **parity** — ``pipeline="parity"`` produces params and per-round metrics
  bitwise-identical to the unpipelined step, jitted, for sync AND
  ``async_mode="on_device"`` rounds, across the composition grid's mesh
  schedules, and end-to-end through ``Trainer.run``;
* **speculative bound** — the seam forward's stale losses deviate from the
  true next-round first-forward losses by exactly zero at ``beta = 0`` and,
  for ``beta > 0``, stay within the stated mean-value bound the step
  measures per round (``spec_dev <= slack * spec_bound``);
* **prefetch correctness** — the first microbatch the host prefetcher
  stages for round ``r+1`` (and the seam carries) is leaf-for-leaf the
  slice the next round's ``reshape_batch`` consumes, and OrderGen's
  keep-or-reshuffle decision fires at EACH segment boundary (mid-epoch),
  not once per epoch.

Adapts to however many host devices exist (1 under plain tier-1; the CI
multidevice job forces 8, giving the rs_ag/shard_map specs real
collectives)."""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs import TrainConfig, WASGDConfig
from repro.data import (OrderedDataset, RoundPrefetcher, first_microbatch,
                        make_classification)
from repro.data.pipeline import OrderedDataset as _OD
from repro.models import cnn
from repro.models.param import build
from repro.optim import make_optimizer
from repro.train import Trainer
from repro.train.state import init_state
from repro.train.step import build_train_step, init_comm_state


def _mesh():
    return Mesh(np.array(jax.devices()), ("data",))


def _w():
    d = len(jax.devices())
    return 2 if d == 1 else d


def _problem(seed=0):
    X, y = make_classification(seed, 1024, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4),
        jax.random.key(seed))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    return X, y, params, axes, loss_fn


def _assert_trees_bitwise(a, b, label=""):
    same = jax.tree.map(lambda x, y: bool(np.array_equal(np.asarray(x),
                                                         np.asarray(y))),
                        a, b)
    assert all(jax.tree.leaves(same)), label


def _assert_history_bitwise(h0, h1):
    assert len(h0) == len(h1)
    for r, (a, b) in enumerate(zip(h0, h1)):
        for k in a:
            assert k in b, (r, k)
            assert np.array_equal(a[k], b[k]), (r, k, a[k], b[k])


# ---------------------------------------------------------------------------
# Prefetch correctness
# ---------------------------------------------------------------------------

def test_first_microbatch_matches_step_slice():
    """The host-staged slice must equal reshape_batch(batch)[0] — the parity
    mode's t=0 substitution rests on this equality."""
    p, tau, bl = 3, 4, 5
    rng = np.random.default_rng(0)
    batch = {"x": rng.normal(size=(tau * p * bl, 7)).astype(np.float32),
             "y": rng.integers(0, 9, size=tau * p * bl)}
    first = first_microbatch(batch, p, tau)
    for k, v in batch.items():
        step_view = np.swapaxes(
            v.reshape(p, tau, bl, *v.shape[1:]), 0, 1)[0]
        np.testing.assert_array_equal(np.asarray(first[k]), step_view)


def test_first_microbatch_rejects_indivisible_batch():
    with pytest.raises(ValueError, match="not divisible"):
        first_microbatch({"x": np.zeros((7, 2))}, n_workers=2, tau=2)


def test_round_prefetcher_pairs_infinite_stream():
    """(batch_r, first_{r+1}) pairs: batch_r equals the raw stream's round r
    and first_{r+1} is round r+1's staged first microbatch."""
    X, y, *_ = _problem()
    p, tau, bl = 2, 2, 4
    mk = lambda: OrderedDataset({"x": X, "y": y}, p, tau, bl, seed=7)
    raw = mk().batches()
    raws = [next(raw) for _ in range(6)]
    pf = RoundPrefetcher(mk().batches(), p, tau)
    try:
        for r in range(5):
            batch, nf = next(pf)
            np.testing.assert_array_equal(np.asarray(batch["x"]),
                                          raws[r]["x"])
            expect = first_microbatch(raws[r + 1], p, tau)
            for k in expect:
                np.testing.assert_array_equal(np.asarray(nf[k]),
                                              np.asarray(expect[k]))
    finally:
        pf.close()


def test_round_prefetcher_finite_stream_reuses_last_first():
    X, y, *_ = _problem()
    p, tau, bl = 2, 2, 4
    ds = OrderedDataset({"x": X, "y": y}, p, tau, bl, seed=3)
    gen = ds.batches()
    raws = [next(gen) for _ in range(3)]
    pf = RoundPrefetcher(iter(raws), p, tau)
    got = list(pf)
    pf.close()
    assert len(got) == 3
    # final pair falls back to the round's own first microbatch
    expect = first_microbatch(raws[2], p, tau)
    for k in expect:
        np.testing.assert_array_equal(np.asarray(got[2][1][k]),
                                      np.asarray(expect[k]))


def test_round_prefetcher_propagates_errors():
    def boom():
        yield {"x": np.zeros((8, 2), np.float32)}
        raise RuntimeError("upstream died")

    pf = RoundPrefetcher(boom(), n_workers=2, tau=2)
    with pytest.raises(RuntimeError, match="upstream died"):
        for _ in pf:
            pass
    pf.close()


# ---------------------------------------------------------------------------
# OrderGen segment boundaries (paper Alg. 2)
# ---------------------------------------------------------------------------

def _segment_ds(n_segments=2, boundary_delay=0):
    data = {"x": np.arange(64, dtype=np.float32)[:, None]}
    return _OD(data, n_workers=2, tau=1, b_local=4, n_segments=n_segments,
               boundary_delay=boundary_delay)
    # seg_len=32, per_round=4 -> rounds_per_segment=8


def test_ordergen_reshuffles_bad_segment_mid_epoch():
    """Regression: end_segment used to fire only at the epoch wrap (all
    segments at once); a badly-scored segment must be reshuffled the moment
    the traversal leaves it — mid-epoch."""
    ds = _segment_ds()
    it = ds.batches()
    seeds0 = ds.order.seeds.copy()
    for _ in range(ds.rounds_per_segment):        # traverse segment 0
        next(it)
    ds.order.record_scores(0, np.array([5.0, 5.0]))   # bad z-scores
    next(it)              # first round of segment 1, still mid-epoch
    assert not np.array_equal(ds.order.seeds[0], seeds0[0]), \
        "bad segment's seeds must reshuffle at its own boundary"
    np.testing.assert_array_equal(ds.order.seeds[1], seeds0[1])
    np.testing.assert_array_equal(ds.order.scores[0], 0.0)   # reset


def test_ordergen_keeps_good_segment_mid_epoch():
    ds = _segment_ds()
    it = ds.batches()
    seeds0 = ds.order.seeds.copy()
    for _ in range(ds.rounds_per_segment):
        next(it)
    ds.order.record_scores(0, np.array([-5.0, -5.0]))  # good z-scores
    next(it)
    np.testing.assert_array_equal(ds.order.seeds[0], seeds0[0])


def test_ordergen_each_segment_ends_at_its_own_boundary():
    """Over one full epoch + 1 round, every segment's decision fires exactly
    when the traversal leaves it (bad scores -> all reshuffled by then)."""
    ds = _segment_ds(n_segments=2)
    it = ds.batches()
    seeds0 = ds.order.seeds.copy()
    for r in range(2 * ds.rounds_per_segment + 1):
        seg = ds.segment_of_round(r)
        ds.order.record_scores(seg, np.array([9.0, 9.0]))
        next(it)
    assert not np.array_equal(ds.order.seeds[0], seeds0[0])
    assert not np.array_equal(ds.order.seeds[1], seeds0[1])


def test_ordergen_boundary_delay_defers_decision():
    """boundary_delay=d holds the decision for d rounds past the boundary so
    a prefetcher running d rounds ahead still sees every recorded score."""
    ds = _segment_ds(boundary_delay=1)
    it = ds.batches()
    seeds0 = ds.order.seeds.copy()
    for _ in range(ds.rounds_per_segment):
        next(it)
    ds.order.record_scores(0, np.array([5.0, 5.0]))
    next(it)                                     # boundary round: deferred
    np.testing.assert_array_equal(ds.order.seeds[0], seeds0[0])
    next(it)                                     # +1 round: decision fires
    assert not np.array_equal(ds.order.seeds[0], seeds0[0])


# ---------------------------------------------------------------------------
# Parity mode: bitwise-identical to the unpipelined step
# ---------------------------------------------------------------------------

def _steps_for(spec, pipeline, loss_fn, axes, n_workers, tau=2,
               async_mode="host_sim", n_pods=1):
    wcfg = WASGDConfig(tau=tau, backend=spec, async_mode=async_mode,
                       n_pods=n_pods)
    opt = make_optimizer("sgd", 0.05, 0.0, 0.0)
    step = build_train_step(loss_fn, opt, axes, wcfg, n_workers,
                            mesh=_mesh(), pipeline=pipeline)
    return wcfg, opt, step


SPECS = ["einsum:f32", "rs_ag:f32", "rs_ag:bf16", "rs_ag:int8",
         "shard_map:f32", "hierarchical:int8"]


@pytest.mark.parametrize("spec", SPECS)
def test_pipeline_parity_bitwise_per_spec(spec):
    """Jitted step-level parity across the composition grid's mesh
    schedules: identical params, identical shared metrics, several rounds
    deep (the carried seam output is consumed as the next round's t=0
    microbatch)."""
    X, y, params0, axes0, loss_fn = _problem()
    w, tau, bl = _w(), 2, 4
    from repro.core import replicate_workers
    params, axes = replicate_workers(params0, axes0, w)
    n_pods = 2 if spec.startswith("hierarchical") else 1
    if n_pods == 2 and w % 2:
        pytest.skip("hierarchical needs even worker count")
    wcfg, opt, step0 = _steps_for(spec, None, loss_fn, axes, w, tau,
                                  n_pods=n_pods)
    _, _, step1 = _steps_for(spec, "parity", loss_fn, axes, w, tau,
                             n_pods=n_pods)
    primer = jax.jit(step1.primer)
    jstep0, jstep1 = jax.jit(step0), jax.jit(step1)

    ds = OrderedDataset({"x": X, "y": y}, w, tau, bl, seed=11)
    gen = ds.batches()
    batches = [jax.device_put(next(gen)) for _ in range(4)]
    comm = init_comm_state("wasgd", params, axes, w, wcfg=wcfg)
    s0 = init_state(params, opt.init(params), w, comm)
    s1 = init_state(params, opt.init(params), w, comm)
    carry = primer(s1.params, batches[0])
    for r in range(3):
        nf = first_microbatch(batches[r + 1], w, tau)
        s0, m0 = jstep0(s0, batches[r])
        s1, m1, carry = jstep1(s1, batches[r], nf, carry)
        for k in m0:
            assert np.array_equal(np.asarray(m0[k]), np.asarray(m1[k])), \
                (spec, r, k)
        _assert_trees_bitwise(s0.params, s1.params, (spec, r))
        # the seam's staged batch is what round r+1 will consume
        _assert_trees_bitwise(carry["first"], nf, (spec, r, "staged"))


def test_pipeline_parity_through_trainer_run():
    """End-to-end: Trainer(pipeline="parity") over the real prefetcher vs
    the unpipelined Trainer — bitwise history and params."""
    X, y, params, axes, loss_fn = _problem()
    w = _w()
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=2, backend="rs_ag"))

    def run(pipeline):
        ds = OrderedDataset({"x": X, "y": y}, w, 2, 4, seed=5)
        tr = Trainer(loss_fn, params, axes, tcfg, w, mesh=_mesh(),
                     pipeline=pipeline)
        tr.run(ds.batches(), 5)
        return tr

    t0, t1 = run(None), run("parity")
    _assert_history_bitwise(t0.history, t1.history)
    _assert_trees_bitwise(t0.state.params, t1.state.params)


def test_pipeline_parity_async_on_device_through_trainer_run():
    """Alg. 4 rounds: the straggler mask rides comm_state, the seam rides
    the masked aggregate — parity must still be bitwise."""
    X, y, params, axes, loss_fn = _problem()
    w = _w()
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=2, backend="rs_ag",
                                         async_mode="on_device"))
    rounds = 5
    rng = np.random.default_rng(2)
    sched = np.ones((rounds, w), bool)
    for r in range(1, rounds):                   # >=1 active per round
        sched[r, rng.choice(w, max(1, w // 3), replace=False)] = False

    def run(pipeline):
        ds = OrderedDataset({"x": X, "y": y}, w, 2, 4, seed=5)
        tr = Trainer(loss_fn, params, axes, tcfg, w, mesh=_mesh(),
                     pipeline=pipeline)
        tr.run(ds.batches(), rounds, straggler_schedule=sched)
        return tr

    t0, t1 = run(None), run("parity")
    _assert_history_bitwise(t0.history, t1.history)
    _assert_trees_bitwise(t0.state.params, t1.state.params)


# ---------------------------------------------------------------------------
# Speculative mode: stale Judge forward, measured deviation bound
# ---------------------------------------------------------------------------

def test_speculative_beta0_deviation_exactly_zero():
    """beta=0 makes the Eq. 10 step the identity for active workers, so the
    pre-aggregate seam forward IS the true forward: spec_dev == 0 bitwise,
    and the whole run matches parity mode."""
    X, y, params, axes, loss_fn = _problem()
    w = _w()
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=2, beta=0.0, backend="rs_ag"))

    def run(pipeline):
        ds = OrderedDataset({"x": X, "y": y}, w, 2, 4, seed=9)
        tr = Trainer(loss_fn, params, axes, tcfg, w, mesh=_mesh(),
                     pipeline=pipeline)
        tr.run(ds.batches(), 5)
        return tr

    t1, t2 = run("parity"), run("speculative")
    for h in t2.history:
        assert float(np.abs(h["spec_dev"]).max()) == 0.0
    _assert_trees_bitwise(t1.state.params, t2.state.params)
    for a, b in zip(t1.history, t2.history):
        np.testing.assert_array_equal(a["h"], b["h"])
        np.testing.assert_array_equal(a["theta"], b["theta"])


def test_speculative_deviation_within_measured_bound():
    """The stated mean-value bound, measured per round by the step itself:
    |spec - true|_i <= slack * ||grad L_i(t=0)|| * ||delta x_i|| with a 2x
    slack for the endpoint-gradient surrogate. Round 0's deviation is 0 by
    construction (the primer runs on the round's own starting params)."""
    X, y, params, axes, loss_fn = _problem()
    w = _w()
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=2, beta=0.5, backend="rs_ag"))
    ds = OrderedDataset({"x": X, "y": y}, w, 2, 4, seed=9)
    tr = Trainer(loss_fn, params, axes, tcfg, w, mesh=_mesh(),
                 pipeline="speculative")
    tr.run(ds.batches(), 8)
    assert float(tr.history[0]["spec_dev"].max()) == 0.0
    devs = np.stack([h["spec_dev"] for h in tr.history[1:]])
    bounds = np.stack([h["spec_bound"] for h in tr.history[1:]])
    assert np.isfinite(devs).all() and (devs > 0).any(), \
        "speculative rounds must actually be stale for beta > 0"
    assert (devs <= 2.0 * bounds + 1e-6).all(), \
        (devs.max(), bounds[devs > 2.0 * bounds].min())


def test_speculative_trains():
    """Stale Judge scores are admissible: the speculative run still learns
    (loss drops) and stays finite."""
    X, y, params, axes, loss_fn = _problem()
    w = _w()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=4))
    ds = OrderedDataset({"x": X, "y": y}, w, 4, 8, seed=1)
    tr = Trainer(loss_fn, params, axes, tcfg, w, pipeline="speculative")
    tr.run(ds.batches(), 12)
    losses = tr.losses()
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# API guards
# ---------------------------------------------------------------------------

def test_pipeline_rejects_unknown_mode_and_overlap_combo():
    X, y, params0, axes0, loss_fn = _problem()
    from repro.core import replicate_workers
    params, axes = replicate_workers(params0, axes0, 2)
    opt = make_optimizer("sgd", 0.05, 0.0, 0.0)
    with pytest.raises(ValueError, match="unknown pipeline mode"):
        build_train_step(loss_fn, opt, axes, WASGDConfig(), 2,
                         pipeline="warp")
    with pytest.raises(ValueError, match="seam"):
        build_train_step(loss_fn, opt, axes, WASGDConfig(), 2,
                         pipeline="parity", overlap=lambda: jnp.float32(1))


def test_pipeline_rejects_rule_without_overlap_seam():
    X, y, params0, axes0, loss_fn = _problem()
    from repro.core import replicate_workers
    from repro.train.step import spsgd_rule
    params, axes = replicate_workers(params0, axes0, 2)
    opt = make_optimizer("sgd", 0.05, 0.0, 0.0)
    with pytest.raises(ValueError, match="overlap"):
        build_train_step(loss_fn, opt, axes, WASGDConfig(), 2,
                         rule=spsgd_rule(), pipeline="parity")


def test_trainer_rejects_pipeline_for_baseline_rules():
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    with pytest.raises(ValueError, match="wasgd"):
        Trainer(loss_fn, params, axes, tcfg, 2, rule="spsgd",
                pipeline="parity")


def test_ordergen_deferred_decision_never_fires_mid_traversal():
    """A boundary_delay that lands inside a NEW traversal of the same
    segment (n_segments=1 here) must hold the decision until that
    traversal's next boundary — reshuffling mid-traversal would switch the
    permutation under an epoch in progress."""
    ds = _segment_ds(n_segments=1, boundary_delay=2)
    rps = ds.rounds_per_segment
    it = ds.batches()
    seeds0 = ds.order.seeds.copy()
    for _ in range(rps):                          # epoch 1
        next(it)
    ds.order.record_scores(0, np.array([9.0, 9.0]))   # bad -> reshuffle due
    for _ in range(rps):                          # epoch 2: decision held
        next(it)
        np.testing.assert_array_equal(ds.order.seeds[0], seeds0[0])
    next(it)                                      # epoch-3 boundary: fires
    assert not np.array_equal(ds.order.seeds[0], seeds0[0])


# ---------------------------------------------------------------------------
# Trainer <-> OrderedDataset coordination under prefetch
# ---------------------------------------------------------------------------

def test_pipelined_run_validates_dataset_boundary_delay():
    """Passing the OrderedDataset itself lets the pipelined Trainer verify
    the OrderGen decisions are deferred past the prefetch run-ahead."""
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    tr = Trainer(loss_fn, params, axes, tcfg, 2, pipeline="parity")
    ds = OrderedDataset({"x": X, "y": y}, 2, 2, 4, n_segments=2, seed=3)
    with pytest.raises(ValueError, match="boundary_delay"):
        tr.run(ds, 4)


def test_pipelined_run_accepts_dataset_and_defaults_order_state():
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    tr = Trainer(loss_fn, params, axes, tcfg, 2, pipeline="parity")
    ds = OrderedDataset({"x": X, "y": y}, 2, 2, 4, n_segments=2, seed=3,
                        boundary_delay=RoundPrefetcher.run_ahead())
    tr.run(ds, 4)
    assert len(tr.history) == 4
    # order_state defaulted from the dataset: scores were recorded
    assert np.abs(ds.order.scores).sum() > 0


def test_pipelined_run_warns_on_bare_iterator_with_order_state():
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    tr = Trainer(loss_fn, params, axes, tcfg, 2, pipeline="parity")
    ds = OrderedDataset({"x": X, "y": y}, 2, 2, 4, n_segments=2, seed=3)
    with pytest.warns(UserWarning, match="run-ahead"):
        tr.run(ds.batches(), 3, order_state=ds.order,
               segment_fn=ds.segment_of_round)


def test_unpipelined_run_accepts_dataset():
    X, y, params, axes, loss_fn = _problem()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    tr = Trainer(loss_fn, params, axes, tcfg, 2)
    ds = OrderedDataset({"x": X, "y": y}, 2, 2, 4, n_segments=2, seed=3)
    tr.run(ds, 4)
    assert len(tr.history) == 4
    assert np.abs(ds.order.scores).sum() > 0
