"""Loss-energy estimation (Sec. 3.3, Alg. 2 RecordIndex) and the
sample-order search (Sec. 3.4, Judge/OrderGen)."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.energy import estimation_error, record_indices, record_mask
from repro.core.order import (OrderState, grouped_order, judge_scores,
                              permutation)


def test_record_indices_alg2():
    """tau=1000, m=100, c=4: the last 25 steps of each 250-step chunk."""
    idx = record_indices(1000, 100, 4)
    assert len(idx) == 100
    for i in range(4):
        end = (i + 1) * 250
        chunk = idx[(idx >= i * 250) & (idx < end)]
        assert len(chunk) == 25
        assert chunk.min() == end - 25 and chunk.max() == end - 1


def test_record_mask_small_round():
    mask = np.asarray(record_mask(4, 100, 4))
    assert mask.all()          # m >= tau: record everything


def test_estimation_error_range():
    t1 = jnp.array([0.5, 0.5])
    t2 = jnp.array([1.0, 0.0])
    assert float(estimation_error(t1, t1)) == 0.0
    assert abs(float(estimation_error(t1, t2)) - 1.0) < 1e-6


def test_judge_scores_finite_for_constant_h():
    """Degenerate round where every worker lands the same loss energy: the
    stdv clamp must keep the z-scores finite (0/sqrt(1e-30) -> 0, not NaN) —
    the async Alg. 4 path hits this whenever a single worker is active or
    losses tie exactly."""
    for h in (jnp.full((6,), 2.5), jnp.zeros((4,)), jnp.full((1,), 7.0)):
        s = np.asarray(judge_scores(h))
        assert np.isfinite(s).all()
        np.testing.assert_allclose(s, 0.0, atol=1e-6)


def test_judge_scores_standardized():
    h = jnp.array([1.0, 2.0, 3.0, 4.0, 5.0])
    s = np.asarray(judge_scores(h))
    np.testing.assert_allclose(s.mean(), 0.0, atol=1e-6)
    np.testing.assert_allclose(s.std(ddof=1), 1.0, rtol=1e-5)
    assert s[0] < -1.0 < s[-1]    # best worker scores below -1 here


def test_orderstate_keeps_good_seeds():
    st_ = OrderState(n_workers=4, n_segments=2, base_seed=0)
    seeds_before = st_.seeds.copy()
    st_.record_scores(0, np.array([-2.0, 0.5, 0.5, 1.0]))
    kept = st_.end_segment(0)
    assert kept.tolist() == [True, False, False, False]
    assert st_.seeds[0, 0] == seeds_before[0, 0]          # good seed survives
    assert (st_.seeds[0, 1:] != seeds_before[0, 1:]).all()  # others reshuffle
    assert (st_.scores[0] == 0).all()


def test_permutation_deterministic():
    a = permutation(7, 100)
    b = permutation(7, 100)
    assert (a == b).all()
    assert sorted(a.tolist()) == list(range(100))


def test_grouped_order_runs():
    labels = np.array([0] * 10 + [1] * 10)
    order = grouped_order(labels, delta=5, seed=0)
    assert sorted(order.tolist()) == list(range(20))
    runs = labels[order]
    # every run of 5 consecutive samples shares one label
    for i in range(0, 20, 5):
        assert len(set(runs[i:i + 5])) == 1


def test_order_effect_figure2_toy():
    """Paper Fig. 2: fitting y=d by SGD — interleaved sample order lands near
    (a+b)/2, grouped order lands near the last group's value."""
    a_val, b_val, lr = 1.0, 3.0, 0.4
    samples_grouped = [b_val] * 6 + [a_val] * 6
    samples_inter = [b_val, a_val] * 6

    def run(samples):
        d = 0.0
        for s in samples:
            d -= lr * (d - s)
        return d

    target = (a_val + b_val) / 2
    assert abs(run(samples_inter) - target) < abs(run(samples_grouped) - target)
    assert abs(run(samples_grouped) - a_val) < 0.3   # dragged to last group


@settings(max_examples=30, deadline=None)
@given(tau=st.integers(4, 2000), m=st.integers(1, 500), c=st.integers(1, 16))
def test_hyp_record_indices_valid(tau, m, c):
    idx = record_indices(tau, m, c)
    assert len(idx) >= 1
    assert idx.min() >= 0 and idx.max() < tau
    assert len(set(idx.tolist())) == len(idx)
