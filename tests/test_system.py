"""System-level behaviour: the full WASGD+ pipeline (Alg. 1) end to end —
data order management + energy recording + Boltzmann weighting + aggregation
— reproduces the paper's qualitative claims on a CPU-scale problem.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, WASGDConfig
from repro.data import OrderedDataset, make_classification
from repro.models import cnn
from repro.models.param import build
from repro.train import Trainer


def _problem(seed=0):
    X, y = make_classification(seed, 4096, d=32, n_classes=10)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=32, d_hidden=64, n_classes=10),
        jax.random.key(seed))

    def loss_fn(p, batch):
        return cnn.classification_loss(cnn.mlp_apply(p, batch["x"]),
                                       batch["y"]), {}

    return X, y, params, axes, loss_fn


def _final_loss(rule, tcfg, seed=0, rounds=15, p=4, **trainer_kw):
    X, y, params, axes, loss_fn = _problem(seed)
    ds = OrderedDataset({"x": X, "y": y}, p, tcfg.wasgd.tau, 16,
                        n_segments=2, seed=7)
    tr = Trainer(loss_fn, params, axes, tcfg, p, rule=rule, **trainer_kw)
    tr.run(ds.batches(), rounds, order_state=ds.order,
           segment_fn=ds.segment_of_round)
    return float(np.mean(tr.losses()[-3:]))


def test_wasgd_plus_beats_no_communication():
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=8, beta=0.9, a_tilde=1.0))
    wasgd = _final_loss("wasgd", tcfg)
    seq = _final_loss("seq", tcfg)
    assert wasgd < seq


def test_beta_zero_equals_sequential():
    """beta=0 rejects the aggregate: identical trajectories to no-comm."""
    tcfg0 = TrainConfig(learning_rate=0.05,
                        wasgd=WASGDConfig(tau=4, beta=0.0))
    a = _final_loss("wasgd", tcfg0, seed=3)
    b = _final_loss("seq", tcfg0, seed=3)
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_full_alg1_round_metrics():
    X, y, params, axes, loss_fn = _problem(5)
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=8, beta=0.9, a_tilde=2.0,
                                         m_estimate=4, record_chunks=2))
    ds = OrderedDataset({"x": X, "y": y}, 4, 8, 8, n_segments=2)
    tr = Trainer(loss_fn, params, axes, tcfg, 4)
    tr.run(ds.batches(), 6, order_state=ds.order,
           segment_fn=ds.segment_of_round)
    m = tr.history[-1]
    assert m["theta"].shape == (4,)
    assert m["h"].shape == (4,)
    assert m["scores"].shape == (4,)
    np.testing.assert_allclose(m["theta"].sum(), 1.0, rtol=1e-5)
    np.testing.assert_allclose(m["scores"].mean(), 0.0, atol=1e-5)
    assert 0 < m["omega"] <= 1.0
