"""Minimal deterministic stand-in for `hypothesis` when it isn't installed.

The container CI / dev images don't always ship hypothesis; without it four
test modules used to fail at *collection*. This shim implements exactly the
surface the suite uses — ``given``, ``settings``, ``strategies.integers /
floats / lists`` — by sampling a fixed number of pseudo-random examples from
a seed derived from the test's qualified name, so runs are deterministic.

It is NOT a property-testing engine (no shrinking, no example database). With
the real `hypothesis` installed (``pip install -e .[test]``) this module is
never imported — see ``conftest.py``.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_MAX_EXAMPLES = 15     # cap: the fallback trades coverage for suite speed


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10,
          unique: bool = False) -> SearchStrategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        out = []
        attempts = 0
        while len(out) < n and attempts < 1000:
            v = elements.example(rng)
            attempts += 1
            if unique and v in out:
                continue
            out.append(v)
        return out
    return SearchStrategy(draw)


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise NotImplementedError("fallback @given supports keyword "
                                  "strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_max_examples", _MAX_EXAMPLES),
                    _MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, **{**drawn, **kwargs})
        # pytest introspects the signature for fixture injection: hide the
        # strategy-filled parameters, keep any others (parametrize/fixtures).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in kw_strategies])
        del wrapper.__wrapped__
        wrapper._max_examples = _MAX_EXAMPLES
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


class settings:
    """Accepts the kwargs the suite uses (max_examples, deadline) and applies
    the example cap to an already-``given``-wrapped test."""

    def __init__(self, max_examples=None, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._max_examples = min(self.max_examples, _MAX_EXAMPLES)
        return fn


def install() -> None:
    """Register this shim as the ``hypothesis`` package in sys.modules."""
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers, st.floats, st.lists = integers, floats, lists
    st.SearchStrategy = SearchStrategy
    hyp.given, hyp.settings, hyp.strategies = given, settings, st
    hyp.__is_fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
