"""Aggregation backend registry: numerical parity across all backends, config
plumbing through ``communicate``/``wasgd_rule``, and regressions for the
config-dropping and rs_ag w/p>1 bugs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.configs.base import WASGDConfig
from repro.core import backends as B
from repro.core import communicate
from repro.core.aggregate import weighted_aggregate
from repro.core.shardmap_agg import weighted_aggregate_shard_map
from repro.core.weights import compute_theta
from repro.train.step import wasgd_rule

W = 4
BETA = 0.9


def _mesh():
    """Single-device worker mesh: collectives are trivial but every shard_map
    code path (specs, scatter, gather, w/p>1 local reduction) still runs."""
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _fixture(seed=0):
    k = jax.random.key(seed)
    # "head" is 33-wide: odd on purpose, to exercise the rs_ag padding path.
    params = {"blk": {"w": jax.random.normal(k, (W, 6, 5))},
              "head": jax.random.normal(jax.random.fold_in(k, 1), (W, 33)),
              "experts": {"up": jnp.ones((3, 2))}}
    axes = {"blk": {"w": ("worker", None, None)},
            "head": ("worker", None),
            "experts": {"up": ("experts", None)}}
    theta = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2), (W,)))
    return params, axes, theta


def _max_err(a, b):
    return float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------

def test_all_expected_backends_registered():
    assert set(B.available_backends()) >= {
        "einsum", "quantized", "hierarchical", "shard_map", "rs_ag",
        "pallas_wagg"}


def test_get_backend_unknown_raises():
    with pytest.raises(KeyError, match="unknown aggregation backend"):
        B.get_backend("does_not_exist")


def test_register_backend_duplicate_raises_and_overwrite_works():
    def fn(params, axes, theta, beta, ctx):
        return params
    B.register_backend("tmp_test_backend", fn)
    with pytest.raises(ValueError, match="already registered"):
        B.register_backend("tmp_test_backend", fn)
    B.register_backend("tmp_test_backend", fn, overwrite=True)
    assert B.get_backend("tmp_test_backend").name == "tmp_test_backend"
    del B._REGISTRY["tmp_test_backend"]


def test_mesh_requiring_backend_raises_without_mesh():
    params, axes, theta = _fixture()
    for name in ("shard_map", "rs_ag"):
        with pytest.raises(ValueError, match="needs ctx.mesh"):
            B.aggregate_with(name, params, axes, theta, BETA)


def test_hierarchical_backend_rejects_bad_n_pods():
    """Fail clear instead of silently degrading to the flat einsum path."""
    params, axes, theta = _fixture()
    for n_pods in (1, 3):               # default, and non-divisor of w=4
        ctx = B.AggregationContext(n_pods=n_pods)
        with pytest.raises(ValueError, match="n_pods"):
            B.aggregate_with("hierarchical", params, axes, theta, BETA,
                             ctx=ctx)


def test_aggregate_from_config_matches_explicit_backend():
    params, axes, theta = _fixture()
    out = B.aggregate_from_config(WASGDConfig(quantize_comm=True), params,
                                  axes, theta)
    ref = B.aggregate_with("quantized", params, axes, theta, BETA)
    np.testing.assert_array_equal(np.asarray(out["head"]),
                                  np.asarray(ref["head"]))


@pytest.mark.parametrize("cfg,expected", [
    (WASGDConfig(), "einsum"),
    (WASGDConfig(quantize_comm=True), "einsum:int8"),
    (WASGDConfig(hierarchical=True, n_pods=2), "hierarchical"),
    (WASGDConfig(sharded_aggregate=True), "rs_ag"),
    (WASGDConfig(backend="pallas_wagg", quantize_comm=True), "pallas_wagg"),
    # legacy booleans COMPOSE now instead of shadowing each other
    (WASGDConfig(quantize_comm=True, sharded_aggregate=True), "rs_ag:int8"),
    (WASGDConfig(quantize_comm=True, hierarchical=True, n_pods=2),
     "hierarchical:int8"),
])
def test_backend_name_from_config(cfg, expected):
    assert B.backend_name_from_config(cfg) == expected


def test_backend_name_from_config_degenerate_pods_raises():
    """hierarchical=True with n_pods=1 used to fall through to the flat
    einsum path without a word — it must fail loud now."""
    with pytest.raises(ValueError, match="n_pods"):
        B.backend_name_from_config(WASGDConfig(hierarchical=True))


def test_backend_name_from_config_conflicting_schedules_warn():
    wcfg = WASGDConfig(hierarchical=True, n_pods=2, sharded_aggregate=True)
    with pytest.warns(UserWarning, match="two different schedules"):
        assert B.backend_name_from_config(wcfg) == "hierarchical"


def test_resolve_spec_and_aliases():
    assert B.resolve_spec("quantized") == ("einsum", "int8")
    assert B.resolve_spec("rs_ag:int8") == ("rs_ag", "int8")
    assert B.resolve_spec("hierarchical") == ("hierarchical", None)
    assert B.canonical_spec("quantized") == "einsum:int8"
    assert B.canonical_spec("async_rs_ag") == "rs_ag"
    with pytest.raises(KeyError, match="unknown aggregation schedule"):
        B.resolve_spec("nope:int8")  # reprolint: allow=SPEC001 -- error path
    with pytest.raises(KeyError, match="unknown payload codec"):
        B.resolve_spec("einsum:fp7")  # reprolint: allow=SPEC001 -- error path


def test_quantized_alias_matches_composed_spec():
    params, axes, theta = _fixture()
    alias = B.aggregate_with("quantized", params, axes, theta, BETA)
    spec = B.aggregate_with("einsum:int8", params, axes, theta, BETA)
    np.testing.assert_array_equal(np.asarray(alias["head"]),
                                  np.asarray(spec["head"]))


def test_pallas_wagg_composes_with_quantizing_codecs():
    """v2: the fused kernel consumes int8/int4 payload tiles directly, so
    pallas_wagg composes with every codec (it used to reject non-f32)."""
    from repro.core.codecs import get_codec
    params, axes, theta = _fixture()
    ref = B.aggregate_with("einsum:f32", params, axes, theta, BETA)
    for codec_name in ("bf16", "int8", "int4"):
        out = B.aggregate_with(f"pallas_wagg:{codec_name}", params, axes,
                               theta, BETA)
        tol = float(get_codec(codec_name).error_bound(params["head"], theta,
                                                      BETA))
        assert _max_err(out["head"], ref["head"]) <= tol, codec_name


def test_schedule_codec_restriction_still_enforced(monkeypatch):
    """The codecs-tuple guard stays live for schedules that declare one."""
    params, axes, theta = _fixture()
    monkeypatch.setattr(B._SCHEDULES["pallas_wagg"], "codecs", ("f32",))
    with pytest.raises(ValueError, match="composes only with codecs"):
        B.aggregate_with("pallas_wagg:int8", params, axes, theta, BETA)


# ---------------------------------------------------------------------------
# Shared numerical-parity fixture: every backend vs the einsum reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(
    {"einsum", "quantized", "hierarchical", "shard_map", "rs_ag",
     "pallas_wagg"}))
def test_backend_parity_with_einsum_reference(name):
    params, axes, theta = _fixture()
    ctx = B.AggregationContext(mesh=_mesh(), comm_dtype=jnp.float32, n_pods=2)
    ref = B.aggregate_with("einsum", params, axes, theta, BETA, ctx=ctx)
    out = B.aggregate_with(name, params, axes, theta, BETA, ctx=ctx)
    # int8 payload: per-leaf scale bounds the error at ~beta * max|x| / 127
    tol = 0.06 if name == "quantized" else 1e-5
    assert _max_err(out["blk"]["w"], ref["blk"]["w"]) < tol
    assert _max_err(out["head"], ref["head"]) < tol
    # non-worker leaves pass through untouched for every backend
    np.testing.assert_array_equal(np.asarray(out["experts"]["up"]),
                                  np.asarray(params["experts"]["up"]))


# ---------------------------------------------------------------------------
# Regression: communicate used to drop comm_dtype / hierarchical / rs_ag
# ---------------------------------------------------------------------------

def test_communicate_honors_comm_dtype():
    """Pre-fix, ``communicate`` passed only ``quantize_comm`` downstream, so a
    bf16 comm config silently computed in f32 — outputs were identical."""
    params, axes, _ = _fixture()
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    f32 = communicate(params, axes, h, WASGDConfig())
    bf16 = communicate(params, axes, h, WASGDConfig(comm_dtype="bfloat16"))
    assert _max_err(f32.params["head"], bf16.params["head"]) > 1e-4
    # and bf16 stays close: same rule, lower-precision payload
    assert _max_err(f32.params["head"], bf16.params["head"]) < 0.1


def test_communicate_honors_hierarchical():
    """A hierarchical+bf16 config must match the 2-hop reference computation
    (pre-fix it ignored both knobs and equalled the plain f32 einsum)."""
    params, axes, _ = _fixture()
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    wcfg = WASGDConfig(hierarchical=True, n_pods=2, comm_dtype="bfloat16")
    out = communicate(params, axes, h, wcfg)
    theta = compute_theta(h, wcfg.strategy, wcfg.a_tilde)
    ref = weighted_aggregate(params, axes, theta, wcfg.beta,
                             comm_dtype=jnp.bfloat16, n_pods=2)
    np.testing.assert_allclose(np.asarray(out.params["head"]),
                               np.asarray(ref["head"]), rtol=1e-6, atol=1e-7)
    plain = communicate(params, axes, h, WASGDConfig())
    assert _max_err(out.params["head"], plain.params["head"]) > 1e-4


def test_communicate_routes_sharded_aggregate_through_rs_ag():
    params, axes, _ = _fixture()
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    wcfg = WASGDConfig(sharded_aggregate=True)
    with pytest.raises(ValueError, match="needs ctx.mesh"):
        communicate(params, axes, h, wcfg)
    out = communicate(params, axes, h, wcfg, mesh=_mesh())
    ref = communicate(params, axes, h, WASGDConfig())
    assert _max_err(out.params["head"], ref.params["head"]) < 1e-5


def test_communicate_backend_field_selects_quantized():
    params, axes, _ = _fixture()
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    ref = communicate(params, axes, h, WASGDConfig())
    out = communicate(params, axes, h, WASGDConfig(backend="quantized"))
    err = _max_err(out.params["head"], ref.params["head"])
    assert 0 < err < 0.06


# ---------------------------------------------------------------------------
# End-to-end: WASGDConfig.backend through the train-step rule (jitted)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["quantized", "hierarchical", "pallas_wagg"])
def test_wasgd_rule_selects_backend_end_to_end(name):
    params, axes, _ = _fixture()
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    wcfg = WASGDConfig(backend=name, n_pods=2)
    rule = wasgd_rule(wcfg)
    new_params, _, theta, _ = jax.jit(
        lambda p, e: rule(p, axes, e, ()))(params, h)
    ref = weighted_aggregate(params, axes, theta, wcfg.beta)
    tol = 0.06 if name == "quantized" else 1e-5
    assert _max_err(new_params["head"], ref["head"]) < tol


def test_wasgd_rule_mesh_backend_end_to_end():
    params, axes, _ = _fixture()
    h = jnp.array([0.5, 1.0, 2.0, 0.1])
    rule = wasgd_rule(WASGDConfig(backend="rs_ag"), mesh=_mesh())
    new_params, _, theta, _ = rule(params, axes, h, ())
    ref = weighted_aggregate(params, axes, theta, 0.9,
                             comm_dtype=jnp.float32)
    assert _max_err(new_params["head"], ref["head"]) < 1e-5


# ---------------------------------------------------------------------------
# Regression: rs_ag with more worker copies than mesh shards (w/p > 1)
# ---------------------------------------------------------------------------

def test_rs_ag_more_copies_than_shards():
    """Pre-fix, ``aggregate_leaf_rs_ag`` flattened the local copies INTO the
    scatter dimension, so with w/p > 1 each copy received a chunk of the
    concatenation instead of the theta-reduced aggregate."""
    params, axes, theta = _fixture()
    mesh = _mesh()                      # 1 shard, 4 worker copies: w/p = 4
    out = weighted_aggregate_shard_map(params, axes, theta, BETA, mesh,
                                       schedule="rs_ag",
                                       comm_dtype=jnp.float32)
    ref = weighted_aggregate(params, axes, theta, BETA)
    assert _max_err(out["blk"]["w"], ref["blk"]["w"]) < 1e-5
    assert _max_err(out["head"], ref["head"]) < 1e-5
