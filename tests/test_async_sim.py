"""Alg. 4 async simulation: scheduling semantics + the paper's Sec. 3.5
sync/async decision rule."""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.async_sim import StepTimeModel, masked_theta, run_parallel_sgd
from repro.core.weights import compute_theta
from repro.data import make_classification
from repro.models import cnn
from repro.models.param import build


def _setup(seed=0):
    X, y = make_classification(seed, 1024, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4), jax.random.key(seed))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    def grad_fn(ps, batch):
        one = lambda p, b: loss_fn(p, b)[0]
        losses = jax.vmap(one)(ps, batch)
        grads = jax.grad(lambda q: jax.vmap(one)(q, batch).sum())(ps)
        return losses, grads

    def batches(w, n):
        rng = np.random.default_rng(seed)
        while True:
            idx = rng.integers(0, len(X), size=(w, n))
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, axes, loss_fn, jax.jit(grad_fn), batches


def test_step_time_model_stragglers_increase_max():
    uniform = StepTimeModel(8, sigma=0.01, seed=0).round_times(50)
    spiky = StepTimeModel(8, sigma=0.01, straggle_p=0.1, straggle_mult=50,
                          seed=0).round_times(50)
    assert spiky.max() > uniform.max() * 5


def test_async_gates_on_pth_arrival():
    params, axes, loss_fn, grad_fn, batches = _setup()
    tm = StepTimeModel(6, sigma=0.3, straggle_p=0.1, straggle_mult=30, seed=1)
    sync = run_parallel_sgd(loss_fn, grad_fn, params, axes, batches(6, 8),
                            n_workers=4, backups=2, tau=4, rounds=6, lr=0.05,
                            time_model=StepTimeModel(6, sigma=0.3,
                                                     straggle_p=0.1,
                                                     straggle_mult=30, seed=1),
                            synchronous=True)
    asyn = run_parallel_sgd(loss_fn, grad_fn, params, axes, batches(6, 8),
                            n_workers=4, backups=2, tau=4, rounds=6, lr=0.05,
                            time_model=StepTimeModel(6, sigma=0.3,
                                                     straggle_p=0.1,
                                                     straggle_mult=30, seed=1),
                            synchronous=False)
    assert asyn.wall <= sync.wall             # p-th arrival <= max arrival
    assert asyn.dropped_rounds == 2 * 6       # b backups excluded per round
    assert np.isfinite(asyn.losses).all()


def test_masked_theta_excludes_stragglers_before_normalization():
    """Regression for the straggler-sentinel bug: the excluded workers'
    sentinel energies used to ride into ``normalize_energy``'s sum, so active
    workers' normalized energies collapsed toward 0 and their Boltzmann
    weights degenerated to near-equal regardless of loss."""
    losses = np.array([0.1, 1.0, 2.0, 0.5, 9.9, 9.9])
    active = np.array([True, True, True, True, False, False])
    theta = masked_theta(losses, active, a_tilde=5.0)
    # stragglers get exactly zero weight; weights sum to 1
    assert theta[~active].max() == 0.0
    np.testing.assert_allclose(theta.sum(), 1.0, rtol=1e-6)
    # p-of-p+b weighting: active weights equal Boltzmann over ACTIVE energies
    expected = np.asarray(compute_theta(jnp.asarray(losses[active]),
                                        "boltzmann", 5.0))
    np.testing.assert_allclose(theta[active], expected, rtol=1e-5)
    # loss-ordered and decisively non-equal (the pre-fix code returned
    # near-uniform weights here: max/min ~ 1.0)
    order = np.argsort(losses[active])
    assert (np.diff(theta[active][order]) < 0).all()
    assert theta[active].max() / theta[active].min() > 1.5


def test_async_still_trains():
    params, axes, loss_fn, grad_fn, batches = _setup(seed=2)
    tm = StepTimeModel(6, seed=2)
    out = run_parallel_sgd(loss_fn, grad_fn, params, axes, batches(6, 16),
                           n_workers=4, backups=2, tau=4, rounds=15, lr=0.1,
                           time_model=tm, synchronous=False)
    assert out.losses[-1] < out.losses[0]
