"""Weight evaluating function (paper Sec. 3.2, Properties 1-2) — unit +
hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.weights import (best_weights, boltzmann_weights,
                                compute_theta, equal_weights, inverse_weights,
                                normalize_energy, omega)


def test_boltzmann_sums_to_one():
    h = jnp.array([1.0, 2.0, 3.0, 4.0])
    th = boltzmann_weights(h, a_tilde=2.0)
    np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-6)


def test_property1_a_to_zero_equal():
    """a -> 0: equally weighted case (Property 1)."""
    h = jnp.array([0.5, 1.5, 3.0])
    th = boltzmann_weights(h, a_tilde=1e-8)
    np.testing.assert_allclose(th, equal_weights(3), atol=1e-6)


def test_property1_a_to_inf_broadcasts_best():
    """a -> inf: one-hot on the smallest loss energy (Property 1)."""
    h = jnp.array([0.5, 1.5, 3.0, 0.9])
    th = boltzmann_weights(h, a_tilde=1e6)
    np.testing.assert_allclose(th, best_weights(h), atol=1e-6)


def test_better_worker_gets_larger_weight():
    h = jnp.array([1.0, 2.0, 4.0])
    th = boltzmann_weights(h, a_tilde=3.0)
    assert th[0] > th[1] > th[2]


def test_inverse_weights_wasgd_v1():
    h = jnp.array([1.0, 2.0, 4.0])
    th = inverse_weights(h)
    np.testing.assert_allclose(th, np.array([4, 2, 1]) / 7.0, rtol=1e-6)


def test_normalize_energy_eq12():
    h = jnp.array([2.0, 6.0])
    np.testing.assert_allclose(normalize_energy(h), [0.25, 0.75])


def test_strategies_dispatch():
    h = jnp.array([1.0, 2.0])
    for s in ("boltzmann", "inverse", "equal", "best"):
        th = compute_theta(h, s, 1.0)
        np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-6)
    with pytest.raises(ValueError):
        compute_theta(h, "nope")


def test_omega_bounds():
    """omega = sum theta^2 in [1/p, 1] (Lemma 2's variance knob)."""
    th = equal_weights(8)
    np.testing.assert_allclose(omega(th), 1.0 / 8)
    th = best_weights(jnp.array([1.0, 2.0]))
    np.testing.assert_allclose(omega(th), 1.0)


@settings(max_examples=50, deadline=None)
@given(
    h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=16),
    a=st.floats(0.0, 50.0),
)
def test_hyp_boltzmann_is_distribution(h, a):
    th = np.asarray(boltzmann_weights(jnp.array(h), a))
    assert np.all(th >= 0)
    np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-4)


@settings(max_examples=50, deadline=None)
@given(
    h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=16, unique=True),
    a=st.floats(0.1, 20.0),
)
def test_hyp_monotone_in_energy(h, a):
    """Lower loss energy never gets a smaller weight."""
    hv = jnp.array(h)
    th = np.asarray(boltzmann_weights(hv, a))
    order = np.argsort(h)
    assert np.all(np.diff(th[order]) <= 1e-7)


@settings(max_examples=30, deadline=None)
@given(a1=st.floats(0.1, 5.0), a2=st.floats(5.1, 50.0))
def test_hyp_larger_a_concentrates(a1, a2):
    """omega (weight concentration) is monotone in a_tilde."""
    h = jnp.array([1.0, 2.0, 3.0, 5.0])
    assert float(omega(boltzmann_weights(h, a2))) >= \
        float(omega(boltzmann_weights(h, a1))) - 1e-7
