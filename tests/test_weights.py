"""Weight evaluating function (paper Sec. 3.2, Properties 1-2) — unit +
hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.weights import (STRATEGIES, best_weights, boltzmann_weights,
                                compute_theta, equal_weights, inverse_weights,
                                normalize_energy, omega)


def test_boltzmann_sums_to_one():
    h = jnp.array([1.0, 2.0, 3.0, 4.0])
    th = boltzmann_weights(h, a_tilde=2.0)
    np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-6)


def test_property1_a_to_zero_equal():
    """a -> 0: equally weighted case (Property 1)."""
    h = jnp.array([0.5, 1.5, 3.0])
    th = boltzmann_weights(h, a_tilde=1e-8)
    np.testing.assert_allclose(th, equal_weights(3), atol=1e-6)


def test_property1_a_to_inf_broadcasts_best():
    """a -> inf: one-hot on the smallest loss energy (Property 1)."""
    h = jnp.array([0.5, 1.5, 3.0, 0.9])
    th = boltzmann_weights(h, a_tilde=1e6)
    np.testing.assert_allclose(th, best_weights(h), atol=1e-6)


def test_better_worker_gets_larger_weight():
    h = jnp.array([1.0, 2.0, 4.0])
    th = boltzmann_weights(h, a_tilde=3.0)
    assert th[0] > th[1] > th[2]


def test_inverse_weights_wasgd_v1():
    h = jnp.array([1.0, 2.0, 4.0])
    th = inverse_weights(h)
    np.testing.assert_allclose(th, np.array([4, 2, 1]) / 7.0, rtol=1e-6)


def test_normalize_energy_eq12():
    h = jnp.array([2.0, 6.0])
    np.testing.assert_allclose(normalize_energy(h), [0.25, 0.75])


def test_strategies_dispatch():
    h = jnp.array([1.0, 2.0])
    for s in ("boltzmann", "inverse", "equal", "best"):
        th = compute_theta(h, s, 1.0)
        np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-6)
    with pytest.raises(ValueError):
        compute_theta(h, "nope")


def test_omega_bounds():
    """omega = sum theta^2 in [1/p, 1] (Lemma 2's variance knob)."""
    th = equal_weights(8)
    np.testing.assert_allclose(omega(th), 1.0 / 8)
    th = best_weights(jnp.array([1.0, 2.0]))
    np.testing.assert_allclose(omega(th), 1.0)


@settings(max_examples=50, deadline=None)
@given(
    h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=16),
    a=st.floats(0.0, 50.0),
)
def test_hyp_boltzmann_is_distribution(h, a):
    th = np.asarray(boltzmann_weights(jnp.array(h), a))
    assert np.all(th >= 0)
    np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-4)


@settings(max_examples=50, deadline=None)
@given(
    h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=16, unique=True),
    a=st.floats(0.1, 20.0),
)
def test_hyp_monotone_in_energy(h, a):
    """Lower loss energy never gets a smaller weight."""
    hv = jnp.array(h)
    th = np.asarray(boltzmann_weights(hv, a))
    order = np.argsort(h)
    assert np.all(np.diff(th[order]) <= 1e-7)


@settings(max_examples=30, deadline=None)
@given(a1=st.floats(0.1, 5.0), a2=st.floats(5.1, 50.0))
def test_hyp_larger_a_concentrates(a1, a2):
    """omega (weight concentration) is monotone in a_tilde."""
    h = jnp.array([1.0, 2.0, 3.0, 5.0])
    assert float(omega(boltzmann_weights(h, a2))) >= \
        float(omega(boltzmann_weights(h, a1))) - 1e-7


@settings(max_examples=40, deadline=None)
@given(
    h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=12),
    a=st.floats(0.1, 20.0),
)
def test_hyp_all_strategies_are_distributions(h, a):
    """Every weight-evaluating function returns a distribution: theta >= 0,
    sum(theta) == 1, finite."""
    hv = jnp.array(h)
    for strategy in STRATEGIES:
        th = np.asarray(compute_theta(hv, strategy, a))
        assert np.isfinite(th).all(), strategy
        assert np.all(th >= 0), strategy
        np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-4,
                                   err_msg=strategy)


@settings(max_examples=40, deadline=None)
@given(
    h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=12, unique=True),
    a=st.floats(0.1, 20.0),
    perm_seed=st.integers(0, 2**31 - 1),
)
def test_hyp_permutation_equivariance(h, a, perm_seed):
    """Relabeling the workers relabels the weights the same way:
    theta(h[perm]) == theta(h)[perm] for all four strategies. (Unique
    energies: 'best' breaks ties by position, which no permutation-
    equivariant rule can.)"""
    hv = jnp.array(h)
    perm = np.random.default_rng(perm_seed).permutation(len(h))
    for strategy in STRATEGIES:
        th = np.asarray(compute_theta(hv, strategy, a))
        th_perm = np.asarray(compute_theta(hv[perm], strategy, a))
        np.testing.assert_allclose(th_perm, th[perm], rtol=1e-4, atol=1e-6,
                                   err_msg=strategy)


@settings(max_examples=30, deadline=None)
@given(h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=12))
def test_hyp_property1_a_to_zero_equal(h):
    """Property 1, a -> 0 limit: Boltzmann weights degenerate to equal."""
    th = np.asarray(boltzmann_weights(jnp.array(h), 1e-8))
    np.testing.assert_allclose(th, np.full(len(h), 1.0 / len(h)), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=12, unique=True),
)
def test_hyp_property1_a_to_inf_one_hot_on_min(h):
    """Property 1, a -> inf limit: one-hot on the minimum energy."""
    hn = np.asarray(h) / np.sum(h)
    gaps = np.diff(np.sort(hn))
    if gaps.min() < 1e-4:       # normalized near-tie: the limit needs a
        return                  # larger a than f32 softmax can resolve
    th = np.asarray(boltzmann_weights(jnp.array(h), 1e8))
    np.testing.assert_allclose(th, np.asarray(best_weights(jnp.array(h))),
                               atol=1e-5)
