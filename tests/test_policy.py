"""Worker-assessment policies (the schedule x codec x POLICY axis) —
property suite, legacy-alias bitwise identity, and end-to-end plumbing.

Three contracts, per the axis redesign:

* every registered policy produces a distribution over workers and is
  permutation-equivariant (from a fresh, symmetric state);
* the masked path with an all-True mask equals the unmasked path
  leaf-for-leaf;
* the legacy ``strategy``/``a_tilde``/``a_schedule`` config knobs resolve
  as ALIASES of their policy counterparts, bitwise-identically — theta and
  whole training trajectories, through both the sync and the
  ``async_mode="on_device"`` rules.
"""
import functools
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import weights as W
from repro.core.weights import (PipelinePolicy, STRATEGIES, as_policy,
                                available_policies, boltzmann_weights,
                                compute_theta, masked_compute_theta,
                                parse_policy, policy_from_config)

# One representative spec per registered stage (plus compositions), so the
# property suite covers every policy in the registry. test_registry_covered
# fails if a stage is registered without a spec here.
POLICY_SPECS = (
    "equal",
    "inverse",
    "best",
    "boltzmann(a=2.5)",
    "ema(0.9)",
    "ema(0.5)|inverse",
    "topk(2)",
    "trimmed(1)",
    "trimmed(1)|topk(3)",
    "boltzmann|anneal(linear, rate=0.1)",
    "boltzmann(a=3)|anneal(cosine, period=10, peak=8)",
    "boltzmann(a=2)|anneal(exp, rate=0.05)",
    "ema(0.9)|time_aware",
    "time_aware(gamma=2)|boltzmann(a=4)",
)


def test_registry_covered():
    mentioned = set()
    for spec in POLICY_SPECS:
        for part in spec.split("|"):
            mentioned.add(part.split("(")[0].strip())
    assert mentioned >= set(available_policies()), (
        "registered policy stages missing from POLICY_SPECS: "
        f"{sorted(set(available_policies()) - mentioned)}")


# ---------------------------------------------------------------------------
# (a) distribution + permutation equivariance, for every registered policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", POLICY_SPECS)
@settings(max_examples=15, deadline=None)
@given(h=st.lists(st.floats(0.01, 100.0), min_size=4, max_size=12,
                  unique=True),
       t=st.integers(0, 20))
def test_hyp_policy_is_distribution(spec, h, t):
    """theta >= 0, finite, sums to 1 — at any round t, from a fresh state,
    masked and unmasked."""
    pol = parse_policy(spec)
    hv = jnp.array(h)
    p = len(h)
    for active in (None, jnp.ones((p,), bool)):
        th, _ = pol(hv, active, None, jnp.float32(t))
        th = np.asarray(th)
        assert np.isfinite(th).all(), spec
        assert (th >= 0).all(), spec
        np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-4, err_msg=spec)


@pytest.mark.parametrize("spec", POLICY_SPECS)
@settings(max_examples=15, deadline=None)
@given(h=st.lists(st.floats(0.01, 100.0), min_size=4, max_size=12,
                  unique=True),
       perm_seed=st.integers(0, 2**31 - 1))
def test_hyp_policy_permutation_equivariance(spec, h, perm_seed):
    """Relabeling the workers relabels the weights the same way (fresh
    symmetric state; unique energies so rank-based stages tie-break
    identically)."""
    pol = parse_policy(spec)
    hv = jnp.array(h)
    perm = np.random.default_rng(perm_seed).permutation(len(h))
    th, _ = pol(hv)
    th_perm, _ = pol(hv[perm])
    np.testing.assert_allclose(np.asarray(th_perm), np.asarray(th)[perm],
                               rtol=1e-4, atol=1e-6, err_msg=spec)


@pytest.mark.parametrize("spec", POLICY_SPECS)
def test_policy_jit_traceable(spec):
    """Every policy traces: theta and state come out of a jitted call with
    the mask as a traced input."""
    pol = parse_policy(spec)
    p = 6
    h = jnp.linspace(0.5, 3.0, p)
    state = pol.init_state(p)
    active = jnp.array([True, True, False, True, True, True])

    @jax.jit
    def step(hh, act, st):
        return pol(hh, act, st)

    th, new_state = step(h, active, state)
    assert np.isfinite(np.asarray(th)).all()
    assert np.asarray(th)[2] == 0.0              # masked worker: exactly 0
    # state structure is stable round over round (it rides comm_state)
    assert jax.tree_util.tree_structure(new_state) == \
        jax.tree_util.tree_structure(state)


# ---------------------------------------------------------------------------
# (b) masked all-True == unmasked, leaf for leaf
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", POLICY_SPECS)
def test_masked_all_true_equals_unmasked_bitwise(spec):
    pol = parse_policy(spec)
    rng = np.random.default_rng(7)
    for p in (2, 3, 5, 8, 13):
        h = jnp.asarray(rng.uniform(0.05, 5.0, p).astype(np.float32))
        th_un, st_un = pol(h, None, None)
        th_ma, st_ma = pol(h, jnp.ones((p,), bool), None)
        np.testing.assert_array_equal(np.asarray(th_un), np.asarray(th_ma),
                                      err_msg=spec)
        for a, b in zip(jax.tree.leaves(st_un), jax.tree.leaves(st_ma)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=spec)


def test_masked_compute_theta_all_true_bitwise():
    """The legacy masked entry point, held to the same exactness."""
    rng = np.random.default_rng(3)
    for p in (2, 3, 4, 7, 16):
        h = jnp.asarray(rng.uniform(0.05, 5.0, p).astype(np.float32))
        for strategy in STRATEGIES:
            np.testing.assert_array_equal(
                np.asarray(masked_compute_theta(h, jnp.ones((p,), bool),
                                                1.7, strategy)),
                np.asarray(compute_theta(h, strategy, 1.7)),
                err_msg=strategy)


# ---------------------------------------------------------------------------
# (c) legacy aliases are bitwise-identical to their policy counterparts
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(h=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=12),
       a=st.floats(0.1, 20.0))
def test_hyp_strategy_aliases_bitwise(h, a):
    hv = jnp.array(h)
    for strategy in STRATEGIES:
        legacy = np.asarray(compute_theta(hv, strategy, a))
        th, state = as_policy(strategy, default_a=a)(hv)
        assert state == ()
        np.testing.assert_array_equal(legacy, np.asarray(th),
                                      err_msg=strategy)


def test_masked_strategy_aliases_bitwise():
    rng = np.random.default_rng(11)
    for trial in range(6):
        p = int(rng.integers(3, 9))
        h = jnp.asarray(rng.uniform(0.05, 5.0, p).astype(np.float32))
        active = np.zeros(p, bool)
        active[rng.choice(p, int(rng.integers(1, p + 1)), replace=False)] \
            = True
        for strategy in STRATEGIES:
            legacy = masked_compute_theta(h, jnp.asarray(active), 2.0,
                                          strategy)
            th, _ = as_policy(strategy, default_a=2.0)(h, jnp.asarray(active))
            np.testing.assert_array_equal(np.asarray(legacy), np.asarray(th),
                                          err_msg=strategy)


def test_legacy_anneal_alias_bitwise():
    """a_schedule="anneal" == the boltzmann|anneal(linear) policy: a_eff =
    a_tilde * (1 + rate*t) round over round, bitwise."""
    from repro.configs.base import WASGDConfig

    a, rate = 2.0, 0.3
    wcfg = WASGDConfig(a_tilde=a, a_schedule="anneal", anneal_rate=rate)
    pol = policy_from_config(wcfg)
    assert pol.stateful
    h = jnp.array([0.4, 1.1, 2.2, 0.9])
    state = pol.init_state(4)
    for t in range(4):
        th, state = pol(h, None, state)
        t_arr = jnp.asarray(float(t), jnp.float32)
        expect = boltzmann_weights(h, a * (1.0 + rate * t_arr))
        np.testing.assert_array_equal(np.asarray(th), np.asarray(expect))


def test_policy_from_config_precedence():
    from repro.configs.base import WASGDConfig

    # explicit policy wins over strategy; kernel's missing a <- a_tilde
    pol = policy_from_config(WASGDConfig(strategy="equal", a_tilde=7.0,
                                         policy="boltzmann"))
    assert pol.kernel.name == "boltzmann" and pol.a == 7.0
    # legacy anneal on an a-less kernel stays the legacy no-op (stateless)
    pol = policy_from_config(WASGDConfig(strategy="equal",
                                         a_schedule="anneal"))
    assert not pol.stateful


# ---------------------------------------------------------------------------
# Trajectory identity: legacy config == policy config, sync and on-device
# ---------------------------------------------------------------------------

def _mlp_problem(seed=0):
    from repro.data import make_classification
    from repro.models import cnn
    from repro.models.param import build

    X, y = make_classification(seed, 256, d=8, n_classes=3)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=8, d_hidden=16, n_classes=3), jax.random.key(seed))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    return X, y, params, axes, loss_fn


def _run_trainer(wcfg, rounds=4, straggler_schedule=None, seed=0):
    from repro.configs import TrainConfig
    from repro.train import Trainer

    X, y, params, axes, loss_fn = _mlp_problem(seed)
    w, tau = 4, 2
    tcfg = TrainConfig(learning_rate=0.05, wasgd=wcfg)
    tr = Trainer(loss_fn, params, axes, tcfg, w)

    def batches():
        rng = np.random.default_rng(seed)
        while True:
            idx = rng.integers(0, len(X), size=tau * w * 4)
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    tr.run(batches(), rounds, straggler_schedule=straggler_schedule)
    return tr


def _assert_trees_bitwise(a, b, msg=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(msg))


@pytest.mark.parametrize("strategy,a", [("boltzmann", 3.0), ("inverse", 1.0),
                                        ("equal", 1.0)])
def test_legacy_config_trajectory_bitwise_sync(strategy, a):
    from repro.configs.base import WASGDConfig

    legacy = _run_trainer(WASGDConfig(tau=2, strategy=strategy, a_tilde=a))
    spec = f"{strategy}(a={a})" if strategy == "boltzmann" else strategy
    pol = _run_trainer(WASGDConfig(tau=2, a_tilde=a, policy=spec))
    _assert_trees_bitwise(legacy.state.params, pol.state.params, strategy)
    for r, (m0, m1) in enumerate(zip(legacy.history, pol.history)):
        np.testing.assert_array_equal(m0["theta"], m1["theta"], err_msg=str(r))
        np.testing.assert_array_equal(m0["loss"], m1["loss"], err_msg=str(r))


def test_legacy_config_trajectory_bitwise_on_device():
    """Acceptance: strategy/a_tilde through async_mode="on_device" with a
    straggler schedule == the equivalent policy spec, bitwise."""
    from repro.configs.base import WASGDConfig

    rounds, w = 4, 4
    rng = np.random.default_rng(5)
    sched = np.ones((rounds, w), bool)
    for r in range(1, rounds):
        sched[r, rng.choice(w, 2, replace=False)] = False
    legacy = _run_trainer(
        WASGDConfig(tau=2, strategy="boltzmann", a_tilde=2.0,
                    async_mode="on_device"),
        rounds=rounds, straggler_schedule=sched)
    pol = _run_trainer(
        WASGDConfig(tau=2, policy="boltzmann(a=2.0)",
                    async_mode="on_device"),
        rounds=rounds, straggler_schedule=sched)
    _assert_trees_bitwise(legacy.state.params, pol.state.params)
    for r, (m0, m1) in enumerate(zip(legacy.history, pol.history)):
        np.testing.assert_array_equal(m0["theta"], m1["theta"], err_msg=str(r))
        assert (np.asarray(m0["theta"])[~sched[r]] == 0.0).all()


def test_stateful_policy_on_device_rides_comm_state():
    """EMA policy state + Alg. 4 mask coexist in comm_state through a real
    Trainer run; straggler theta stays exactly 0 and the EMA state
    advances."""
    from repro.configs.base import WASGDConfig

    rounds, w = 4, 4
    rng = np.random.default_rng(9)
    sched = np.ones((rounds, w), bool)
    for r in range(rounds):
        sched[r, rng.choice(w, 1)] = False
    tr = _run_trainer(WASGDConfig(tau=2, policy="ema(0.9)",
                                  async_mode="on_device"),
                      rounds=rounds, straggler_schedule=sched)
    assert set(tr.state.comm_state) == {"active", "policy"}
    ema_state = tr.state.comm_state["policy"]
    (key,) = [k for k in ema_state if k != "t"]
    # each worker's observation count == its active rounds
    np.testing.assert_array_equal(np.asarray(ema_state[key]["n"]),
                                  sched.sum(axis=0).astype(np.float32))
    for r, rec in enumerate(tr.history):
        assert (np.asarray(rec["theta"])[~sched[r]] == 0.0).all()
        assert np.isfinite(rec["loss"])


# ---------------------------------------------------------------------------
# Host sim stays the parity oracle for stateful policies
# ---------------------------------------------------------------------------

def _grad_setup(seed=0):
    X, y, params, axes, loss_fn = _mlp_problem(seed)

    def grad_fn(ps, batch):
        one = lambda p, b: loss_fn(p, b)[0]
        losses = jax.vmap(one)(ps, batch)
        grads = jax.grad(lambda q: jax.vmap(one)(q, batch).sum())(ps)
        return losses, grads

    def batches(w, n):
        rng = np.random.default_rng(seed)
        while True:
            idx = rng.integers(0, len(X), size=(w, n))
            yield {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, axes, loss_fn, jax.jit(grad_fn), batches


@pytest.mark.parametrize("policy", ["ema(0.9)", "trimmed(1)",
                                    "boltzmann(a=2)|anneal(linear, rate=0.2)"])
def test_policy_parity_host_vs_device(policy):
    """Same straggler schedule + same policy into both async paths ->
    leaf-for-leaf params (the PR 2 harness, extended to the policy axis)."""
    from repro.core import backends as B
    from repro.core.async_device import run_parallel_sgd_on_device
    from repro.core.async_sim import (StepTimeModel, make_schedule,
                                      run_parallel_sgd)

    params, axes, loss_fn, grad_fn, batches = _grad_setup()
    p, b = 4, 1
    w = p + b
    tm = StepTimeModel(w, sigma=0.3, straggle_p=0.2, straggle_mult=10, seed=3)
    sched = make_schedule(tm, rounds=4, tau=2, n_workers=p, backups=b)
    host = run_parallel_sgd(loss_fn, grad_fn, params, axes, batches(w, 8),
                            n_workers=p, backups=b, tau=2, rounds=4, lr=0.05,
                            schedule=sched, policy=policy)
    dev = run_parallel_sgd_on_device(
        grad_fn, params, axes, batches(w, 8), n_workers=p, backups=b, tau=2,
        rounds=4, lr=0.05, schedule=sched, policy=policy,
        backend="async_einsum")
    np.testing.assert_allclose(dev.losses, host.losses, atol=1e-5)
    errs = jax.tree.map(lambda a, c: float(jnp.abs(a - c).max()),
                        host.params, dev.params)
    assert max(jax.tree.leaves(errs)) < 1e-5, policy


# ---------------------------------------------------------------------------
# Measured round times: the on-device mask without any StepTimeModel
# ---------------------------------------------------------------------------

def test_measured_times_drive_on_device_round():
    """Acceptance: a full on-device async run driven by measured per-device
    round times — no StepTimeModel, no precomputed schedule. time_aware
    consumes the measurements through observe_times."""
    from repro.core.async_device import run_parallel_sgd_on_device

    params, axes, _, grad_fn, batches = _grad_setup()
    p, b, rounds = 3, 1, 4
    w = p + b
    res = run_parallel_sgd_on_device(
        grad_fn, params, axes, batches(w, 8), n_workers=p, backups=b, tau=2,
        rounds=rounds, lr=0.05, measure_times=True,
        policy="ema(0.9)|time_aware", backend="async_einsum")
    assert res.round_times is not None and res.round_times.shape == (rounds, w)
    assert np.isfinite(res.round_times).all()
    assert (res.round_times >= 0).all()
    assert np.isfinite(res.losses).all()
    assert res.dropped_rounds == rounds * b      # first-p-of-w every round
    assert res.wall > 0


def test_async_driver_legacy_strategy_stays_kernel_checked():
    """strategy= is the legacy scalar knob: a non-kernel stage name must
    keep raising the unknown-strategy error, not silently parse as a
    policy spec (which would flip the round to a stateful pipeline)."""
    from repro.core.async_device import build_async_round

    _, axes, _, grad_fn, _ = _grad_setup()
    with pytest.raises(ValueError, match="unknown weighting strategy"):
        build_async_round(grad_fn, axes, lr=0.1, strategy="ema",
                          backend="async_einsum")


def test_measured_times_reject_redundant_time_source():
    from repro.core.async_device import run_parallel_sgd_on_device
    from repro.core.async_sim import StepTimeModel

    params, axes, _, grad_fn, batches = _grad_setup()
    with pytest.raises(ValueError, match="measure_times"):
        run_parallel_sgd_on_device(
            grad_fn, params, axes, batches(4, 8), n_workers=3, backups=1,
            tau=2, rounds=2, lr=0.05, measure_times=True,
            time_model=StepTimeModel(4), backend="async_einsum")


def test_time_aware_downweights_slow_workers():
    pol = parse_policy("time_aware(gamma=1.0)|boltzmann(a=2)")
    h = jnp.array([1.0, 1.0, 1.0, 1.0])
    state = pol.init_state(4)
    th0, state = pol(h, None, state)
    np.testing.assert_allclose(np.asarray(th0), 0.25, atol=1e-6)
    state = pol.observe_times(state, jnp.array([1.0, 1.0, 1.0, 8.0]))
    th1, state = pol(h, None, state)
    assert th1[3] < th1[0]                        # slow worker downweighted
    np.testing.assert_allclose(np.asarray(th1).sum(), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# Stage behavior units
# ---------------------------------------------------------------------------

def test_topk_keeps_k_lowest_energies():
    th, _ = parse_policy("topk(2)")(jnp.array([1.0, 2.0, 4.0, 0.5]))
    th = np.asarray(th)
    assert (th > 0).sum() == 2 and th[0] > 0 and th[3] > 0


def test_trimmed_drops_both_tails():
    th, _ = parse_policy("trimmed(1)")(jnp.array([1.0, 2.0, 4.0, 0.5]))
    th = np.asarray(th)
    assert th[2] == 0.0 and th[3] == 0.0          # max and min energies
    assert th[0] > 0 and th[1] > 0


def test_trimmed_small_round_left_untrimmed():
    """<= 2k active workers: trimming would empty the round; keep the mask."""
    h = jnp.array([1.0, 2.0, 4.0, 0.5])
    active = jnp.array([True, True, False, False])
    th, _ = parse_policy("trimmed(1)")(h, active)
    th = np.asarray(th)
    assert th[0] > 0 and th[1] > 0 and th[2] == 0 and th[3] == 0


def test_ema_smooths_across_rounds():
    pol = parse_policy("ema(0.9)|best")
    state = pol.init_state(2)
    # round 0: worker 1 is better -> one-hot on 1 (bias-corrected EMA == h)
    th, state = pol(jnp.array([2.0, 1.0]), None, state)
    np.testing.assert_array_equal(np.asarray(th), [0.0, 1.0])
    # one noisy spike for worker 1 does NOT flip the smoothed ranking
    th, state = pol(jnp.array([2.0, 2.1]), None, state)
    np.testing.assert_array_equal(np.asarray(th), [0.0, 1.0])


def test_anneal_cosine_reaches_peak_and_saturates():
    pol = parse_policy("boltzmann(a=2)|anneal(cosine, period=10, peak=5)")
    stage = pol.modifiers[0]
    assert float(stage.factor(0.0)) == 1.0
    np.testing.assert_allclose(float(stage.factor(10.0)), 5.0, rtol=1e-6)
    np.testing.assert_allclose(float(stage.factor(50.0)), 5.0, rtol=1e-6)
    mid = float(stage.factor(5.0))
    assert 1.0 < mid < 5.0


# ---------------------------------------------------------------------------
# Config-time validation + empty-round rejection
# ---------------------------------------------------------------------------

def test_config_validates_strategy_listing_policies():
    from repro.configs.base import WASGDConfig
    with pytest.raises(ValueError, match="registered kernel policies"):
        WASGDConfig(strategy="nope")


def test_config_validates_policy_spec_listing_policies():
    from repro.configs.base import WASGDConfig
    with pytest.raises(ValueError, match="registered policies"):
        WASGDConfig(policy="boltzmann|nope")  # reprolint: allow=SPEC001 -- error path
    with pytest.raises(ValueError, match="at most one"):
        WASGDConfig(policy="boltzmann|equal")  # reprolint: allow=SPEC001 -- error path
    with pytest.raises(ValueError, match="schedules the kernel's 'a'"):
        WASGDConfig(policy="equal|anneal(linear)")  # reprolint: allow=SPEC001 -- error path
    with pytest.raises(ValueError, match="takes"):
        WASGDConfig(policy="boltzmann(nope=3)")  # reprolint: allow=SPEC001 -- error path
    WASGDConfig(policy="ema(0.9)|time_aware")     # valid spec constructs


def test_all_false_mask_rejected_host_and_device_identically():
    """The documented NaN footgun: a concrete all-False mask now fails
    eagerly with the same error on both the host oracle and the traced
    entry point (the async drivers already reject it at schedule
    injection)."""
    from repro.core.async_sim import masked_theta

    h = np.array([1.0, 2.0, 3.0], np.float32)
    dead = np.zeros(3, bool)
    with pytest.raises(ValueError, match="no active worker"):
        masked_theta(h, dead)
    with pytest.raises(ValueError, match="no active worker"):
        masked_compute_theta(jnp.asarray(h), jnp.asarray(dead))
    with pytest.raises(ValueError, match="no active worker"):
        parse_policy("boltzmann")(jnp.asarray(h), jnp.asarray(dead))


DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs import TrainConfig, WASGDConfig, get_smoke_config
    from repro.configs.base import InputShape
    from repro.launch.specs import input_specs
    from repro.parallel.sharding import num_workers, tree_shardings

    cfg = get_smoke_config("stablelm-1.6b")
    shape = InputShape("t", 32, 16, "train")
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    w = num_workers(mesh)
    for wcfg in (WASGDConfig(tau=2, policy="ema(0.9)|time_aware"),
                 WASGDConfig(tau=2, policy="ema(0.9)",
                             async_mode="on_device")):
        wl = input_specs(cfg, shape, w, TrainConfig(wasgd=wcfg))
        in_sh = tuple(tree_shardings(mesh, s, a, wl.rules)
                      for s, a in zip(wl.arg_shapes, wl.arg_axes))
        with mesh:
            jax.jit(wl.fn, in_shardings=in_sh).lower(*wl.arg_shapes).compile()
        print("COMPILED", wcfg.policy, wcfg.async_mode)
    print("RESULT ok")
""")


def test_policy_state_compiles_through_dryrun_specs():
    """The multi-pod dry-run path: stateful policy state (sync) and the
    {"active", "policy"} dict (on-device async) shard and compile through
    input_specs -> tree_shardings -> jit(in_shardings) on a placeholder
    mesh. Subprocess so the forced device count never leaks."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", DRYRUN_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT ok" in out.stdout


def test_register_policy_duplicate_and_custom():
    with pytest.raises(ValueError, match="already registered"):
        @W.register_policy
        class Dup:                                 # noqa
            name = "boltzmann"
            role = "kernel"

    @W.register_policy(overwrite=True)
    class Scale:
        name = "_test_scale"
        role = "energy"
        stateful = False

        def transform(self, h, active, state, t):
            return h * 2.0, state

    try:
        # reprolint: allow=SPEC001 -- _test_scale is registered above, only
        # for the duration of this test
        th, _ = parse_policy("_test_scale|boltzmann(a=2)")(
            jnp.array([1.0, 2.0]))
        # h*2 then Eq. 12 normalization: the scale cancels — same theta
        ref, _ = parse_policy("boltzmann(a=2)")(jnp.array([1.0, 2.0]))
        np.testing.assert_allclose(np.asarray(th), np.asarray(ref),
                                   atol=1e-7)
    finally:
        W._STAGES.pop("_test_scale", None)
