"""Attention substrate: chunked flash == naive softmax, sliding windows,
GQA grouping, decode-vs-prefill consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (decode_attention, flash_attention)


def naive_attention(q, k, v, causal=True, window=None):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k.astype(jnp.float32))
    qpos, kpos = jnp.arange(sq), jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window is not None:
        mask &= qpos[:, None] - kpos[None] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bkgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("kv", [1, 2, 8])
def test_flash_matches_naive(window, kv):
    b, s, h, hd = 2, 65, 8, 32
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    out = flash_attention(q, k, v, causal=True, window=window, block_k=32)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_unroll_same_result():
    b, s, h, hd = 1, 48, 4, 16
    key = jax.random.key(3)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, hd))
    a = flash_attention(q, k, v, block_k=16, unroll=False)
    bu = flash_attention(q, k, v, block_k=16, unroll=True)
    np.testing.assert_allclose(a, bu, rtol=1e-6, atol=1e-6)


def test_decode_matches_last_prefill_position():
    """decode_attention over a cache == the last row of full attention."""
    b, s, h, kv, hd = 2, 33, 4, 2, 16
    key = jax.random.key(1)
    q_all = jax.random.normal(key, (b, s, h, hd))
    k_all = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v_all = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    full = naive_attention(q_all, k_all, v_all, causal=True)

    S = 64   # cache capacity > s
    k_cache = jnp.zeros((b, S, kv, hd)).at[:, :s].set(k_all)
    v_cache = jnp.zeros((b, S, kv, hd)).at[:, :s].set(v_all)
    dec = decode_attention(q_all[:, -1:], k_cache, v_cache,
                           cache_len=jnp.int32(s))
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=2e-5, atol=2e-5)


def test_decode_window_masks_old_entries():
    b, S, h, kv, hd, win = 1, 32, 2, 1, 8, 4
    key = jax.random.key(2)
    q = jax.random.normal(key, (b, 1, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, S, kv, hd))
    cl = jnp.int32(20)
    out = decode_attention(q, k, v, cl, window=win)
    # equivalent: zero out everything but positions [16, 20)
    k2 = jnp.zeros_like(k).at[:, 16:20].set(k[:, 16:20])
    v2 = jnp.zeros_like(v).at[:, 16:20].set(v[:, 16:20])
    ref = decode_attention(q, k2, v2, cl, window=win)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
