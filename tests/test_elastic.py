"""Elastic worker membership: the WorkerSet lifecycle, resize machinery,
data-side re-sharding, and the chaos convergence test (workers join, leave,
and die mid-run; training converges anyway)."""
import functools
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, WASGDConfig
from repro.core import (MembershipSchedule, WorkerSet, make_chaos_schedule,
                        replicate_workers, resize_comm_state,
                        resize_opt_state, resize_train_state,
                        resize_worker_leaves)
from repro.core.async_device import resize_active_mask
from repro.core.membership import MembershipEvent
from repro.core.order import OrderState
from repro.core.weights import parse_policy
from repro.data import OrderedDataset, RoundPrefetcher, make_classification
from repro.models import cnn
from repro.models.param import build
from repro.optim import make_optimizer
from repro.train import Trainer


# -- WorkerSet / schedules ---------------------------------------------------

def test_workerset_lifecycle():
    ws = WorkerSet(4)
    assert ws.p == 4 and ws.generation == 0
    ev = ws.resize(6, round=3)
    assert ev == MembershipEvent(3, 4, 6)
    assert ws.p == 6 and ws.generation == 1
    ws.resize(6)                                  # no-op resize: logged, no gen bump
    assert ws.generation == 1 and len(ws.log) == 2
    with pytest.raises(ValueError):
        ws.resize(0)
    with pytest.raises(ValueError):
        WorkerSet(0)


def test_membership_schedule_p_of():
    s = MembershipSchedule(4, {3: 6, 7: 2})
    assert [s.p_of(r) for r in (0, 2, 3, 6, 7, 100)] == [4, 4, 6, 6, 2, 2]
    assert s.max_p(8) == 6
    with pytest.raises(ValueError):
        MembershipSchedule(4, {2: 0})


def test_chaos_schedule_bounds_and_determinism():
    a = make_chaos_schedule(4, 32, seed=7)
    b = make_chaos_schedule(4, 32, seed=7)
    assert a.events == b.events and a.events  # deterministic, non-trivial
    ps = [a.p_of(r) for r in range(32)]
    assert all(1 <= p <= 8 for p in ps)
    assert len(set(ps)) > 1                   # it actually moves


# -- param / mask / policy-state resize --------------------------------------

def _stacked(p):
    params = {"w": jnp.arange(p * 3, dtype=jnp.float32).reshape(p, 3),
              "shared": jnp.ones((2,))}
    axes = {"w": ("worker", None), "shared": (None,)}
    return params, axes


def test_resize_worker_leaves_grow_shrink():
    params, axes = _stacked(4)
    small = resize_worker_leaves(params, axes, 2)
    np.testing.assert_array_equal(small["w"], params["w"][:2])
    np.testing.assert_array_equal(small["shared"], params["shared"])
    big = resize_worker_leaves(params, axes, 6)
    np.testing.assert_array_equal(big["w"][:4], params["w"])  # survivors bitwise
    m = np.asarray(params["w"]).mean(axis=0)
    np.testing.assert_allclose(big["w"][4:], np.stack([m, m]), rtol=1e-6)


def test_resize_worker_leaves_theta_weighted_newcomers():
    params, axes = _stacked(4)
    theta = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    big = resize_worker_leaves(params, axes, 5, theta=theta)
    np.testing.assert_allclose(big["w"][4], params["w"][0], rtol=1e-6)


def test_resize_active_mask():
    m = jnp.asarray([True, False, True, True])
    np.testing.assert_array_equal(resize_active_mask(m, 2),
                                  np.array([True, False]))
    grown = resize_active_mask(m, 6)
    np.testing.assert_array_equal(np.asarray(grown)[4:], [True, True])
    with pytest.raises(ValueError):
        resize_active_mask(jnp.asarray([False, False, True]), 2)


def test_ema_policy_expand_state():
    pol = parse_policy("ema(0.5)|boltzmann")
    st = pol.init_state(3)
    h = jnp.asarray([1.0, 2.0, 3.0])
    _, st = pol(h, state=st)
    grown = pol.expand_state(st, 5)
    (k,) = [k for k in grown if k.endswith("ema")]
    assert grown[k]["h_bar"].shape == (5,)
    # newcomers adopt the survivors' mean running state
    np.testing.assert_allclose(np.asarray(grown[k]["h_bar"][3:]),
                               np.full(2, np.asarray(st[k]["h_bar"]).mean()),
                               rtol=1e-6)
    shrunk = pol.expand_state(st, 2)
    np.testing.assert_allclose(np.asarray(shrunk[k]["h_bar"]),
                               np.asarray(st[k]["h_bar"][:2]))


def test_resize_comm_state_shapes():
    assert resize_comm_state((), 5) == ()
    mask = jnp.ones((4,), bool)
    assert resize_comm_state(mask, 6).shape == (6,)
    pol = parse_policy("ema|boltzmann")
    st = pol.init_state(4)
    out = resize_comm_state({"active": mask, "policy": st}, 6, policy=pol)
    assert out["active"].shape == (6,)
    with pytest.raises(ValueError):
        resize_comm_state(object(), 3)


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adamw"])
def test_resize_opt_state(opt_name):
    params, axes = _stacked(4)
    opt = make_optimizer(opt_name, 0.1, 0.9, 0.01)
    st = opt.init(params)
    grown = resize_opt_state(st, axes, 6)
    shrunk = resize_opt_state(st, axes, 2)
    for s, p in ((grown, 6), (shrunk, 2)):
        for leaf in jax.tree.leaves(s):
            if np.ndim(leaf) >= 1 and np.shape(leaf)[-1] == 3:
                assert np.shape(leaf)[0] == p


def test_resize_train_state_full():
    from repro.train.state import init_state
    from repro.train.step import init_comm_state
    params, axes = _stacked(4)
    wcfg = WASGDConfig(tau=2, policy="ema|boltzmann", async_mode="on_device")
    opt = make_optimizer("adamw", 1e-3, 0.0, 0.01)
    cs = init_comm_state("wasgd+", params, axes, 4, wcfg=wcfg)
    state = init_state(params, opt.init(params), 4, cs)
    pol = parse_policy("ema|boltzmann")
    out = resize_train_state(state, axes, 6, policy=pol)
    assert out.params["w"].shape == (6, 3)
    assert out.energy.shape == (6,)
    assert out.comm_state["active"].shape == (6,)
    np.testing.assert_array_equal(out.params["w"][:4], params["w"])


def test_init_comm_state_prev_threads_membership():
    from repro.train.step import init_comm_state
    params, axes = _stacked(4)
    wcfg = WASGDConfig(tau=2, async_mode="on_device")
    cs = init_comm_state("wasgd", params, axes, 4, wcfg=wcfg)
    out = init_comm_state("wasgd", params, axes, 6, wcfg=wcfg, prev=cs)
    assert out.shape == (6,)
    with pytest.raises(ValueError):
        init_comm_state("easgd", params, axes, 6, prev=cs)


# -- data-side resize --------------------------------------------------------

def test_order_state_resize_keeps_survivor_seeds():
    st = OrderState(4, 2, base_seed=1)
    seeds = st.seeds.copy()
    st.resize(6)
    np.testing.assert_array_equal(st.seeds[:, :4], seeds)
    assert st.seeds.shape == (2, 6) and st.scores.shape == (2, 6)
    st.resize(3)
    np.testing.assert_array_equal(st.seeds, seeds[:, :3])


def test_ordered_dataset_resize_and_start_round():
    X, y = make_classification(0, 256, d=4, n_classes=2)
    ds = OrderedDataset({"x": X, "y": y}, 4, tau=2, b_local=4, n_segments=2)
    it = ds.batches()
    b = next(it)
    assert b["x"].shape[0] == 2 * 4 * 4
    ds.resize(6)
    it2 = ds.batches(start_round=5)
    b2 = next(it2)
    assert b2["x"].shape[0] == 2 * 6 * 4
    # a worker's round-5 rows are independent of the other workers' count:
    # survivors keep their permutation seeds (the slot contract)
    ds2 = OrderedDataset({"x": X, "y": y}, 4, tau=2, b_local=4, n_segments=2,
                         order_state=None, seed=0)
    for _ in range(5):
        next(ds2.batches())


def test_prefetcher_resize_restarts_staging():
    X, y = make_classification(1, 256, d=4, n_classes=2)
    ds = OrderedDataset({"x": X, "y": y}, 2, tau=2, b_local=4)
    pf = RoundPrefetcher(ds.batches(), 2, tau=2, to_device=False)
    b, first = next(pf)
    assert b["x"].shape[0] == 2 * 2 * 4 and first["x"].shape[:2] == (2, 4)
    ds.resize(3)
    pf.resize(3, ds.batches(start_round=1))
    b, first = next(pf)
    assert b["x"].shape[0] == 2 * 3 * 4 and first["x"].shape[:2] == (3, 4)
    pf.close()


# -- Trainer integration -----------------------------------------------------

def _trainer_setup(seed=0):
    X, y = make_classification(seed, 1024, d=16, n_classes=4)
    params, axes = build(functools.partial(
        cnn.mlp_init, d_in=16, d_hidden=32, n_classes=4),
        jax.random.key(seed))

    def loss_fn(p, b):
        return cnn.classification_loss(cnn.mlp_apply(p, b["x"]), b["y"]), {}

    return X, y, params, axes, loss_fn


def test_trainer_resize_validations():
    X, y, params, axes, loss_fn = _trainer_setup()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    tr = Trainer(loss_fn, params, axes, tcfg, 2, rule="easgd")
    with pytest.raises(ValueError):
        tr.resize(3)
    tr2 = Trainer(loss_fn, params, axes, tcfg, 2)
    ds = OrderedDataset({"x": X, "y": y}, 2, 2, 8)
    with pytest.raises(ValueError, match="OrderedDataset"):
        tr2.run(ds.batches(), 4,
                membership_schedule=MembershipSchedule(2, {1: 3}))


def test_trainer_membership_straggler_exclusive():
    X, y, params, axes, loss_fn = _trainer_setup()
    tcfg = TrainConfig(learning_rate=0.05,
                       wasgd=WASGDConfig(tau=2, async_mode="on_device"))
    tr = Trainer(loss_fn, params, axes, tcfg, 2)
    ds = OrderedDataset({"x": X, "y": y}, 2, 2, 8)
    with pytest.raises(ValueError, match="mutually exclusive"):
        tr.run(ds, 4, membership_schedule=MembershipSchedule(2, {1: 3}),
               straggler_schedule=np.ones((4, 2), bool))


def test_trainer_resize_preserves_survivors():
    X, y, params, axes, loss_fn = _trainer_setup()
    tcfg = TrainConfig(learning_rate=0.05, wasgd=WASGDConfig(tau=2))
    tr = Trainer(loss_fn, params, axes, tcfg, 4)
    before = jax.tree.map(np.asarray, tr.state.params)
    ev = tr.resize(6, round=0)
    assert ev.new_p == 6 and tr.n_workers == 6
    for k, v in tr.state.params.items():
        np.testing.assert_array_equal(np.asarray(v)[:4], before[k])
    assert tr.resize(6) is None              # no-op


@pytest.mark.parametrize("pipeline", [None, "parity"])
def test_chaos_schedule_converges(pipeline):
    """The acceptance chaos test: a kill/revive schedule over >= 8 rounds
    still converges, with the final loss within tolerance of a fixed-p
    run of the same length."""
    X, y, params, axes, loss_fn = _trainer_setup(seed=3)
    n_rounds = 12
    bd = RoundPrefetcher.run_ahead() if pipeline else 0

    def make(p):
        tcfg = TrainConfig(learning_rate=0.05,
                           wasgd=WASGDConfig(tau=2, policy="ema|boltzmann"))
        tr = Trainer(loss_fn, params, axes, tcfg, p, rule="wasgd+",
                     pipeline=pipeline)
        ds = OrderedDataset({"x": X, "y": y}, p, 2, 8, n_segments=2,
                            boundary_delay=bd)
        return tr, ds

    tr_fixed, ds_fixed = make(4)
    tr_fixed.run(ds_fixed, n_rounds)

    sched = make_chaos_schedule(4, n_rounds, seed=2)
    assert sched.events, "chaos schedule must actually change membership"
    tr_el, ds_el = make(4)
    res = tr_el.run(ds_el, n_rounds, membership_schedule=sched)

    ps = [h["p"] for h in tr_el.history]
    assert len(set(ps)) > 1                   # membership really moved
    assert tr_el.n_workers == sched.p_of(n_rounds - 1)
    first, final = tr_el.history[0]["loss"], res["final_loss"]
    assert final < 0.6 * float(first)         # it converges
    # and lands within tolerance of the fixed-membership run
    assert final < 3.0 * tr_fixed.history[-1]["loss"] + 0.15


def test_elastic_checkpoint_resume_other_p(tmp_path):
    """Sharded checkpoint saved mid-run restores bitwise-identically on the
    same topology, and resumes under a DIFFERENT p via the resize
    machinery."""
    X, y, params, axes, loss_fn = _trainer_setup(seed=4)
    tcfg = TrainConfig(
        learning_rate=0.05, optimizer="adamw",
        wasgd=WASGDConfig(tau=2, policy="ema|boltzmann",
                          async_mode="on_device"))

    def make(p):
        tr = Trainer(loss_fn, params, axes, tcfg, p, rule="wasgd+")
        ds = OrderedDataset({"x": X, "y": y}, p, 2, 8)
        return tr, ds

    tr, ds = make(4)
    cpath = str(tmp_path / "ck")
    tr.run(ds, 6, checkpoint_every=3, checkpoint_path=cpath)
    ck = os.path.join(cpath, "round_6")

    # same topology: bitwise restore of the FULL state
    tr2, _ = make(4)
    assert tr2.resume(ck) == 6
    for a, b in zip(jax.tree.leaves(tr.state), jax.tree.leaves(tr2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # different p: survivors land bitwise, newcomers from the aggregate,
    # and the run continues
    tr3, ds3 = make(6)
    res = tr3.run(ds3, 10, resume_from=ck)
    assert res["rounds"] == 4 and tr3.n_workers == 6
    assert np.isfinite(res["final_loss"])

    # shrink resume too
    tr4, _ = make(2)
    assert tr4.resume(ck) == 6
    np.testing.assert_array_equal(
        np.asarray(tr4.state.params["w_in"]),
        np.asarray(tr.state.params["w_in"])[:2])
